//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored crate implements the subset of anyhow's API the workspace
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Swapping in the real crate is
//! a one-line change in the root `Cargo.toml`; nothing in the tree
//! depends on stub-only behaviour.
//!
//! Design notes mirroring real anyhow:
//! * `Error` deliberately does **not** implement `std::error::Error`, so
//!   the blanket `impl<E: std::error::Error> From<E> for Error` does not
//!   collide with the reflexive `From<Error> for Error`.
//! * `{:#}` (alternate `Display`) prints the full cause chain,
//!   `msg: cause1: cause2`, matching anyhow's behaviour that the CLI's
//!   `error: {e:#}` reporting relies on.

use std::error::Error as StdError;
use std::fmt::{self, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message plus a flattened cause chain.
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string(), chain: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, c: C) -> Error {
        let mut chain = vec![self.msg];
        chain.extend(self.chain);
        Error { msg: c.to_string(), chain }
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str())
            .chain(self.chain.iter().map(String::as_str))
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().collect::<Vec<_>>().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: Display>(self, c: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "inner 42");
    }

    #[test]
    fn context_chains() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn from_std_error() {
        let io = std::fs::read_to_string("/definitely/not/a/file");
        let e: Error = io.unwrap_err().into();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<u32> {
            let v: u32 = "7".parse()?;
            Ok(v)
        }
        assert_eq!(f().unwrap(), 7);
    }
}
