//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The container image carries no libxla/PJRT shared library, so this
//! vendored crate keeps the workspace compiling and the pure-host parts
//! working:
//!
//! * [`Literal`] is a real host-side implementation (shape + typed data),
//!   enough for `Tensor::to_literal` / `from_literal` round-trips and
//!   their unit tests.
//! * The PJRT surface ([`PjRtClient`], [`PjRtLoadedExecutable`], ...)
//!   compiles but returns errors at runtime — `PjRtClient::cpu()` fails
//!   up front, so nothing downstream ever reaches an executing path.
//!
//! Swapping in the real xla-rs bindings is a one-line change in the root
//! `Cargo.toml`; the API mirrored here is exactly the subset
//! `rust/src/runtime/` uses.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable — built against the vendored \
         stub `xla` crate (no libxla in this environment); link the real \
         xla-rs bindings to execute artifacts"))
}

/// Element types the manifest can mention (subset of XLA's set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    F32,
    F64,
    Tuple,
}

/// Typed storage behind a [`Literal`].  Public only because the sealed
/// [`NativeType`] trait must name it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Host element types [`Literal`] can hold (`f32` and `i32` here).
pub trait NativeType: sealed::Sealed + Copy + 'static {
    const TYPE: PrimitiveType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TYPE: PrimitiveType = PrimitiveType::F32;

    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }

    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TYPE: PrimitiveType = PrimitiveType::S32;

    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }

    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host literal: dims + typed data (row-major), or a tuple of literals.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::wrap(vec![v]) }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: Data::Tuple(parts) }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.element_count())));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => PrimitiveType::F32,
            Data::I32(_) => PrimitiveType::S32,
            Data::Tuple(_) => {
                return Err(Error::new("array_shape of a tuple literal"))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error::new("literal element type mismatch in to_vec")
        })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error::new("to_tuple on a non-tuple literal")),
        }
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

// ---------------------------------------------------------------------
// PJRT surface: compiles, errors at runtime (no libxla in this image).
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct PjRtClient;

#[derive(Debug, Clone)]
pub struct PjRtDevice;

#[derive(Debug)]
pub struct PjRtBuffer;

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

#[derive(Debug)]
pub struct HloModuleProto;

#[derive(Debug)]
pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn addressable_devices(&self) -> Vec<PjRtDevice> {
        vec![PjRtDevice]
    }

    pub fn buffer_from_host_literal(&self, _device: Option<&PjRtDevice>,
                                    _lit: &Literal) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        PjRtClient
    }

    pub fn execute_b(&self, _inputs: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_roundtrip() {
        let l = Literal::vec1(&[1f32, 2., 3., 4.]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.primitive_type(), PrimitiveType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4.]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(5i32);
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![5]);
        let t = Literal::tuple(vec![s.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_stubbed() {
        assert!(PjRtClient::cpu().is_err());
    }
}
