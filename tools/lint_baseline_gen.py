#!/usr/bin/env python3
"""Generate lint_baseline.toml for pallas-lint's panic-hygiene rule.

This is a byte-for-byte replica of the counting semantics implemented in
rust/src/lint/ (scan.rs + rules.rs).  Run it after burning down or adding
panic sites in the hot path to refresh the committed baseline:

    python3 tools/lint_baseline_gen.py > lint_baseline.toml

(`pallas-lint --check rust/src --write-baseline` does the same thing from
the Rust side; this script exists so the baseline can be regenerated in
environments without a Rust toolchain.)
"""
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..", "rust", "src")

IDENT = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")

PANIC_MACROS = ["panic!", "unreachable!", "todo!", "unimplemented!"]


def scrub(src):
    """Blank comments and string/char literal contents with spaces
    (newlines preserved), returning (scrubbed, {offset: literal_body}).

    The literal map keys are the byte offset of the opening quote of each
    (non-raw) string literal; values are the literal body text.
    """
    b = list(src)
    n = len(src)
    literals = {}
    out = b[:]
    i = 0
    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            while i < n and src[i] != "\n":
                out[i] = " "
                i += 1
        elif c == "/" and nxt == "*":
            depth = 0
            while i < n:
                if src[i] == "/" and i + 1 < n and src[i + 1] == "*":
                    depth += 1
                    out[i] = " "
                    out[i + 1] = " "
                    i += 2
                elif src[i] == "*" and i + 1 < n and src[i + 1] == "/":
                    depth -= 1
                    out[i] = " "
                    out[i + 1] = " "
                    i += 2
                    if depth == 0:
                        break
                else:
                    if src[i] != "\n":
                        out[i] = " "
                    i += 1
        elif c == "r" and (nxt == '"' or nxt == "#"):
            # raw string r"..." / r#"..."# (possibly more #s)
            j = i + 1
            hashes = 0
            while j < n and src[j] == "#":
                hashes += 1
                j += 1
            if j < n and src[j] == '"':
                close = '"' + "#" * hashes
                k = src.find(close, j + 1)
                end = (k + len(close)) if k != -1 else n
                for p in range(i, end):
                    if src[p] != "\n":
                        out[p] = " "
                i = end
            else:
                i += 1
        elif c == '"':
            start = i
            j = i + 1
            body = []
            while j < n:
                if src[j] == "\\" and j + 1 < n:
                    body.append(src[j:j + 2])
                    j += 2
                elif src[j] == '"':
                    break
                else:
                    body.append(src[j])
                    j += 1
            end = j + 1 if j < n else n
            for p in range(i, end):
                if src[p] != "\n":
                    out[p] = " "
            literals[start] = "".join(body)
            i = end
        elif c == "'":
            # char literal vs lifetime: 'x' or '\x' is a literal; 'ident
            # (no closing quote right after) is a lifetime
            if nxt == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                end = j + 1 if j < n else n
                for p in range(i, end):
                    if src[p] != "\n":
                        out[p] = " "
                i = end
            elif i + 2 < n and src[i + 2] == "'":
                for p in range(i, i + 3):
                    out[p] = " "
                i += 3
            else:
                i += 1
        else:
            i += 1
    return "".join(out), literals


def test_spans(scrubbed):
    """Spans of `#[cfg(test)] mod … { … }` blocks (byte ranges)."""
    spans = []
    pos = 0
    attr = "#[cfg(test)]"
    while True:
        a = scrubbed.find(attr, pos)
        if a == -1:
            break
        open_b = scrubbed.find("{", a + len(attr))
        if open_b == -1 or "mod" not in scrubbed[a + len(attr):open_b]:
            pos = a + len(attr)
            continue
        depth = 0
        j = open_b
        end = len(scrubbed)
        while j < len(scrubbed):
            if scrubbed[j] == "{":
                depth += 1
            elif scrubbed[j] == "}":
                depth -= 1
                if depth == 0:
                    end = j + 1
                    break
            j += 1
        spans.append((a, end))
        pos = end
    return spans


def in_spans(spans, off):
    return any(a <= off < b for a, b in spans)


def skip_ws(s, i):
    while i < len(s) and s[i] in " \t\r\n":
        i += 1
    return i


def panic_sites(src):
    """Offsets of panic-hygiene findings, per the pallas-lint semantics:
    .unwrap(), .expect(..) without an "invariant: …" literal message,
    and panic-family macros — all outside #[cfg(test)] mod blocks."""
    scrubbed, literals = scrub(src)
    spans = test_spans(scrubbed)
    sites = []
    pos = 0
    while True:
        i = scrubbed.find(".unwrap", pos)
        if i == -1:
            break
        j = skip_ws(scrubbed, i + len(".unwrap"))
        if j < len(scrubbed) and scrubbed[j] == "(":
            k = skip_ws(scrubbed, j + 1)
            if k < len(scrubbed) and scrubbed[k] == ")":
                after = scrubbed[i + len(".unwrap"):i + len(".unwrap") + 1]
                if after not in IDENT:  # not .unwrap_or etc.
                    if not in_spans(spans, i):
                        sites.append((i, "unwrap"))
        pos = i + 1
    pos = 0
    while True:
        i = scrubbed.find(".expect", pos)
        if i == -1:
            break
        after = scrubbed[i + len(".expect"):i + len(".expect") + 1]
        if after in IDENT:  # .expect_err etc.
            pos = i + 1
            continue
        j = skip_ws(scrubbed, i + len(".expect"))
        if j < len(scrubbed) and scrubbed[j] == "(":
            # a string-literal argument is blanked to spaces in the
            # scrubbed text, so skip_ws runs past it: the literal (if
            # any) is the first one recorded in (j, k]
            k = skip_ws(scrubbed, j + 1)
            lit = None
            for off in range(j + 1, k + 1):
                if off in literals:
                    lit = literals[off]
                    break
            ok = lit is not None and lit.startswith("invariant:")
            if not ok and not in_spans(spans, i):
                sites.append((i, "expect"))
        pos = i + 1
    for mac in PANIC_MACROS:
        pos = 0
        while True:
            i = scrubbed.find(mac, pos)
            if i == -1:
                break
            before = scrubbed[i - 1:i]
            if before not in IDENT and not in_spans(spans, i):
                sites.append((i, mac))
            pos = i + 1
    return sorted(sites)


def scoped(rel):
    return (rel.startswith("serving/") or rel.startswith("exec/")
            or rel == "methods/pattern_cache.rs"
            or rel == "methods/flash_threshold.rs")


def main():
    counts = {}
    for dirpath, _, files in os.walk(ROOT):
        for f in sorted(files):
            if not f.endswith(".rs"):
                continue
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, ROOT).replace(os.sep, "/")
            if not scoped(rel):
                continue
            with open(path) as fh:
                src = fh.read()
            n = len(panic_sites(src))
            if n:
                counts[rel] = n
    print("# pallas-lint panic-hygiene baseline — frozen counts of")
    print("# unwrap()/expect()/panic-family sites in the serving hot path")
    print("# (serving/, exec/, methods/pattern_cache.rs,")
    print("# methods/flash_threshold.rs; test modules")
    print("# excluded).  This file may only shrink: pallas-lint fails if a")
    print("# file exceeds its count here (new panic site) OR falls below it")
    print("# (stale baseline — regenerate with `pallas-lint --check")
    print("# rust/src --write-baseline` or tools/lint_baseline_gen.py so")
    print("# the burn-down is recorded).  Files absent from this list are")
    print("# at zero.")
    for rel in sorted(counts):
        print(f'"{rel}" = {counts[rel]}')


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--debug":
        for dirpath, _, files in os.walk(ROOT):
            for f in sorted(files):
                if f.endswith(".rs"):
                    path = os.path.join(dirpath, f)
                    rel = os.path.relpath(path, ROOT).replace(os.sep, "/")
                    if scoped(rel):
                        with open(path) as fh:
                            src = fh.read()
                        for off, kind in panic_sites(src):
                            line = src[:off].count("\n") + 1
                            print(f"{rel}:{line}: {kind}")
    else:
        main()
