#!/usr/bin/env python3
"""Intra-repo markdown link checker (zero-dep; CI's blocking `docs` job).

Walks every *.md file in the repository, extracts inline links
`[text](target)` outside fenced code blocks, and verifies each
repo-relative target resolves to an existing file or directory.
External schemes (http/https/mailto), pure `#anchor` links, and image
embeds `![..](..)` (the retrieved-paper dumps quote figure references
from PDF conversion) are skipped; `#fragment` suffixes are stripped
before the existence check.  Exits nonzero listing every broken link.
"""
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_DIRS = {".git", "target", "node_modules"}
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_files():
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def main():
    broken = []
    for path in md_files():
        in_fence = False
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                if line.lstrip().startswith("```"):
                    in_fence = not in_fence
                    continue
                if in_fence:
                    continue
                for m in LINK.finditer(line):
                    if m.start() > 0 and line[m.start() - 1] == "!":
                        continue
                    target = m.group(1)
                    if target.startswith(("http://", "https://",
                                          "mailto:", "#")):
                        continue
                    rel = target.split("#", 1)[0]
                    if not rel:
                        continue
                    base = ROOT if rel.startswith("/") \
                        else os.path.dirname(path)
                    resolved = os.path.normpath(
                        os.path.join(base, rel.lstrip("/")))
                    if not os.path.exists(resolved):
                        broken.append("%s:%d: %s" % (
                            os.path.relpath(path, ROOT), lineno, target))
    if broken:
        print("%d broken intra-repo markdown link(s):" % len(broken))
        for b in broken:
            print("  " + b)
        return 1
    print("markdown links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
