//! Quickstart: build an engine with `EngineBuilder`, prefill one long
//! prompt with SharePrefill chunk by chunk (the resumable path the
//! scheduler interleaves), greedy-decode a few tokens, print the pattern
//! statistics.
//!
//!   make artifacts && cargo run --release --example quickstart

use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::open_registry;
use shareprefill::serving::{EngineBuilder, EngineCore};
use shareprefill::workloads::corpus::detokenize;
use shareprefill::workloads::tasks::{sample, Task};

fn main() -> anyhow::Result<()> {
    let cfg = Config::default(); // paper defaults: τ=0.2, δ=0.3
    let registry = open_registry(&cfg)?;
    let mut engine = EngineBuilder::new(registry, "sim-llama")
        .method_config(cfg.method.clone())
        .method(MethodKind::SharePrefill)
        .build()?;

    // A Retr.KV-style long prompt (key planted early, queried at the end).
    let s = sample(Task::RetrKV, 7, 1024);
    println!("prompt: {} tokens (ends {:?})", s.prompt.len(),
             detokenize(&s.prompt[s.prompt.len() - 24..]));

    // Chunked prefill: one layer per chunk, exactly what the scheduler
    // does between decode steps of other sessions.
    let mut task = engine.begin_prefill(&s.prompt)?;
    loop {
        let done = engine.prefill_chunk(&mut task, 1)?;
        let (ld, lt) = engine.prefill_progress(&task);
        println!("  prefill chunk {ld}/{lt}");
        if done {
            break;
        }
    }
    let pre = engine.finish_prefill(task)?;
    println!("prefill: {:.1} ms | density {:.2} | patterns: {} dense, \
              {} shared, {} vslash",
             pre.stats.latency_us as f64 / 1e3, pre.stats.density(),
             pre.stats.dense, pre.stats.shared, pre.stats.vslash);
    println!("stage breakdown:\n{}", pre.stats.profiler.report());

    let (generated, decode_us) = engine.decode(&pre, s.gen_tokens)?;
    println!("decode {:.1} ms -> {:?} (expected {:?})",
             decode_us as f64 / 1e3, detokenize(&generated), s.answer);
    Ok(())
}
