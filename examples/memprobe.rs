use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::workloads::tasks::latency_prompt;
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}
fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let mut e = build_engine(&registry, &cfg, "sim-llama", MethodKind::Flash)?;
    let p = latency_prompt(512);
    for i in 0..6 {
        let pre = e.prefill(&p)?;
        let _ = e.decode(&pre, 2)?;
        println!("iter {i}: rss {:.0} MB", rss_mb());
    }
    Ok(())
}
