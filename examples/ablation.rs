//! Table 2 regeneration: SharePrefill ablations (w/o sharing τ=0,
//! w/o exclusion δ=1.01, full method) + max-context latency column.
//!
//!   cargo run --release --example ablation [samples] [ctx]

use shareprefill::config::Config;
use shareprefill::eval::{ablation, open_registry};
use shareprefill::workloads::tasks::TASK_NAMES;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let samples: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);
    let ctx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let tasks: Vec<_> = TASK_NAMES.iter().map(|(t, _)| *t).collect();
    let latency_ctx = 2048;
    let rows = ablation::run_ablation(&registry, &cfg, "sim-llama", &tasks,
                                      samples, ctx, latency_ctx)?;
    println!("{}", ablation::render(&rows, ctx, latency_ctx));
    Ok(())
}
