//! Figures 2 & 6 regeneration: per-head block patterns across tasks
//! (2a), the head-similarity Jaccard matrix (2b) and the
//! dense/shared/vslash pattern distribution (6).
//!
//!   cargo run --release --example pattern_explorer [ctx]

use shareprefill::cli_main::collect_head_maps;
use shareprefill::clustering::{jaccard_matrix, pattern_of_map};
use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::util::ascii::mask_map;
use shareprefill::workloads::tasks::{sample, Task, TASK_NAMES};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let ctx: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let model = "sim-llama";
    let spec = registry.model(model)?.clone();

    // Figure 2a: same three heads across two tasks
    println!("## Figure 2a — the same heads across tasks\n");
    let probe_heads = [(1usize, 2usize), (3, 2), (5, 2)];
    for task in [Task::EnDia, Task::CodeDebug] {
        let s = sample(task, 1, ctx);
        let (maps, nb) = collect_head_maps(&registry, model, &s.prompt)?;
        println!("task {}:", task.name());
        for (l, h) in probe_heads {
            let p = pattern_of_map(&maps[l * spec.num_heads + h], nb,
                                   cfg.method.gamma);
            println!("(L{l}, H{h}) density {:.2}", p.density());
            println!("{}", mask_map(&p.to_grid(), nb));
        }
    }

    // Figure 2b: similarity matrix stats per task + cross-task consistency
    println!("## Figure 2b — inter-head Jaccard similarity\n");
    let mut sims = Vec::new();
    for task in [Task::EnDia, Task::CodeDebug, Task::RetrKV] {
        let s = sample(task, 1, ctx);
        let (maps, nb) = collect_head_maps(&registry, model, &s.prompt)?;
        let pats: Vec<_> = maps.iter()
            .map(|m| pattern_of_map(m, nb, cfg.method.gamma)).collect();
        let m = jaccard_matrix(&pats);
        let n = pats.len();
        let off: Vec<f64> = (0..n).flat_map(|i| (0..n)
            .filter(move |&j| j != i).map(move |j| (i, j)))
            .map(|(i, j)| m[i * n + j]).collect();
        let above = off.iter().filter(|&&x| x > 0.5).count() as f64
            / off.len() as f64;
        println!("task {:12} pairs with similarity > 0.5: {:.2}",
                 task.name(), above);
        sims.push(m);
    }
    // cross-input consistency: correlation of similarity matrices
    let (a, b) = (&sims[0], &sims[1]);
    let n = a.len() as f64;
    let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let (va, vb) = (a.iter().map(|x| (x - ma).powi(2)).sum::<f64>(),
                    b.iter().map(|y| (y - mb).powi(2)).sum::<f64>());
    println!("\ncross-task similarity-matrix correlation (En.Dia vs \
              Code.Debug): {:.3}", cov / (va.sqrt() * vb.sqrt()));

    // Figure 6: pattern distribution
    println!("\n## Figure 6 — pattern distribution (SharePrefill)\n");
    println!("| task | dense | shared | vslash |");
    println!("|---|---:|---:|---:|");
    for (t, name) in TASK_NAMES {
        let mut e = build_engine(&registry, &cfg, model,
                                 MethodKind::SharePrefill)?;
        let sm = sample(t, 3, ctx);
        let pre = e.prefill(&sm.prompt)?;
        println!("| {} | {} | {} | {} |", name, pre.stats.dense,
                 pre.stats.shared, pre.stats.vslash);
    }
    Ok(())
}
