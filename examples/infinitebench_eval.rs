//! Table 1 regeneration: InfiniteBench-sim scores for all four methods.
//!
//!   cargo run --release --example infinitebench_eval [samples] [ctx]

use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{infinitebench, open_registry};
use shareprefill::workloads::tasks::TASK_NAMES;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let samples: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let ctx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let tasks: Vec<_> = TASK_NAMES.iter().map(|(t, _)| *t).collect();
    for model in ["sim-llama", "sim-qwen"] {
        let t1 = infinitebench::run_table1(
            &registry, &cfg, model, &MethodKind::all(), &tasks, samples,
            ctx)?;
        println!("{}\n", t1.render());
    }
    Ok(())
}
