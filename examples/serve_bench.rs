//! End-to-end serving driver (the DESIGN.md E2E validation): a batched
//! request stream through router -> batcher -> KV admission -> prefill ->
//! decode, reporting latency/throughput per method.  Results are recorded
//! in EXPERIMENTS.md.
//!
//!   cargo run --release --example serve_bench [requests] [ctx]

use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::serving::request::Request;
use shareprefill::serving::scheduler::Scheduler;
use shareprefill::serving::server;
use shareprefill::workloads::tasks::latency_prompt;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let ctx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);

    for kind in [MethodKind::Flash, MethodKind::SharePrefill] {
        let cfg = Config::default();
        let handle = server::spawn(move || {
            let registry = open_registry(&cfg)?;
            let engine = build_engine(&registry, &cfg, "sim-llama", kind)?;
            Ok((Scheduler::new(&cfg.serve), engine))
        });
        let t0 = std::time::Instant::now();
        for i in 0..n {
            handle.submit(Request::new(i as u64, latency_prompt(ctx), 4));
        }
        let (responses, report) = handle.shutdown_and_report();
        let wall = t0.elapsed().as_secs_f64();
        println!("== {} ==", kind.name());
        println!("{report}");
        println!("wall {:.1}s for {} requests -> {:.0} prompt tok/s e2e\n",
                 wall, responses.len(), (n * ctx) as f64 / wall);
    }
    Ok(())
}
