//! End-to-end serving driver (the DESIGN.md E2E validation): a batched
//! request stream through the streaming session API — admission -> KV ->
//! chunked prefill (interleaved with decode and with *other prefills*
//! via continuous batching) -> per-token events — reporting per-request
//! TTFT and throughput per method.  Results are recorded in
//! EXPERIMENTS.md; CI's `bench-smoke` job runs the deterministic
//! SimEngine scenarios and archives the machine-readable trajectory.
//!
//! Seven scenarios:
//!
//! 1. **Per-method uniform stream** (needs `make artifacts`): the real
//!    engine under concurrent equal-length prompts.  Skipped with
//!    `--sim-only`.
//! 2. **Mixed-length fairness** (artifact-free, `SimEngine` with
//!    simulated per-token compute): one very long prompt plus a stream
//!    of short prompts, run at `max_concurrent_prefills` 1 vs 4 — the
//!    per-class TTFT p50/p95 shows what interleaved multi-prefill buys
//!    short prompts stuck behind a long one.
//! 3. **Repeated workload, cross-request pattern cache** (artifact-free):
//!    the same-length prompt stream served with the cache off vs on —
//!    warm requests skip the pivotal bootstrap, so per-request prefill
//!    cost drops after the first (cold) request and the metrics report
//!    shows the cache hit rate.
//! 4. **Worker scaling** (artifact-free): the same prompt stream at
//!    `serve.workers` 1 / 2 / 4 — simulated prefill time must strictly
//!    decrease (asserted; CI fails on a scaling regression) while the
//!    outputs stay identical.
//! 5. **Fleet scaling** (artifact-free): a mixed-length workload at
//!    `serve.shards` 1 / 2 / 4 — aggregate simulated prefill
//!    throughput (total tokens over the busiest shard's modeled
//!    makespan) must strictly increase with the shard count (asserted;
//!    CI fails on a scaling regression).
//! 6. **Open-loop overload** (artifact-free, fully virtual-time):
//!    Poisson and bursty arrival traces with mixed prompt-length
//!    classes (70% short interactive / 25% medium / 5% long) driven
//!    through `Scheduler` + `SimEngine` on a deterministic virtual
//!    clock — arrivals do not wait for service, so offered load can
//!    exceed capacity.  Closed-loop capacity is calibrated first, then
//!    the overload traces run at 2× that rate with the
//!    `serve.admission.*` knobs on.  Asserted (here and re-asserted by
//!    CI from the JSON): goodput stays ≥ 70% of closed-loop capacity,
//!    admitted interactive p99 TTFT stays bounded, sheds are fast and
//!    structured, and completed + rejected == submitted.
//!
//! 7. **Prompt template, prefix-sharing KV cache** (artifact-free):
//!    every request opens with the same long system-prompt template
//!    and ends in a short unique tail, served with
//!    `serve.prefix_cache` off vs on — warm requests adopt the
//!    template's cached KV blocks and prefill only the tail, so warm
//!    TTFT collapses versus cold (asserted, with nonzero block reuse;
//!    CI fails if warm prefill is not strictly below cold).
//!
//!   cargo run --release --example serve_bench -- \
//!       [requests] [ctx] [--sim-only] [--json BENCH_10.json]
//!
//! `--json` writes one row per SimEngine scenario (name, tokens/s,
//! TTFT p50/p95, mean prefill ms, cache hit rate) for the CI artifact.

use std::collections::{HashMap, HashSet};

use shareprefill::config::{MethodKind, ServeConfig};
use shareprefill::serving::fleet::spawn_fleet;
use shareprefill::serving::scheduler::Scheduler;
use shareprefill::serving::sim::SimEngine;
use shareprefill::serving::{server, Event, EventSink, Request, ServerBuilder};
use shareprefill::util::rng::Rng;
use shareprefill::util::stats::Summary;
use shareprefill::workloads::tasks::latency_prompt;

/// One machine-readable result row (the `--json` schema).  `extras`
/// holds scenario-specific numeric fields (the open-loop rows carry
/// `goodput_ratio` / `ttft_p99_ms` / `reject_p99_ms` / `requests_shed`
/// on top of the common schema; the CI validator checks the common
/// keys and the overload SLOs, and tolerates the extras elsewhere).
struct ScenarioRow {
    name: String,
    tokens_per_s: f64,
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    prefill_ms_mean: f64,
    cache_hit_rate: f64,
    extras: Vec<(&'static str, f64)>,
}

/// Outcome of one drained session, pulled off its event stream.
struct SessionOutcome {
    ttft_ms: f64,
    prefill_ms: f64,
    cache_hits: usize,
    cache_misses: usize,
    cache_rejected: usize,
    prefix_blocks_reused: usize,
    prefix_tokens_skipped: usize,
}

/// Drain a session's events into the numbers the scenarios report
/// (`None` if it ended in anything but `Done`).
fn drain_session(s: shareprefill::serving::SessionHandle)
                 -> Option<SessionOutcome> {
    let id = s.id;
    let mut out = SessionOutcome {
        ttft_ms: 0.0,
        prefill_ms: 0.0,
        cache_hits: 0,
        cache_misses: 0,
        cache_rejected: 0,
        prefix_blocks_reused: 0,
        prefix_tokens_skipped: 0,
    };
    let mut done = false;
    for e in s.collect() {
        match e {
            Event::PrefillDone { stats, .. } => {
                out.cache_hits += stats.cache_hits;
                out.cache_misses += stats.cache_misses;
                out.cache_rejected += stats.cache_rejected;
                out.prefix_blocks_reused += stats.prefix_blocks_reused;
                out.prefix_tokens_skipped += stats.prefix_tokens_skipped;
            }
            Event::Done { response, .. } => {
                out.ttft_ms = response.ttft_us as f64 / 1e3;
                out.prefill_ms = response.prefill_us as f64 / 1e3;
                done = true;
            }
            Event::Rejected { reason, .. } => {
                println!("req {id:3}: rejected ({})", reason.kind());
            }
            Event::Error { message, .. } => {
                println!("req {id:3}: {message}");
            }
            _ => {}
        }
    }
    done.then_some(out)
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Mixed-length fairness: 1 × `LONG_TOKENS` prompt submitted first, then
/// `SHORTS` × `SHORT_TOKENS` prompts.  Coordinator-only (SimEngine), so
/// it runs without artifacts; simulated compute makes TTFT ordering
/// effects real wall-clock time.
fn mixed_length_scenario(max_prefills: usize) -> ScenarioRow {
    const LONG_TOKENS: usize = 8192;
    const SHORT_TOKENS: usize = 128;
    const SHORTS: usize = 16;
    const LAYERS: usize = 8;
    const NS_PER_TOKEN_LAYER: u64 = 200;

    let cfg = ServeConfig {
        max_batch_tokens: 512,
        chunk_layers: 1,
        decode_tokens: 4,
        kv_blocks: 4096,
        max_concurrent_prefills: max_prefills,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let handle = server::spawn(move || {
        Ok((Scheduler::new(&cfg),
            SimEngine::new(LAYERS).with_work(NS_PER_TOKEN_LAYER)))
    });
    let long = handle.submit(vec![7; LONG_TOKENS], 4);
    let shorts: Vec<_> = (0..SHORTS)
        .map(|_| handle.submit(vec![7; SHORT_TOKENS], 4))
        .collect();

    let mut short_ttft = Summary::new();
    let mut short_prefill = Vec::new();
    for s in shorts {
        if let Some(o) = drain_session(s) {
            short_ttft.add(o.ttft_ms);
            short_prefill.push(o.prefill_ms);
        }
    }
    let long_ttft = drain_session(long)
        .map_or(f64::NAN, |o| o.ttft_ms);
    let report = handle.shutdown();
    let wall = t0.elapsed().as_secs_f64();
    println!("== mixed-length fairness, max_concurrent_prefills = \
              {max_prefills} ==");
    println!("short ({SHORT_TOKENS} tok x{SHORTS}): ttft p50 {:8.2} ms, \
              p95 {:8.2} ms",
             short_ttft.p50(), short_ttft.percentile(95.0));
    println!("long  ({LONG_TOKENS} tok x1):  ttft     {long_ttft:8.2} ms");
    println!("{report}\n");
    ScenarioRow {
        name: format!("mixed_length_c{max_prefills}"),
        tokens_per_s: (LONG_TOKENS + SHORTS * SHORT_TOKENS) as f64 / wall,
        ttft_p50_ms: short_ttft.p50(),
        ttft_p95_ms: short_ttft.percentile(95.0),
        prefill_ms_mean: mean(&short_prefill),
        cache_hit_rate: 0.0,
        extras: Vec::new(),
    }
}

/// Repeated-workload cache scenario: one prompt length served
/// `REPEATS` times, cache off vs on (SimEngine, simulated compute,
/// serial prefills so every repeat after the first runs warm).
fn pattern_cache_scenario() -> Vec<ScenarioRow> {
    const TOKENS: usize = 2048;
    const REPEATS: usize = 8;
    const LAYERS: usize = 8;
    const NS_PER_TOKEN_LAYER: u64 = 200;

    let run = |cache_on: bool| {
        let cfg = ServeConfig {
            max_batch_tokens: 4096,
            chunk_layers: 1,
            decode_tokens: 2,
            kv_blocks: 4096,
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let handle = server::spawn(move || {
            let engine = SimEngine::new(LAYERS)
                .with_work(NS_PER_TOKEN_LAYER);
            let engine = if cache_on {
                engine.with_pattern_cache()
            } else {
                engine
            };
            Ok((Scheduler::new(&cfg), engine))
        });
        let mut outcomes = Vec::new();
        for _ in 0..REPEATS {
            // serial submits: each waits, so repeats always run warm
            if let Some(o) =
                drain_session(handle.submit(vec![7; TOKENS], 2))
            {
                outcomes.push(o);
            }
        }
        let report = handle.shutdown();
        (outcomes, report, t0.elapsed().as_secs_f64())
    };

    println!("== cross-request pattern cache, repeated workload \
              ({TOKENS} tok x{REPEATS}) ==");
    let (off, _, wall_off) = run(false);
    let (on, report, wall_on) = run(true);
    let prefill_off: Vec<f64> = off.iter().map(|o| o.prefill_ms).collect();
    let prefill_on: Vec<f64> = on.iter().map(|o| o.prefill_ms).collect();
    println!("cache off: prefill mean {:8.2} ms", mean(&prefill_off));
    if prefill_on.len() > 1 {
        let (cold, warm) = (prefill_on[0], mean(&prefill_on[1..]));
        println!("cache on:  cold {cold:8.2} ms, warm mean {warm:8.2} ms \
                  ({:.2}x faster warm)", cold / warm);
    }
    println!("{report}\n");
    let row = |name: &str, outcomes: &[SessionOutcome], wall: f64| {
        let mut ttft = Summary::new();
        let (mut hits, mut total) = (0usize, 0usize);
        for o in outcomes {
            ttft.add(o.ttft_ms);
            hits += o.cache_hits;
            total += o.cache_hits + o.cache_misses + o.cache_rejected;
        }
        ScenarioRow {
            name: name.to_string(),
            tokens_per_s: (outcomes.len() * TOKENS) as f64 / wall,
            ttft_p50_ms: ttft.p50(),
            ttft_p95_ms: ttft.percentile(95.0),
            prefill_ms_mean: mean(&outcomes.iter()
                .map(|o| o.prefill_ms)
                .collect::<Vec<_>>()),
            cache_hit_rate: if total == 0 {
                0.0
            } else {
                hits as f64 / total as f64
            },
            extras: Vec::new(),
        }
    };
    vec![row("pattern_cache_off", &off, wall_off),
         row("pattern_cache_on", &on, wall_on)]
}

/// Prompt-template prefix sharing: every request opens with the same
/// `TEMPLATE_TOKENS`-token system prompt and ends in a short unique
/// tail, served with `serve.prefix_cache` off vs on (SimEngine with
/// simulated compute, serial submits so each request after the first
/// finds the template's blocks cached).  Asserts the PR's headline:
/// warm prefill strictly below cold, with nonzero block reuse.
fn prefix_cache_scenario() -> Vec<ScenarioRow> {
    const TEMPLATE_TOKENS: usize = 2048;
    const TAIL_TOKENS: usize = 128;
    const REPEATS: usize = 8;
    const LAYERS: usize = 8;
    const NS_PER_TOKEN_LAYER: u64 = 1_000;

    let prompt = |i: usize| -> Vec<i32> {
        let mut p = vec![7i32; TEMPLATE_TOKENS];
        p.resize(TEMPLATE_TOKENS + TAIL_TOKENS, 100 + i as i32);
        p
    };
    let run = |prefix_on: bool| {
        let cfg = ServeConfig {
            max_batch_tokens: 4096,
            chunk_layers: 1,
            decode_tokens: 2,
            kv_blocks: 4096,
            max_concurrent_prefills: 1,
            prefix_cache: shareprefill::config::PrefixCacheConfig {
                enabled: prefix_on,
                ..Default::default()
            },
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let handle = server::spawn(move || {
            Ok((Scheduler::new(&cfg),
                SimEngine::new(LAYERS).with_work(NS_PER_TOKEN_LAYER)))
        });
        let mut outcomes = Vec::new();
        for i in 0..REPEATS {
            // serial submits: each waits, so repeats always run warm
            if let Some(o) = drain_session(handle.submit(prompt(i), 2)) {
                outcomes.push(o);
            }
        }
        let report = handle.shutdown();
        (outcomes, report, t0.elapsed().as_secs_f64())
    };

    println!("== prefix-sharing KV cache, prompt template \
              ({TEMPLATE_TOKENS} tok template + {TAIL_TOKENS} tok tail \
              x{REPEATS}) ==");
    let (off, _, wall_off) = run(false);
    let (on, report, wall_on) = run(true);
    let cold_mean = mean(&off.iter()
        .map(|o| o.prefill_ms)
        .collect::<Vec<_>>());
    let warm: Vec<f64> =
        on.iter().skip(1).map(|o| o.prefill_ms).collect();
    let warm_mean = mean(&warm);
    let reused: usize =
        on.iter().map(|o| o.prefix_blocks_reused).sum();
    let skipped: usize =
        on.iter().map(|o| o.prefix_tokens_skipped).sum();
    println!("prefix off: prefill mean {cold_mean:8.2} ms");
    println!("prefix on:  warm prefill mean {warm_mean:8.2} ms \
              ({:.2}x faster), {reused} blocks reused, {skipped} \
              prompt tokens skipped", cold_mean / warm_mean);
    println!("{report}\n");
    // the PR's headline, asserted so CI fails on a regression: warm
    // template requests must reuse cached blocks and prefill strictly
    // faster than the cold/off baseline
    assert!(reused > 0,
            "warm template requests must adopt cached KV blocks");
    assert!(warm_mean < cold_mean,
            "warm prefix prefill must be strictly below cold \
             ({warm_mean:.2} ms !< {cold_mean:.2} ms)");
    let row = |name: &str, outcomes: &[SessionOutcome], wall: f64,
               reused: usize, skipped: usize| {
        let mut ttft = Summary::new();
        for o in outcomes {
            ttft.add(o.ttft_ms);
        }
        ScenarioRow {
            name: name.to_string(),
            tokens_per_s: (outcomes.len()
                           * (TEMPLATE_TOKENS + TAIL_TOKENS)) as f64
                / wall,
            ttft_p50_ms: ttft.p50(),
            ttft_p95_ms: ttft.percentile(95.0),
            prefill_ms_mean: mean(&outcomes.iter()
                .map(|o| o.prefill_ms)
                .collect::<Vec<_>>()),
            cache_hit_rate: 0.0,
            extras: vec![
                ("prefix_blocks_reused", reused as f64),
                ("prefix_tokens_skipped", skipped as f64),
            ],
        }
    };
    vec![row("prefix_cache_off", &off, wall_off, 0, 0),
         row("prefix_cache_on", &on, wall_on, reused, skipped)]
}

/// Worker scaling: the identical prompt stream at `serve.workers`
/// 1 / 2 / 4 — mean simulated prefill time must strictly decrease
/// (more hardware, same work), which this function asserts so CI's
/// bench-smoke job fails on a scaling regression.
fn worker_scaling_scenario() -> Vec<ScenarioRow> {
    const TOKENS: usize = 4096;
    const REQUESTS: usize = 4;
    const LAYERS: usize = 8;
    // heavier simulated compute than the other scenarios: the strict
    // w1 > w2 > w4 assert needs mean gaps (~6 ms+) that shared-runner
    // scheduling noise cannot flip
    const NS_PER_TOKEN_LAYER: u64 = 1_000;

    println!("== worker scaling ({TOKENS} tok x{REQUESTS}, workers \
              1/2/4) ==");
    let mut rows = Vec::new();
    let mut prev_mean = f64::INFINITY;
    for workers in [1usize, 2, 4] {
        let cfg = ServeConfig {
            max_batch_tokens: 4096,
            chunk_layers: 1,
            decode_tokens: 2,
            kv_blocks: 4096,
            max_concurrent_prefills: 2,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let handle = server::spawn(move || {
            Ok((Scheduler::new(&cfg),
                SimEngine::new(LAYERS)
                    .with_work(NS_PER_TOKEN_LAYER)
                    .with_workers(workers)))
        });
        let sessions: Vec<_> = (0..REQUESTS)
            .map(|_| handle.submit(vec![7; TOKENS], 2))
            .collect();
        let mut ttft = Summary::new();
        let mut prefill = Vec::new();
        for s in sessions {
            if let Some(o) = drain_session(s) {
                ttft.add(o.ttft_ms);
                prefill.push(o.prefill_ms);
            }
        }
        let _ = handle.shutdown();
        let wall = t0.elapsed().as_secs_f64();
        let prefill_mean = mean(&prefill);
        println!("workers {workers}: prefill mean {prefill_mean:8.2} ms, \
                  ttft p50 {:8.2} ms", ttft.p50());
        assert!(prefill_mean < prev_mean,
                "prefill time must strictly decrease with more workers \
                 (workers {workers}: {prefill_mean:.2} ms !< \
                 {prev_mean:.2} ms)");
        prev_mean = prefill_mean;
        rows.push(ScenarioRow {
            name: format!("worker_scaling_w{workers}"),
            tokens_per_s: (REQUESTS * TOKENS) as f64 / wall,
            ttft_p50_ms: ttft.p50(),
            ttft_p95_ms: ttft.percentile(95.0),
            prefill_ms_mean: prefill_mean,
            cache_hit_rate: 0.0,
            extras: Vec::new(),
        });
    }
    println!();
    rows
}

/// Fleet scaling: a mixed-length workload (long + short prompts) at
/// `serve.shards` 1 / 2 / 4.  Throughput is computed from the *modeled*
/// per-request prefill cost (tokens × layers × ns/token/layer — the
/// exact work `SimEngine` simulates) over the busiest shard's makespan,
/// so the scaling assertion is deterministic on oversubscribed CI
/// runners where four spinning shards contend for two cores; TTFT
/// percentiles are real measured wall-clock.  Aggregate throughput
/// must strictly increase 1 → 2 → 4 (asserted; CI re-asserts from the
/// JSON).
fn fleet_scaling_scenario() -> Vec<ScenarioRow> {
    const LONG_TOKENS: usize = 2048;
    const SHORT_TOKENS: usize = 256;
    const EACH: usize = 8;
    const LAYERS: usize = 8;
    const NS_PER_TOKEN_LAYER: u64 = 2_000;
    const TOTAL_TOKENS: usize = EACH * (LONG_TOKENS + SHORT_TOKENS);

    println!("== fleet scaling ({EACH} x {LONG_TOKENS} tok + {EACH} x \
              {SHORT_TOKENS} tok, shards 1/2/4) ==");
    let mut rows = Vec::new();
    let mut prev_tput = 0.0f64;
    for shards in [1usize, 2, 4] {
        let cfg = ServeConfig {
            max_batch_tokens: 4096,
            chunk_layers: 1,
            decode_tokens: 2,
            kv_blocks: 4096,
            max_concurrent_prefills: 2,
            shards,
            ..Default::default()
        };
        let mut fleet = spawn_fleet(shards, {
            let cfg = cfg.clone();
            move |_| Ok((Scheduler::new(&cfg),
                         SimEngine::new(LAYERS)
                             .with_work(NS_PER_TOKEN_LAYER)))
        });
        // interleave long and short prompts so the router sees the
        // mixed-length stream the placement score is built for
        let lens: Vec<usize> = (0..EACH)
            .flat_map(|_| [LONG_TOKENS, SHORT_TOKENS])
            .collect();
        let sessions: Vec<_> = lens.iter()
            .map(|&l| fleet.submit(vec![7; l], 2))
            .collect();
        // modeled per-shard makespan from the router's actual placement
        let mut shard_ns = vec![0u64; shards];
        for (s, &len) in sessions.iter().zip(&lens) {
            let shard = fleet.assignment_of(s.id).unwrap_or(0);
            shard_ns[shard] +=
                (len * LAYERS) as u64 * NS_PER_TOKEN_LAYER;
        }
        let makespan_s = shard_ns.iter().copied().max().unwrap_or(1)
            as f64 / 1e9;
        let mut ttft = Summary::new();
        let mut prefill = Vec::new();
        for s in sessions {
            if let Some(o) = drain_session(s) {
                ttft.add(o.ttft_ms);
                prefill.push(o.prefill_ms);
            }
        }
        let _ = fleet.shutdown();
        let tput = TOTAL_TOKENS as f64 / makespan_s;
        println!("shards {shards}: modeled makespan {:8.2} ms -> \
                  {tput:10.0} tok/s, ttft p50 {:8.2} ms",
                 makespan_s * 1e3, ttft.p50());
        assert!(tput > prev_tput,
                "aggregate prefill throughput must strictly increase \
                 with the shard count (shards {shards}: {tput:.0} !> \
                 {prev_tput:.0} tok/s)");
        prev_tput = tput;
        rows.push(ScenarioRow {
            name: format!("fleet_shards_s{shards}"),
            tokens_per_s: tput,
            ttft_p50_ms: ttft.p50(),
            ttft_p95_ms: ttft.percentile(95.0),
            prefill_ms_mean: mean(&prefill),
            cache_hit_rate: 0.0,
            extras: Vec::new(),
        });
    }
    println!();
    rows
}

// ---------------------------------------------------------------------
// Open-loop overload: trace-driven arrivals on a virtual clock.
// ---------------------------------------------------------------------

/// Virtual cost model for the open-loop rows: the SimEngine runs with
/// `with_work(0)` (no wall-clock spin), and the driver advances a
/// virtual clock by `ROUND_OVERHEAD_NS` plus `NS_PER_TOKEN` per budget
/// token the round actually spent — so every number below is exactly
/// reproducible on any machine.
const OL_LAYERS: usize = 8;
const OL_NS_PER_TOKEN: u64 = 2_000;
const OL_ROUND_OVERHEAD_NS: u64 = 20_000;
const OL_MAX_NEW: usize = 4;
/// Interactive class boundary (also `serve.admission.interactive_max_tokens`).
const OL_INTERACTIVE_MAX: usize = 128;
/// Overload SLOs, asserted here and re-asserted by CI from the JSON.
const OL_GOODPUT_FLOOR: f64 = 0.70;
const OL_TTFT_P99_SLO_MS: f64 = 250.0;
const OL_REJECT_P99_SLO_MS: f64 = 500.0;

/// One arrival in a generated open-loop trace.
struct Arrival {
    at_ns: u64,
    prompt: usize,
}

/// Mixed prompt-length classes: 70% short interactive, 25% medium,
/// 5% long.
fn sample_class(rng: &mut Rng) -> usize {
    match rng.below(100) {
        0..=69 => 64,
        70..=94 => 512,
        _ => 2048,
    }
}

/// Poisson arrivals over pre-sampled prompt lengths: exponential
/// inter-arrival gaps around `mean_gap_ns`.
fn poisson_trace(rng: &mut Rng, prompts: &[usize], mean_gap_ns: f64)
                 -> Vec<Arrival> {
    let mut t = 0.0f64;
    prompts.iter()
        .map(|&prompt| {
            t += -mean_gap_ns * (1.0 - rng.f64()).ln();
            Arrival { at_ns: t as u64, prompt }
        })
        .collect()
}

/// Bursty arrivals: volleys of 8–16 simultaneous requests, with the
/// volley gap sized so the *average* rate matches `mean_gap_ns` per
/// request — same offered load as the Poisson trace, spikier shape.
fn burst_trace(rng: &mut Rng, prompts: &[usize], mean_gap_ns: f64)
               -> Vec<Arrival> {
    let mut t = 0u64;
    let mut out = Vec::with_capacity(prompts.len());
    while out.len() < prompts.len() {
        let volley = (8 + rng.below(9)).min(prompts.len() - out.len());
        for _ in 0..volley {
            out.push(Arrival { at_ns: t, prompt: prompts[out.len()] });
        }
        t += (volley as f64 * mean_gap_ns) as u64;
    }
    out
}

/// Serving config the open-loop rows run under; `admission` switches
/// the `serve.admission.*` ladder on (the calibration run keeps every
/// knob at its inert default).
fn open_loop_cfg(admission: bool) -> ServeConfig {
    let mut cfg = ServeConfig {
        max_batch_tokens: 1024,
        max_batch_requests: 8,
        queue_capacity: 256,
        decode_tokens: OL_MAX_NEW,
        kv_blocks: 4096,
        chunk_layers: 1,
        max_concurrent_prefills: 2,
        ..Default::default()
    };
    if admission {
        cfg.admission.enabled = true;
        cfg.admission.max_queue_depth = 24;
        cfg.admission.kv_overcommit = 1.5;
        cfg.admission.max_queue_rounds = 64;
        cfg.admission.interactive_max_tokens = OL_INTERACTIVE_MAX;
        cfg.admission.degrade_queue_depth = 12;
        cfg.admission.degraded_budget_pct = 75;
        cfg.admission.degraded_max_prefills = 1;
    }
    cfg
}

/// Everything one open-loop run reports, all in virtual time.
struct OpenLoopOutcome {
    submitted: usize,
    completed: usize,
    rejected: usize,
    completed_prompt_tokens: usize,
    makespan_s: f64,
    ttft_ms: Vec<f64>,
    interactive_ttft_ms: Vec<f64>,
    prefill_ms: Vec<f64>,
    reject_ms: Vec<f64>,
}

/// Sorted-percentile over raw samples (0 when empty) — the open-loop
/// rows use exact percentiles rather than `Summary`'s histogram bins
/// so the deterministic virtual-time numbers stay exact.
fn pctl(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[rank.min(s.len() - 1)]
}

/// Drive one trace through `Scheduler` + `SimEngine` on the virtual
/// clock: submit every arrival whose timestamp has passed, run one
/// scheduling round, advance the clock by the round's modeled cost,
/// drain the event stream with the new timestamp, repeat until the
/// trace is exhausted and the scheduler drains.
fn drive_open_loop(cfg: &ServeConfig, trace: &[Arrival]) -> OpenLoopOutcome {
    let mut engine = SimEngine::new(OL_LAYERS).with_work(0);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(cfg);
    let (sink, rx) = EventSink::channel();

    let mut out = OpenLoopOutcome {
        submitted: trace.len(),
        completed: 0,
        rejected: 0,
        completed_prompt_tokens: 0,
        makespan_s: 0.0,
        ttft_ms: Vec::new(),
        interactive_ttft_ms: Vec::new(),
        prefill_ms: Vec::new(),
        reject_ms: Vec::new(),
    };
    let mut arrived_at: HashMap<u64, u64> = HashMap::new();
    let mut prompt_of: HashMap<u64, usize> = HashMap::new();
    let mut seen_ttft: HashSet<u64> = HashSet::new();
    let mut last_terminal_ns = 0u64;
    let interactive_max = cfg.admission.interactive_max_tokens;

    let mut vclock = 0u64;
    let mut next = 0usize;
    let mut rounds = 0usize;
    loop {
        while next < trace.len() && trace[next].at_ns <= vclock {
            let id = next as u64;
            arrived_at.insert(id, trace[next].at_ns);
            prompt_of.insert(id, trace[next].prompt);
            sched.submit(&engine,
                         Request::new(id, vec![7; trace[next].prompt],
                                      OL_MAX_NEW),
                         sink.clone());
            next += 1;
        }
        // submit-time sheds surface immediately, at the current clock
        drain_virtual(&rx, vclock, &arrived_at, &prompt_of,
                      interactive_max, &mut seen_ttft, &mut out,
                      &mut last_terminal_ns);
        if !sched.has_work() {
            match trace.get(next) {
                // idle gap: jump straight to the next arrival
                Some(a) => {
                    vclock = vclock.max(a.at_ns);
                    continue;
                }
                None => break,
            }
        }
        let before = sched.metrics.decode_budget_tokens
            + sched.metrics.prefill_budget_tokens;
        sched.run_round(&mut engine)
            .expect("SimEngine rounds cannot fail");
        let spent = sched.metrics.decode_budget_tokens
            + sched.metrics.prefill_budget_tokens - before;
        vclock += OL_ROUND_OVERHEAD_NS + spent * OL_NS_PER_TOKEN;
        drain_virtual(&rx, vclock, &arrived_at, &prompt_of,
                      interactive_max, &mut seen_ttft, &mut out,
                      &mut last_terminal_ns);
        rounds += 1;
        assert!(rounds < 1_000_000, "open-loop driver failed to drain");
    }
    out.makespan_s = last_terminal_ns.max(1) as f64 / 1e9;
    out
}

/// Drain every event currently on the stream, timestamping it `now_ns`
/// on the virtual clock (event latency = now − the trace arrival time).
#[allow(clippy::too_many_arguments)]
fn drain_virtual(rx: &std::sync::mpsc::Receiver<Event>, now_ns: u64,
                 arrived_at: &HashMap<u64, u64>,
                 prompt_of: &HashMap<u64, usize>, interactive_max: usize,
                 seen_ttft: &mut HashSet<u64>, out: &mut OpenLoopOutcome,
                 last_terminal_ns: &mut u64) {
    while let Ok(ev) = rx.try_recv() {
        let id = ev.id();
        let t0 = arrived_at.get(&id).copied().unwrap_or(now_ns);
        let ms = now_ns.saturating_sub(t0) as f64 / 1e6;
        let record_ttft = |out: &mut OpenLoopOutcome,
                           seen: &mut HashSet<u64>| {
            if seen.insert(id) {
                out.ttft_ms.push(ms);
                let len = prompt_of.get(&id).copied().unwrap_or(usize::MAX);
                if interactive_max > 0 && len <= interactive_max {
                    out.interactive_ttft_ms.push(ms);
                }
            }
        };
        match ev {
            Event::Token { .. } => record_ttft(out, seen_ttft),
            Event::PrefillDone { .. } => out.prefill_ms.push(ms),
            Event::Done { .. } => {
                record_ttft(out, seen_ttft);
                out.completed += 1;
                out.completed_prompt_tokens +=
                    prompt_of.get(&id).copied().unwrap_or(0);
                *last_terminal_ns = now_ns;
            }
            Event::Rejected { .. } => {
                out.rejected += 1;
                out.reject_ms.push(ms);
                *last_terminal_ns = now_ns;
            }
            Event::Cancelled { .. } | Event::Error { .. } => {
                *last_terminal_ns = now_ns;
            }
            Event::PrefillProgress { .. } => {}
        }
    }
}

/// The open-loop scenario set: calibrate closed-loop capacity, then a
/// sustained Poisson trace at 0.9× and Poisson + bursty overload
/// traces at 2×, with the admission ladder on.  The per-trace arrival
/// gap is derived from the *sampled* prompt lengths so the offered
/// token rate is exactly `mult ×` the calibrated capacity.
/// Deterministic end to end (fixed seed, virtual clock).
fn open_loop_scenario() -> Vec<ScenarioRow> {
    const N_REQ: usize = 256;
    const CALIB_REQ: usize = 64;
    let mut rng = Rng::new(0x09_0AD5);

    // closed-loop capacity: everything queued up front, no admission
    let closed: Vec<Arrival> = (0..CALIB_REQ)
        .map(|_| Arrival { at_ns: 0, prompt: sample_class(&mut rng) })
        .collect();
    let cal = drive_open_loop(&open_loop_cfg(false), &closed);
    assert_eq!(cal.completed, CALIB_REQ,
               "closed-loop calibration must complete every request");
    let capacity = cal.completed_prompt_tokens as f64 / cal.makespan_s;
    println!("== open-loop overload (virtual time) ==");
    println!("closed-loop capacity: {capacity:10.0} tok/s \
              ({CALIB_REQ} requests, makespan {:.2} ms)",
             cal.makespan_s * 1e3);

    let cases: [(&str, bool, f64); 3] = [
        ("open_loop_sustained", false, 0.9),
        ("open_loop_overload_poisson", false, 2.0),
        ("open_loop_overload_burst", true, 2.0),
    ];
    let mut rows = Vec::new();
    for (name, bursty, mult) in cases {
        let prompts: Vec<usize> =
            (0..N_REQ).map(|_| sample_class(&mut rng)).collect();
        let offered: usize = prompts.iter().sum();
        // mean gap that makes this trace's offered token rate exactly
        // `mult ×` the calibrated closed-loop capacity
        let gap = offered as f64 / N_REQ as f64 / (capacity * mult) * 1e9;
        let trace = if bursty {
            burst_trace(&mut rng, &prompts, gap)
        } else {
            poisson_trace(&mut rng, &prompts, gap)
        };
        let o = drive_open_loop(&open_loop_cfg(true), &trace);
        assert_eq!(o.completed + o.rejected, o.submitted,
                   "{name}: terminal accounting must reconcile");
        let goodput = o.completed_prompt_tokens as f64 / o.makespan_s;
        let ratio = goodput / capacity;
        let ttft_p99 = pctl(&o.interactive_ttft_ms, 99.0);
        let reject_p99 = pctl(&o.reject_ms, 99.0);
        println!("{name}: {:3} done / {:3} shed of {:3}, goodput \
                  {goodput:10.0} tok/s ({:.2}x closed-loop), interactive \
                  ttft p99 {ttft_p99:7.2} ms, reject p99 \
                  {reject_p99:7.2} ms",
                 o.completed, o.rejected, o.submitted, ratio);
        // the overload SLOs (CI re-asserts these from the JSON)
        assert!(ratio >= OL_GOODPUT_FLOOR,
                "{name}: goodput {ratio:.2}x below the \
                 {OL_GOODPUT_FLOOR:.2}x closed-loop floor");
        assert!(ttft_p99 <= OL_TTFT_P99_SLO_MS,
                "{name}: admitted interactive ttft p99 {ttft_p99:.2} ms \
                 over the {OL_TTFT_P99_SLO_MS} ms SLO");
        assert!(reject_p99 <= OL_REJECT_P99_SLO_MS,
                "{name}: shed latency p99 {reject_p99:.2} ms over the \
                 {OL_REJECT_P99_SLO_MS} ms bound — rejects must be fast");
        if mult >= 2.0 {
            assert!(o.rejected > 0,
                    "{name}: 2x overload must shed load");
        }
        rows.push(ScenarioRow {
            name: name.to_string(),
            tokens_per_s: goodput,
            ttft_p50_ms: pctl(&o.ttft_ms, 50.0),
            ttft_p95_ms: pctl(&o.ttft_ms, 95.0),
            prefill_ms_mean: mean(&o.prefill_ms),
            cache_hit_rate: 0.0,
            extras: vec![
                ("goodput_ratio", ratio),
                ("ttft_p99_ms", ttft_p99),
                ("reject_p99_ms", reject_p99),
                ("requests_shed", o.rejected as f64),
            ],
        });
    }
    println!();
    rows
}

/// Per-method uniform stream over the real artifact-backed engine.
fn real_engine_scenario(n: usize, ctx: usize) {
    for kind in [MethodKind::Flash, MethodKind::SharePrefill] {
        let handle = ServerBuilder::new().method(kind).spawn();
        let t0 = std::time::Instant::now();
        // submit the whole stream up front: requests overlap, so each
        // response's ttft_us shows what continuous batching buys
        let sessions: Vec<_> =
            (0..n).map(|_| handle.submit(latency_prompt(ctx), 4)).collect();
        let mut ttft = Summary::new();
        let mut ok = 0usize;
        println!("== {} ==", kind.name());
        for s in sessions {
            let id = s.id;
            match s.wait() {
                Ok(r) => {
                    ttft.add(r.ttft_us as f64 / 1e3);
                    ok += 1;
                    println!("req {:3}: ttft {:8.1} ms (queue {:6.1} + \
                              prefill {:7.1}), density {:.2}",
                             r.id, r.ttft_us as f64 / 1e3,
                             r.queue_us as f64 / 1e3,
                             r.prefill_us as f64 / 1e3, r.density);
                }
                Err(e) => println!("req {id:3}: {e:#}"),
            }
        }
        let report = handle.shutdown();
        let wall = t0.elapsed().as_secs_f64();
        println!("{report}");
        println!("ttft per request: mean {:.1} ms, p50 {:.1} ms, p99 \
                  {:.1} ms",
                 ttft.mean(), ttft.p50(), ttft.p99());
        println!("wall {:.1}s for {ok} requests -> {:.0} prompt tok/s e2e\n",
                 wall, (ok * ctx) as f64 / wall);
    }
}

/// Render the rows as the `BENCH_10.json` artifact (no JSON serializer
/// in the offline vendor set; the schema is flat enough to emit by
/// hand).  Non-finite values are clamped to 0 so the output always
/// parses.
fn render_json(rows: &[ScenarioRow]) -> String {
    let fin = |x: f64| if x.is_finite() { x } else { 0.0 };
    let mut s = String::from("{\n  \"pr\": 10,\n  \"scenarios\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"tokens_per_s\": {:.3}, \
             \"ttft_p50_ms\": {:.3}, \"ttft_p95_ms\": {:.3}, \
             \"prefill_ms_mean\": {:.3}, \"cache_hit_rate\": {:.4}",
            r.name, fin(r.tokens_per_s), fin(r.ttft_p50_ms),
            fin(r.ttft_p95_ms), fin(r.prefill_ms_mean),
            fin(r.cache_hit_rate)));
        for (k, v) in &r.extras {
            s.push_str(&format!(", \"{k}\": {:.4}", fin(*v)));
        }
        s.push_str(&format!("}}{}\n",
                            if i + 1 < rows.len() { "," } else { "" }));
    }
    s.push_str("  ]\n}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut sim_only = false;
    let mut json_path: Option<String> = None;
    let mut positional: Vec<usize> = Vec::new();
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--sim-only" => sim_only = true,
            "--json" => {
                json_path = Some(it.next().ok_or_else(
                    || anyhow::anyhow!("--json expects a path"))?);
            }
            other => {
                if let Ok(v) = other.parse() {
                    positional.push(v);
                }
            }
        }
    }
    let n = positional.first().copied().unwrap_or(6);
    let ctx = positional.get(1).copied().unwrap_or(1024);

    if !sim_only {
        real_engine_scenario(n, ctx);
    }

    let mut rows = Vec::new();
    // the fairness headline: short-prompt TTFT with prefill concurrency
    // off (serial, PR-2 behavior) vs on
    rows.push(mixed_length_scenario(1));
    rows.push(mixed_length_scenario(4));
    // the amortization headline: warm-cache prefill cost on a repeated
    // workload vs the cold/cache-off baseline
    rows.extend(pattern_cache_scenario());
    // the prefix-sharing headline: shared prompt template served off
    // cached KV blocks -> warm TTFT collapse (asserted inside)
    rows.extend(prefix_cache_scenario());
    // the scaling headline: same work, more hardware -> strictly less
    // simulated prefill time (asserted inside)
    rows.extend(worker_scaling_scenario());
    // the fleet headline: same mixed workload, more engine shards ->
    // strictly more aggregate prefill throughput (asserted inside)
    rows.extend(fleet_scaling_scenario());
    // the overload headline: open-loop arrivals past capacity, survived
    // by SLO-aware admission (goodput floor + interactive TTFT + fast
    // sheds asserted inside)
    rows.extend(open_loop_scenario());

    if let Some(path) = json_path {
        std::fs::write(&path, render_json(&rows))?;
        println!("wrote {} scenario rows to {path}", rows.len());
    }
    Ok(())
}
