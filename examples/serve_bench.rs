//! End-to-end serving driver (the DESIGN.md E2E validation): a batched
//! request stream through the streaming session API — admission -> KV ->
//! chunked prefill (interleaved with decode via continuous batching) ->
//! per-token events — reporting per-request TTFT and throughput per
//! method.  Results are recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example serve_bench [requests] [ctx]

use shareprefill::config::MethodKind;
use shareprefill::serving::ServerBuilder;
use shareprefill::util::stats::Summary;
use shareprefill::workloads::tasks::latency_prompt;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let ctx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);

    for kind in [MethodKind::Flash, MethodKind::SharePrefill] {
        let handle = ServerBuilder::new().method(kind).spawn();
        let t0 = std::time::Instant::now();
        // submit the whole stream up front: requests overlap, so each
        // response's ttft_us shows what continuous batching buys
        let sessions: Vec<_> =
            (0..n).map(|_| handle.submit(latency_prompt(ctx), 4)).collect();
        let mut ttft = Summary::new();
        let mut ok = 0usize;
        println!("== {} ==", kind.name());
        for s in sessions {
            let id = s.id;
            match s.wait() {
                Ok(r) => {
                    ttft.add(r.ttft_us as f64 / 1e3);
                    ok += 1;
                    println!("req {:3}: ttft {:8.1} ms (queue {:6.1} + \
                              prefill {:7.1}), density {:.2}",
                             r.id, r.ttft_us as f64 / 1e3,
                             r.queue_us as f64 / 1e3,
                             r.prefill_us as f64 / 1e3, r.density);
                }
                Err(e) => println!("req {id:3}: {e:#}"),
            }
        }
        let report = handle.shutdown();
        let wall = t0.elapsed().as_secs_f64();
        println!("{report}");
        println!("ttft per request: mean {:.1} ms, p50 {:.1} ms, p99 \
                  {:.1} ms",
                 ttft.mean(), ttft.p50(), ttft.p99());
        println!("wall {:.1}s for {ok} requests -> {:.0} prompt tok/s e2e\n",
                 wall, (ok * ctx) as f64 / wall);
    }
    Ok(())
}
