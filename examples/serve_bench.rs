//! End-to-end serving driver (the DESIGN.md E2E validation): a batched
//! request stream through the streaming session API — admission -> KV ->
//! chunked prefill (interleaved with decode and with *other prefills*
//! via continuous batching) -> per-token events — reporting per-request
//! TTFT and throughput per method.  Results are recorded in
//! EXPERIMENTS.md.
//!
//! Three scenarios:
//!
//! 1. **Per-method uniform stream** (needs `make artifacts`): the real
//!    engine under concurrent equal-length prompts.
//! 2. **Mixed-length fairness** (artifact-free, `SimEngine` with
//!    simulated per-token compute): one very long prompt plus a stream
//!    of short prompts, run at `max_concurrent_prefills` 1 vs 4 — the
//!    per-class TTFT p50/p95 shows what interleaved multi-prefill buys
//!    short prompts stuck behind a long one.
//! 3. **Repeated workload, cross-request pattern cache** (artifact-free):
//!    the same-length prompt stream served with the cache off vs on —
//!    warm requests skip the pivotal bootstrap, so per-request prefill
//!    cost drops after the first (cold) request and the metrics report
//!    shows the cache hit rate.
//!
//!   cargo run --release --example serve_bench [requests] [ctx]

use shareprefill::config::{MethodKind, ServeConfig};
use shareprefill::serving::scheduler::Scheduler;
use shareprefill::serving::sim::SimEngine;
use shareprefill::serving::{server, ServerBuilder};
use shareprefill::util::stats::Summary;
use shareprefill::workloads::tasks::latency_prompt;

/// Mixed-length fairness: 1 × `LONG_TOKENS` prompt submitted first, then
/// `SHORTS` × `SHORT_TOKENS` prompts.  Coordinator-only (SimEngine), so
/// it runs without artifacts; simulated compute makes TTFT ordering
/// effects real wall-clock time.
fn mixed_length_scenario(max_prefills: usize) {
    const LONG_TOKENS: usize = 8192;
    const SHORT_TOKENS: usize = 128;
    const SHORTS: usize = 16;
    const LAYERS: usize = 8;
    const NS_PER_TOKEN_LAYER: u64 = 200;

    let cfg = ServeConfig {
        max_batch_tokens: 512,
        chunk_layers: 1,
        decode_tokens: 4,
        kv_blocks: 4096,
        max_concurrent_prefills: max_prefills,
        ..Default::default()
    };
    let handle = server::spawn(move || {
        Ok((Scheduler::new(&cfg),
            SimEngine::new(LAYERS).with_work(NS_PER_TOKEN_LAYER)))
    });
    let long = handle.submit(vec![7; LONG_TOKENS], 4);
    let shorts: Vec<_> = (0..SHORTS)
        .map(|_| handle.submit(vec![7; SHORT_TOKENS], 4))
        .collect();

    let mut short_ttft = Summary::new();
    for s in shorts {
        match s.wait() {
            Ok(r) => short_ttft.add(r.ttft_us as f64 / 1e3),
            Err(e) => println!("short request failed: {e:#}"),
        }
    }
    let long_ttft = match long.wait() {
        Ok(r) => r.ttft_us as f64 / 1e3,
        Err(e) => {
            println!("long request failed: {e:#}");
            f64::NAN
        }
    };
    let report = handle.shutdown();
    println!("== mixed-length fairness, max_concurrent_prefills = \
              {max_prefills} ==");
    println!("short ({SHORT_TOKENS} tok x{SHORTS}): ttft p50 {:8.2} ms, \
              p95 {:8.2} ms",
             short_ttft.p50(), short_ttft.percentile(95.0));
    println!("long  ({LONG_TOKENS} tok x1):  ttft     {long_ttft:8.2} ms");
    println!("{report}\n");
}

/// Repeated-workload cache scenario: one prompt length served
/// `REPEATS` times, cache off vs on (SimEngine, simulated compute,
/// serial prefills so every repeat after the first runs warm).
fn pattern_cache_scenario() {
    const TOKENS: usize = 2048;
    const REPEATS: usize = 8;
    const LAYERS: usize = 8;
    const NS_PER_TOKEN_LAYER: u64 = 200;

    let run = |cache_on: bool| {
        let cfg = ServeConfig {
            max_batch_tokens: 4096,
            chunk_layers: 1,
            decode_tokens: 2,
            kv_blocks: 4096,
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        let handle = server::spawn(move || {
            let engine = SimEngine::new(LAYERS)
                .with_work(NS_PER_TOKEN_LAYER);
            let engine = if cache_on {
                engine.with_pattern_cache()
            } else {
                engine
            };
            Ok((Scheduler::new(&cfg), engine))
        });
        let mut prefill_ms = Vec::new();
        for _ in 0..REPEATS {
            // serial submits: each waits, so repeats always run warm
            match handle.submit(vec![7; TOKENS], 2).wait() {
                Ok(r) => prefill_ms.push(r.prefill_us as f64 / 1e3),
                Err(e) => println!("request failed: {e:#}"),
            }
        }
        (prefill_ms, handle.shutdown())
    };

    println!("== cross-request pattern cache, repeated workload \
              ({TOKENS} tok x{REPEATS}) ==");
    let (off, _) = run(false);
    let (on, report) = run(true);
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    println!("cache off: prefill mean {:8.2} ms", mean(&off));
    if on.len() > 1 {
        let (cold, warm) = (on[0], mean(&on[1..]));
        println!("cache on:  cold {cold:8.2} ms, warm mean {warm:8.2} ms \
                  ({:.2}x faster warm)", cold / warm);
    }
    println!("{report}\n");
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let ctx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);

    for kind in [MethodKind::Flash, MethodKind::SharePrefill] {
        let handle = ServerBuilder::new().method(kind).spawn();
        let t0 = std::time::Instant::now();
        // submit the whole stream up front: requests overlap, so each
        // response's ttft_us shows what continuous batching buys
        let sessions: Vec<_> =
            (0..n).map(|_| handle.submit(latency_prompt(ctx), 4)).collect();
        let mut ttft = Summary::new();
        let mut ok = 0usize;
        println!("== {} ==", kind.name());
        for s in sessions {
            let id = s.id;
            match s.wait() {
                Ok(r) => {
                    ttft.add(r.ttft_us as f64 / 1e3);
                    ok += 1;
                    println!("req {:3}: ttft {:8.1} ms (queue {:6.1} + \
                              prefill {:7.1}), density {:.2}",
                             r.id, r.ttft_us as f64 / 1e3,
                             r.queue_us as f64 / 1e3,
                             r.prefill_us as f64 / 1e3, r.density);
                }
                Err(e) => println!("req {id:3}: {e:#}"),
            }
        }
        let report = handle.shutdown();
        let wall = t0.elapsed().as_secs_f64();
        println!("{report}");
        println!("ttft per request: mean {:.1} ms, p50 {:.1} ms, p99 \
                  {:.1} ms",
                 ttft.mean(), ttft.p50(), ttft.p99());
        println!("wall {:.1}s for {ok} requests -> {:.0} prompt tok/s e2e\n",
                 wall, (ok * ctx) as f64 / wall);
    }

    // the fairness headline: short-prompt TTFT with prefill concurrency
    // off (serial, PR-2 behavior) vs on
    mixed_length_scenario(1);
    mixed_length_scenario(4);

    // the amortization headline: warm-cache prefill cost on a repeated
    // workload vs the cold/cache-off baseline
    pattern_cache_scenario();
    Ok(())
}
