//! Figure 4 regeneration: PG19-sim perplexity vs. context length.
//!
//!   cargo run --release --example perplexity [samples]

use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{open_registry, perplexity};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let samples: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    for (model, ctxs) in [("sim-llama", vec![256usize, 512, 1024, 2048]),
                          ("sim-qwen", vec![256, 512, 1024])] {
        let curves = perplexity::run_ppl(&registry, &cfg, model,
                                         &MethodKind::all(), &ctxs,
                                         samples)?;
        println!("{}\n", curves.render());
    }
    Ok(())
}
