//! Figure 1 regeneration: accuracy (InfiniteBench-sim avg) vs. prefill
//! latency scatter for all methods/models.
//!
//!   cargo run --release --example tradeoff [samples] [ctx]

use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{infinitebench, latency, open_registry};
use shareprefill::workloads::tasks::TASK_NAMES;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let samples: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);
    let ctx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let tasks: Vec<_> = TASK_NAMES.iter().map(|(t, _)| *t).collect();
    println!("| model | method | avg score | prefill ms @ {ctx} |");
    println!("|---|---|---:|---:|");
    for model in ["sim-llama", "sim-qwen"] {
        let t1 = infinitebench::run_table1(&registry, &cfg, model,
                                           &MethodKind::all(), &tasks,
                                           samples, ctx)?;
        let lat = latency::run_latency(&registry, &cfg, model,
                                       &MethodKind::all(), &[ctx], 1)?;
        for m in MethodKind::all() {
            println!("| {} | {} | {:.1} | {:.0} |", model, m.name(),
                     t1.average(m), lat.curves[&m][0].0);
        }
    }
    Ok(())
}
