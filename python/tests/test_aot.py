"""aot.py manifest/emitter logic (no heavy lowering — structure only)."""

import json
import os

import numpy as np

from compile.aot import Emitter, spec
from compile.configs import SIM_LLAMA


def test_emitter_manifest_records_params(tmp_path):
    em = Emitter(str(tmp_path), force=False)
    import jax.numpy as jnp

    def fn(x, y):
        return (x @ y,)

    em.emit("t_fn", fn, [("x", spec((2, 3))), ("y", spec((3, 4)))],
            [spec((2, 4))], {"model": "m", "stage": "s", "seq": 2})
    em.write_manifest()
    man = json.load(open(tmp_path / "manifest.json"))
    (a,) = man["artifacts"]
    assert a["name"] == "t_fn"
    assert a["params"][0] == {"name": "x", "dtype": "f32", "shape": [2, 3]}
    assert a["outputs"] == [{"dtype": "f32", "shape": [2, 4]}]
    assert os.path.exists(tmp_path / "t_fn.hlo.txt")
    text = open(tmp_path / "t_fn.hlo.txt").read()
    assert "HloModule" in text


def test_emitter_idempotent(tmp_path):
    em = Emitter(str(tmp_path), force=False)

    def fn(x):
        return (x + 1.0,)

    em.emit("t_id", fn, [("x", spec((2,)))], [spec((2,))],
            {"model": "m", "stage": "s", "seq": 2})
    mtime = os.path.getmtime(tmp_path / "t_id.hlo.txt")
    em2 = Emitter(str(tmp_path), force=False)
    em2.emit("t_id", fn, [("x", spec((2,)))], [spec((2,))],
             {"model": "m", "stage": "s", "seq": 2})
    assert os.path.getmtime(tmp_path / "t_id.hlo.txt") == mtime


def test_budget_manifest_consistency():
    for s in SIM_LLAMA.seq_buckets:
        budgets = SIM_LLAMA.budgets(s)
        nb = SIM_LLAMA.num_blocks(s)
        assert budgets[-1] == nb and all(b <= nb for b in budgets)
