"""tenstore round-trip + format invariants (the rust reader mirrors these)."""

import struct

import numpy as np
import pytest

from compile import tenstore


def test_roundtrip(tmp_path):
    p = str(tmp_path / "t.bin")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.c": np.ones((2, 2, 2), np.float32),
        "scalarish": np.array([3.5], np.float32),
    }
    tenstore.write(p, tensors)
    back = tenstore.read(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_header_layout(tmp_path):
    p = str(tmp_path / "t.bin")
    tenstore.write(p, {"x": np.zeros((4,), np.float32)})
    raw = open(p, "rb").read()
    assert raw[:8] == b"TENSTOR1"
    (hlen,) = struct.unpack("<Q", raw[8:16])
    header = raw[16:16 + hlen]
    assert b'"x"' in header and b'"f32"' in header
    assert len(raw) == 16 + hlen + 16  # 4 f32 payload


def test_non_f32_is_coerced(tmp_path):
    p = str(tmp_path / "t.bin")
    tenstore.write(p, {"i": np.arange(4, dtype=np.int64)})
    back = tenstore.read(p)
    assert back["i"].dtype == np.float32
    np.testing.assert_array_equal(back["i"], [0, 1, 2, 3])


def test_deterministic_bytes(tmp_path):
    """Same tensors -> byte-identical file (sorted names, sorted header)."""
    a = {"z": np.ones(3, np.float32), "a": np.zeros(2, np.float32)}
    p1, p2 = str(tmp_path / "1.bin"), str(tmp_path / "2.bin")
    tenstore.write(p1, a)
    tenstore.write(p2, dict(reversed(list(a.items()))))
    assert open(p1, "rb").read() == open(p2, "rb").read()


def test_bad_magic_rejected(tmp_path):
    p = str(tmp_path / "bad.bin")
    with open(p, "wb") as f:
        f.write(b"NOTMAGIC" + b"\0" * 16)
    with pytest.raises(AssertionError):
        tenstore.read(p)
