"""L1 kernel vs. pure-jnp oracle — the core correctness signal.

Includes hypothesis sweeps over shapes / patterns / seeds (the system-level
requirement: the kernel must match ref.py for *any* coordinator-produced
index set, including degenerate ones).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import BLOCK_SIZE
from compile.kernels import ref
from compile.kernels.sparse_attn import (dense_causal_indices,
                                         sparse_attention)

ATOL = 2e-5


def rand_qkv(rng, seq, d):
    return tuple(
        jnp.asarray(rng.standard_normal((seq, d)), jnp.float32)
        for _ in range(3))


def random_pattern(rng, nb, budget, include_diag=True):
    """A random (idx, valid) pair like the coordinator would emit."""
    idx = np.zeros((nb, budget), np.int32)
    valid = np.zeros((nb, budget), np.float32)
    for i in range(nb):
        cand = list(range(i + 1))
        rng.shuffle(cand)
        picks = cand[:budget]
        if include_diag and i not in picks and picks:
            picks[0] = i
        for s, p in enumerate(picks):
            idx[i, s] = p
            valid[i, s] = 1.0
    return jnp.asarray(idx), jnp.asarray(valid)


@pytest.mark.parametrize("seq,d", [(128, 16), (128, 32), (256, 32), (192, 32)])
def test_dense_budget_matches_dense_attention(seq, d):
    rng = np.random.default_rng(seq + d)
    q, k, v = rand_qkv(rng, seq, d)
    idx, valid = dense_causal_indices(seq)
    o, _ = jax.jit(sparse_attention)(q, k, v, idx, valid)
    o_ref = ref.dense_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=ATOL)


@pytest.mark.parametrize("seq,budget", [(256, 1), (256, 2), (256, 3),
                                        (192, 2), (128, 1)])
def test_sparse_matches_ref(seq, budget):
    rng = np.random.default_rng(seq * 10 + budget)
    q, k, v = rand_qkv(rng, seq, 32)
    nb = seq // BLOCK_SIZE
    idx, valid = random_pattern(rng, nb, budget)
    o, abar = jax.jit(sparse_attention)(q, k, v, idx, valid)
    o_ref, abar_ref = ref.sparse_attention_ref(q, k, v, idx, valid)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=ATOL)
    a, b = np.asarray(abar), np.asarray(abar_ref)
    assert (np.isfinite(a) == np.isfinite(b)).all()
    np.testing.assert_allclose(a[np.isfinite(a)], b[np.isfinite(b)],
                               atol=ATOL)


def test_abar_dense_equals_block_average_map():
    """abar at the dense pattern == the full block-average map oracle."""
    rng = np.random.default_rng(3)
    seq = 192
    q, k, v = rand_qkv(rng, seq, 32)
    idx, valid = dense_causal_indices(seq)
    _, abar = jax.jit(sparse_attention)(q, k, v, idx, valid)
    amap = ref.block_average_map_ref(q, k)
    nb = seq // BLOCK_SIZE
    for i in range(nb):
        for j in range(nb):
            got = float(abar[i, j])
            want = float(amap[i, j])
            if j <= i:
                assert abs(got - want) < ATOL, (i, j, got, want)
            else:
                assert got == float("-inf")


def test_missing_diagonal_rows_are_zero():
    """Rows whose pattern excludes every causally-valid block output 0 and
    do not poison neighbours with NaN."""
    rng = np.random.default_rng(4)
    seq = 128
    q, k, v = rand_qkv(rng, seq, 32)
    nb = seq // BLOCK_SIZE
    idx = jnp.zeros((nb, 1), jnp.int32)
    valid = jnp.zeros((nb, 1), jnp.float32)  # nothing visited at all
    o, abar = jax.jit(sparse_attention)(q, k, v, idx, valid)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o), 0.0, atol=1e-6)
    assert (np.asarray(abar) == -np.inf).all()


def test_duplicate_indices_do_not_double_count():
    """The online softmax visits a block twice when idx repeats it — the
    oracle semantics (mask-level union) must still hold for the output."""
    rng = np.random.default_rng(5)
    seq = 128
    q, k, v = rand_qkv(rng, seq, 32)
    nb = seq // BLOCK_SIZE
    # budget 2, both slots point at the diagonal — attention over one block
    idx = jnp.stack([jnp.arange(nb, dtype=jnp.int32)] * 2, axis=1)
    valid = jnp.ones((nb, 2), jnp.float32)
    o, _ = jax.jit(sparse_attention)(q, k, v, idx, valid)
    idx1 = jnp.arange(nb, dtype=jnp.int32)[:, None]
    valid1 = jnp.ones((nb, 1), jnp.float32)
    o1, _ = jax.jit(sparse_attention)(q, k, v, idx1, valid1)
    # NOTE: duplicates *are* double-counted by an online softmax (same block
    # contributes twice to the denominator with identical scores -> same
    # normalized distribution). Outputs must therefore agree.
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    seq=st.sampled_from([128, 192, 256]),
    d=st.sampled_from([16, 32]),
    budget=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_hypothesis_sparse_matches_ref(seq, d, budget, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, seq, d)
    nb = seq // BLOCK_SIZE
    include_diag = seed % 3 != 0  # also exercise diagonal-free patterns
    idx, valid = random_pattern(rng, nb, budget, include_diag)
    o, abar = jax.jit(sparse_attention)(q, k, v, idx, valid)
    o_ref, abar_ref = ref.sparse_attention_ref(q, k, v, idx, valid)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=ATOL)
    a, b = np.asarray(abar), np.asarray(abar_ref)
    assert (np.isfinite(a) == np.isfinite(b)).all()
    if np.isfinite(a).any():
        np.testing.assert_allclose(a[np.isfinite(a)], b[np.isfinite(b)],
                                   atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hypothesis_output_rows_convex(seed):
    """Each output row is a convex combination of V rows: within V bounds."""
    rng = np.random.default_rng(seed)
    seq = 128
    q, k, v = rand_qkv(rng, seq, 32)
    nb = seq // BLOCK_SIZE
    idx, valid = random_pattern(rng, nb, 2)
    o, _ = jax.jit(sparse_attention)(q, k, v, idx, valid)
    o = np.asarray(o)
    vmin, vmax = float(jnp.min(v)), float(jnp.max(v))
    assert (o >= vmin - 1e-4).all() and (o <= vmax + 1e-4).all()
