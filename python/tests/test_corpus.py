"""Synthetic corpus generator invariants."""

import numpy as np

from compile.corpus import Corpus, batches


def test_deterministic():
    a = Corpus(7).tokens(2000)
    b = Corpus(7).tokens(2000)
    np.testing.assert_array_equal(a, b)


def test_token_range():
    t = Corpus(1).tokens(5000)
    assert t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 256  # raw bytes in a 512 vocab


def test_documents_contain_retrieval_structure():
    doc = Corpus(3).document(4000)
    assert "<KEY:" in doc and "<GET:" in doc
    # every GET's name was defined by a KEY earlier, and the value follows
    import re
    keys = dict(re.findall(r"<KEY:([a-z]+\d+)=(\d{6})>", doc))
    gets = re.findall(r"<GET:([a-z]+\d+)>(\d{6})", doc)
    assert gets, "no queries emitted"
    for name, val in gets:
        assert keys.get(name) == val


def test_batches_shapes_and_coverage():
    rows = list(batches(0, seq=64, batch=3, steps=4))
    assert len(rows) == 4
    for r in rows:
        assert r.shape == (3, 65)
    # batches must not repeat data between steps
    assert not np.array_equal(rows[0], rows[1])
