"""L2 stage functions: staged pipeline == monolithic forward, decode ==
prefill, GQA handling, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import SIM_LLAMA, SIM_QWEN, ModelConfig
from compile.kernels.sparse_attn import dense_causal_indices

TINY = ModelConfig(name="tiny-test", num_layers=2, num_heads=4,
                   num_kv_heads=2, head_dim=16, hidden=64, ffn=128,
                   vocab=512, max_seq=256, seq_buckets=(128, 256))


@pytest.fixture(scope="module")
def params():
    return M.init_params(TINY, jax.random.PRNGKey(0))


def toks(rng, seq):
    return jnp.asarray(rng.integers(0, 256, size=seq), jnp.int32)


def test_staged_equals_full_forward(params):
    """Running embed->qkv->dense attention->post_attn->lm_head through the
    stage functions must equal the monolithic training forward."""
    rng = np.random.default_rng(0)
    tokens = toks(rng, 128)
    want = M.full_forward(TINY, params, tokens)

    x = M.stage_embed(tokens, params.embed)
    qkv, post = M.stage_qkv(TINY), M.stage_post_attn(TINY)
    for lp in params.layers:
        q, k, v = qkv(x, lp.ln1, lp.wq, lp.wk, lp.wv)
        o = M.attention_dense(TINY, q, k, v)
        x = post(o, x, lp.wo, lp.ln2, lp.w_gate, lp.w_up, lp.w_down)
    got = M.stage_lm_head(TINY)(x, params.ln_f, params.w_out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_sparse_staged_dense_budget_equals_full(params):
    """The L1 kernel at the dense pattern inside the staged pipeline equals
    the monolithic dense forward — the end-to-end numerics contract the
    rust coordinator relies on."""
    rng = np.random.default_rng(1)
    tokens = toks(rng, 128)
    idx, valid = dense_causal_indices(128)
    got = M.staged_forward_sparse(TINY, params, tokens, idx, valid)
    want = M.full_forward(TINY, params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_decode_step_matches_prefill(params):
    """Fused decode over a KV cache reproduces the prefill logits for the
    final position — validates cache layout, GQA repeat and RoPE-at-pos."""
    rng = np.random.default_rng(2)
    seq = 64
    max_seq = TINY.max_seq
    tokens = toks(rng, seq)
    want_logits = M.full_forward(TINY, params, tokens)[-1]

    # prefill seq-1 tokens through the stage pipeline collecting the cache
    x = M.stage_embed(tokens[:-1], params.embed)
    qkv, post = M.stage_qkv(TINY), M.stage_post_attn(TINY)
    caches = []
    for lp in params.layers:
        q, k, v = qkv(x, lp.ln1, lp.wq, lp.wk, lp.wv)
        kc = jnp.zeros((TINY.num_kv_heads, max_seq, TINY.head_dim))
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, :seq - 1].set(k)
        vc = vc.at[:, :seq - 1].set(v)
        caches.append((kc, vc))
        o = M.attention_dense(TINY, q, k, v)
        x = post(o, x, lp.wo, lp.ln2, lp.w_gate, lp.w_up, lp.w_down)

    # decode the final token
    step = M.stage_decode_step(TINY, max_seq)
    x1 = M.stage_embed(tokens[-1:], params.embed)
    pos = jnp.int32(seq - 1)
    for lp, (kc, vc) in zip(params.layers, caches):
        x1, _, _ = step(x1, lp.ln1, lp.wq, lp.wk, lp.wv, lp.wo, lp.ln2,
                        lp.w_gate, lp.w_up, lp.w_down, kc, vc, pos)
    got = M.stage_lm_head(TINY)(x1, params.ln_f, params.w_out)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_logits),
                               atol=2e-3)


def test_decode_returns_cache_rows(params):
    """k_new/v_new from decode equal the qkv-stage rows at that position."""
    rng = np.random.default_rng(3)
    seq = 32
    tokens = toks(rng, seq)
    x = M.stage_embed(tokens, params.embed)
    lp = params.layers[0]
    q, k, v = M.stage_qkv(TINY)(x, lp.ln1, lp.wq, lp.wk, lp.wv)

    step = M.stage_decode_step(TINY, TINY.max_seq)
    xlast = M.stage_embed(tokens[seq - 1:seq], params.embed)
    kc = jnp.zeros((TINY.num_kv_heads, TINY.max_seq, TINY.head_dim))
    kc = kc.at[:, :seq - 1].set(k[:, :seq - 1])
    vc = jnp.zeros_like(kc).at[:, :seq - 1].set(v[:, :seq - 1])
    _, k_new, v_new = step(xlast, lp.ln1, lp.wq, lp.wk, lp.wv, lp.wo,
                           lp.ln2, lp.w_gate, lp.w_up, lp.w_down, kc, vc,
                           jnp.int32(seq - 1))
    np.testing.assert_allclose(np.asarray(k_new), np.asarray(k[:, -1]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_new), np.asarray(v[:, -1]),
                               atol=1e-4)


def test_rope_relative_property():
    """RoPE: <rope(q,m), rope(k,n)> depends only on m-n (shift both by s)."""
    d = 16
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)
    sin, cos = M.rope_tables(64, d)

    def at(x, pos):
        return M.apply_rope(x, sin[pos:pos + 1], cos[pos:pos + 1])

    dot1 = float(jnp.sum(at(q, 10) * at(k, 3)))
    dot2 = float(jnp.sum(at(q, 30) * at(k, 23)))
    assert abs(dot1 - dot2) < 1e-4


@pytest.mark.parametrize("cfg", [SIM_LLAMA, SIM_QWEN], ids=lambda c: c.name)
def test_config_shapes(cfg):
    assert cfg.q_dim == cfg.num_heads * cfg.head_dim
    assert cfg.num_heads % cfg.num_kv_heads == 0
    for s in cfg.seq_buckets:
        assert s % 64 == 0
        budgets = cfg.budgets(s)
        assert budgets[-1] == cfg.num_blocks(s)
        assert all(b1 < b2 for b1, b2 in zip(budgets, budgets[1:]))


def test_gqa_repeat_matches_mha_when_kv_equal():
    """With num_kv_heads == num_heads, GQA path == MHA path."""
    cfg = ModelConfig(name="t", num_layers=1, num_heads=2, num_kv_heads=2,
                      head_dim=8, hidden=16, ffn=32, vocab=512, max_seq=64,
                      seq_buckets=(64,))
    p = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(5)
    tokens = toks(rng, 64)
    logits = M.full_forward(cfg, p, tokens)
    assert np.isfinite(np.asarray(logits)).all()
