"""Probe kernels vs. oracles + distribution invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import BLOCK_SIZE
from compile.kernels import ref
from compile.kernels.probes import flex_probe, pattern_probe, vslash_probe

ATOL = 2e-5


def rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("h,seq,d", [(2, 128, 32), (4, 256, 32), (3, 192, 16)])
def test_pattern_probe_matches_ref(h, seq, d):
    rng = np.random.default_rng(h * seq)
    qh, k = rand(rng, (h, BLOCK_SIZE, d)), rand(rng, (h, seq, d))
    got = jax.jit(pattern_probe)(qh, k)
    want = ref.pattern_probe_ref(qh, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_pattern_probe_is_distribution():
    rng = np.random.default_rng(0)
    qh, k = rand(rng, (4, BLOCK_SIZE, 32)), rand(rng, (4, 256, 32))
    a = np.asarray(jax.jit(pattern_probe)(qh, k))
    assert (a >= 0).all()
    np.testing.assert_allclose(a.sum(-1), 1.0, atol=1e-5)


@pytest.mark.parametrize("h,seq", [(2, 128), (4, 256)])
def test_vslash_probe_matches_ref(h, seq):
    rng = np.random.default_rng(seq)
    qh, k = rand(rng, (h, BLOCK_SIZE, 32)), rand(rng, (h, seq, 32))
    got = jax.jit(vslash_probe)(qh, k)
    want = ref.vslash_probe_ref(qh, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_vslash_probe_causal_rows():
    """Row r of the last block attends to exactly seq-BS+r+1 positions."""
    rng = np.random.default_rng(1)
    seq = 192
    qh, k = rand(rng, (1, BLOCK_SIZE, 32)), rand(rng, (1, seq, 32))
    a = np.asarray(jax.jit(vslash_probe)(qh, k))[0]
    for r in range(BLOCK_SIZE):
        live = seq - BLOCK_SIZE + r + 1
        assert (a[r, :live] > 0).all()
        np.testing.assert_allclose(a[r, live:], 0.0, atol=1e-8)
        np.testing.assert_allclose(a[r].sum(), 1.0, atol=1e-5)


@pytest.mark.parametrize("h,seq", [(2, 128), (4, 256)])
def test_flex_probe_matches_ref(h, seq):
    rng = np.random.default_rng(seq + 1)
    q, k = rand(rng, (h, seq, 32)), rand(rng, (h, seq, 32))
    got = jax.jit(flex_probe)(q, k)
    want = ref.flex_probe_ref(q, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_flex_probe_reproduces_pooling_failure_modes():
    """Section 3 of the paper: pooling mis-estimates block importance.

    Construct the paper's token-alignment counterexample at block scale:
    Q rows/K rows arranged so pool(Q)·pool(K) is nonzero while every
    token-level score inside the block is ~zero relative to a control
    block.  The flex estimator must rank the control block wrong vs. the
    exact block average — the measurable inaccuracy SharePrefill avoids."""
    bs = BLOCK_SIZE
    seq = 2 * bs
    d = 4
    q = np.zeros((seq, d), np.float32)
    k = np.zeros((seq, d), np.float32)
    # block 0 of K: mean is large but each token orthogonal to each q token
    # (alternating +e0/-e0 in q, all e1 in k-block0 -> token scores 0)
    q[bs:, 0] = np.tile([1.0, -1.0], bs // 2)   # row-block 1 queries
    k[:bs, 1] = 1.0                              # k block 0
    # block 1 of K aligned with q tokens -> real attention mass
    k[bs:, 0] = np.tile([1.0, -1.0], bs // 2)
    qj, kj = jnp.asarray(q[None]), jnp.asarray(k[None])
    est = np.asarray(jax.jit(flex_probe)(qj, kj))[0]       # [2, 2]
    exact = np.asarray(ref.block_average_map_ref(qj[0], kj[0]))
    # exact: for row-block 1, block 1 (diag, aligned) carries the mass
    assert exact[1, 1] > exact[1, 0]
    # pooled estimator collapses the +1/-1 structure: pool(q) ~ 0 so the
    # aligned block's advantage is lost (scores ~equal) — the failure mode.
    assert abs(est[1, 1] - est[1, 0]) < 0.05


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), h=st.integers(1, 4),
       seq=st.sampled_from([128, 192, 256]))
def test_hypothesis_probe_distributions(seed, h, seq):
    rng = np.random.default_rng(seed)
    qh, k = rand(rng, (h, BLOCK_SIZE, 32)), rand(rng, (h, seq, 32))
    a = np.asarray(jax.jit(pattern_probe)(qh, k))
    assert a.shape == (h, seq // BLOCK_SIZE)
    assert (a >= 0).all()
    np.testing.assert_allclose(a.sum(-1), 1.0, atol=1e-5)
