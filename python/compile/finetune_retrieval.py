"""Retrieval-dense fine-tune: teaches the <KEY:name=val>/<GET:name>val
induction behaviour the Retr.* evaluations need (the base mix has too few
retrieval tokens for it to emerge in 500 steps)."""
import sys, time
import jax, jax.numpy as jnp
import numpy as np

from . import corpus, tenstore
from .configs import CONFIGS
from . import model as M
from .train import adamw_init, train_step, flatten_params

def retrieval_batch(rng, seq, batch):
    rows = []
    for _ in range(batch):
        c = corpus.Corpus(int(rng.integers(1 << 30)))
        s = ""
        while len(s) < seq + 1:
            defs, queries = c.kv_pairs(int(rng.integers(2, 6)))
            block = "\n".join(defs) + "\n"
            block += c.prose(int(rng.integers(10, 60))) + "\n"
            block += "".join(q + v + "\n" for q, v in queries)
            s += block
        b = np.frombuffer(s.encode()[:seq + 1], dtype=np.uint8)
        rows.append(b.astype(np.int32))
    return np.stack(rows)

def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "sim-llama"
    steps = int(sys.argv[2]) if len(sys.argv) > 2 else 120
    cfg = CONFIGS[name]
    ts = tenstore.read(f"../artifacts/weights-{name}.bin")
    layers = [M.LayerParams(**{f: jnp.asarray(ts[f"layer{i}.{f}"])
                               for f in M.LayerParams._fields})
              for i in range(cfg.num_layers)]
    params = M.Params(embed=jnp.asarray(ts["embed"]), layers=layers,
                      ln_f=jnp.asarray(ts["ln_f"]),
                      w_out=jnp.asarray(ts["w_out"]))
    m, v = adamw_init(params)
    rng = np.random.default_rng(99)
    t0 = time.time()
    for step in range(steps):
        rows = retrieval_batch(rng, 512, 4)
        params, m, v, loss = train_step(cfg, params, m, v,
                                        jnp.asarray(rows),
                                        jnp.float32(1e-4), jnp.int32(step))
        if step % 20 == 0 or step == steps - 1:
            print(f"[ft {name}] {step}/{steps} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    tenstore.write(f"../artifacts/weights-{name}.bin",
                   {k: np.asarray(w) for k, w in
                    flatten_params(cfg, params).items()})
    print("saved")

if __name__ == "__main__":
    main()
