"""Model configurations shared by the L2 model, the trainer and the AOT
pipeline.

Two tiny byte-level transformer configs stand in for the paper's
Llama-3-8B-Instruct-262k and Qwen2.5-7B-Instruct (see DESIGN.md
"Substitutions").  ``sim_qwen`` exercises GQA (num_kv_heads < num_heads).
"""

from dataclasses import dataclass
from typing import Tuple

# Block size of the block-sparse attention grid.  Shared constant across all
# three layers (L1 kernel, L2 artifact shapes, L3 coordinator).
BLOCK_SIZE = 64

# Budget buckets as fractions of the number of kv blocks.  The coordinator
# picks the smallest bucket >= the per-head required block count.
BUDGET_FRACTIONS = (0.125, 0.25, 0.5, 1.0)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    hidden: int
    ffn: int
    vocab: int
    max_seq: int
    seq_buckets: Tuple[int, ...] = ()
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def group(self) -> int:
        """Number of query heads sharing one kv head."""
        return self.num_heads // self.num_kv_heads

    def num_blocks(self, seq: int) -> int:
        assert seq % BLOCK_SIZE == 0, f"seq {seq} not a multiple of {BLOCK_SIZE}"
        return seq // BLOCK_SIZE

    def budgets(self, seq: int):
        """Distinct budget bucket sizes (in kv blocks) for a sequence bucket."""
        nb = self.num_blocks(seq)
        out = []
        for f in BUDGET_FRACTIONS:
            b = max(1, int(round(nb * f)))
            if b not in out:
                out.append(b)
        return out


SIM_LLAMA = ModelConfig(
    name="sim-llama",  # stands in for Llama-3-8B-Instruct-262k
    num_layers=6,
    num_heads=8,
    num_kv_heads=8,
    head_dim=32,
    hidden=256,
    ffn=512,
    vocab=512,
    max_seq=4096,
    seq_buckets=(256, 512, 1024, 2048, 4096),
)

SIM_QWEN = ModelConfig(
    name="sim-qwen",  # stands in for Qwen2.5-7B-Instruct (exercises GQA)
    num_layers=4,
    num_heads=6,
    num_kv_heads=2,
    head_dim=32,
    hidden=192,
    ffn=384,
    vocab=512,
    max_seq=2048,
    seq_buckets=(256, 512, 1024, 2048),
)

CONFIGS = {c.name: c for c in (SIM_LLAMA, SIM_QWEN)}
