"""AOT pipeline: lower every L2 stage (and the L1 kernels inside them) to
HLO **text** artifacts + manifest for the rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs under ``--out`` (default ../artifacts):

  {model}_{stage}_s{seq}.hlo.txt          per-seq-bucket stages
  {model}_attn_s{seq}_b{budget}.hlo.txt   budgeted sparse attention
  {model}_decode.hlo.txt                  fused decode layer (Smax cache)
  {model}_lmhead_s1.hlo.txt               single-position lm head
  manifest.json                           shapes + parameter order
  golden-{model}.bin                      tenstore golden vectors for the
                                          rust integration tests

Idempotent: existing files are skipped unless --force.  Python never runs
after this; the rust binary is self-contained.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tenstore
from .configs import BLOCK_SIZE, CONFIGS
from .kernels import ref as kref
from .kernels.probes import flex_probe, pattern_probe, vslash_probe
from .kernels.sparse_attn import dense_causal_indices, sparse_attention

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Emitter:
    def __init__(self, out_dir: str, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.manifest = {"block_size": BLOCK_SIZE, "models": {},
                         "artifacts": []}

    def emit(self, name: str, fn, params, outputs, meta):
        """Lower fn at the given arg specs and write {name}.hlo.txt."""
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        entry = dict(meta)
        entry["name"] = name
        entry["file"] = f"{name}.hlo.txt"
        entry["params"] = [
            {"name": n, "dtype": "i32" if s.dtype == I32 else "f32",
             "shape": list(s.shape)} for n, s in params]
        entry["outputs"] = [
            {"dtype": "i32" if s.dtype == I32 else "f32",
             "shape": list(s.shape)} for s in outputs]
        self.manifest["artifacts"].append(entry)
        if os.path.exists(path) and not self.force:
            return
        lowered = jax.jit(fn).lower(*[s for _, s in params])
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"  wrote {entry['file']}")

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote manifest.json ({len(self.manifest['artifacts'])} artifacts)")


def emit_model(em: Emitter, cfg):
    n = cfg.name.replace("-", "")
    em.manifest["models"][cfg.name] = {
        "prefix": n,
        "num_layers": cfg.num_layers, "num_heads": cfg.num_heads,
        "num_kv_heads": cfg.num_kv_heads, "head_dim": cfg.head_dim,
        "hidden": cfg.hidden, "ffn": cfg.ffn, "vocab": cfg.vocab,
        "max_seq": cfg.max_seq, "seq_buckets": list(cfg.seq_buckets),
        "budgets": {str(s): cfg.budgets(s) for s in cfg.seq_buckets},
        "rope_theta": cfg.rope_theta, "norm_eps": cfg.norm_eps,
        "weights_file": f"weights-{cfg.name}.bin",
    }
    H, Hkv, D, Dm, F, V = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                           cfg.hidden, cfg.ffn, cfg.vocab)
    BS = BLOCK_SIZE

    for seq in cfg.seq_buckets:
        nb = cfg.num_blocks(seq)
        base = {"model": cfg.name, "seq": seq}
        em.emit(f"{n}_embed_s{seq}", M.stage_embed,
                [("tokens", spec((seq,), I32)), ("table", spec((V, Dm)))],
                [spec((seq, Dm))], {**base, "stage": "embed"})
        em.emit(f"{n}_qkv_s{seq}", M.stage_qkv(cfg),
                [("x", spec((seq, Dm))), ("ln_w", spec((Dm,))),
                 ("wq", spec((Dm, H * D))), ("wk", spec((Dm, Hkv * D))),
                 ("wv", spec((Dm, Hkv * D)))],
                [spec((H, seq, D)), spec((Hkv, seq, D)), spec((Hkv, seq, D))],
                {**base, "stage": "qkv"})
        em.emit(f"{n}_postattn_s{seq}", M.stage_post_attn(cfg),
                [("attn_out", spec((H, seq, D))), ("resid", spec((seq, Dm))),
                 ("wo", spec((H * D, Dm))), ("ln2_w", spec((Dm,))),
                 ("w_gate", spec((Dm, F))), ("w_up", spec((Dm, F))),
                 ("w_down", spec((F, Dm)))],
                [spec((seq, Dm))], {**base, "stage": "post_attn"})
        em.emit(f"{n}_lmhead_s{seq}", M.stage_lm_head(cfg),
                [("x", spec((seq, Dm))), ("ln_w", spec((Dm,))),
                 ("w_out", spec((Dm, V)))],
                [spec((seq, V))], {**base, "stage": "lm_head"})
        em.emit(f"{n}_patternprobe_s{seq}", pattern_probe,
                [("qh", spec((H, BS, D))), ("k", spec((H, seq, D)))],
                [spec((H, nb))], {**base, "stage": "pattern_probe"})
        em.emit(f"{n}_vslashprobe_s{seq}", vslash_probe,
                [("qh", spec((H, BS, D))), ("k", spec((H, seq, D)))],
                [spec((H, BS, seq))], {**base, "stage": "vslash_probe"})
        em.emit(f"{n}_flexprobe_s{seq}", flex_probe,
                [("q", spec((H, seq, D))), ("k", spec((H, seq, D)))],
                [spec((H, nb, nb))], {**base, "stage": "flex_probe"})
        for b in cfg.budgets(seq):
            em.emit(f"{n}_attn_s{seq}_b{b}", sparse_attention,
                    [("q", spec((seq, D))), ("k", spec((seq, D))),
                     ("v", spec((seq, D))), ("idx", spec((nb, b), I32)),
                     ("valid", spec((nb, b)))],
                    [spec((seq, D)), spec((nb, b))],
                    {**base, "stage": "attn", "budget": b})

    em.emit(f"{n}_lmhead_s1", M.stage_lm_head(cfg),
            [("x", spec((1, Dm))), ("ln_w", spec((Dm,))),
             ("w_out", spec((Dm, V)))],
            [spec((1, V))], {"model": cfg.name, "stage": "lm_head", "seq": 1})
    Smax = cfg.max_seq
    em.emit(f"{n}_decode", M.stage_decode_step(cfg, Smax),
            [("x", spec((1, Dm))), ("ln_w", spec((Dm,))),
             ("wq", spec((Dm, H * D))), ("wk", spec((Dm, Hkv * D))),
             ("wv", spec((Dm, Hkv * D))), ("wo", spec((H * D, Dm))),
             ("ln2_w", spec((Dm,))), ("w_gate", spec((Dm, F))),
             ("w_up", spec((Dm, F))), ("w_down", spec((F, Dm))),
             ("kcache", spec((Hkv, Smax, D))), ("vcache", spec((Hkv, Smax, D))),
             ("pos", spec((), I32))],
            [spec((1, Dm)), spec((Hkv, D)), spec((Hkv, D))],
            {"model": cfg.name, "stage": "decode", "seq": Smax})


def emit_golden(em: Emitter, cfg, seq: int = 256):
    """Golden vectors the rust integration tests replay through the compiled
    artifacts: random inputs + oracle outputs (all f32 via tenstore; the
    int inputs are stored as f32 and cast on the rust side)."""
    path = os.path.join(em.out_dir, f"golden-{cfg.name}.bin")
    if os.path.exists(path) and not em.force:
        return
    rng = np.random.default_rng(42)
    D = cfg.head_dim
    nb = seq // BLOCK_SIZE
    q = rng.standard_normal((seq, D)).astype(np.float32)
    k = rng.standard_normal((seq, D)).astype(np.float32)
    v = rng.standard_normal((seq, D)).astype(np.float32)
    idx, valid = dense_causal_indices(seq)
    o_dense, abar_dense = kref.sparse_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), idx, valid)
    # a sparse pattern: diagonal + sink + one random mid block, budget nb//4
    b = max(2, nb // 4)
    sidx = np.zeros((nb, b), np.int32)
    svalid = np.zeros((nb, b), np.float32)
    for i in range(nb):
        picks = [i, 0] + list(rng.integers(0, i + 1, size=max(0, b - 2)))
        for s, p in enumerate(picks[:b]):
            sidx[i, s] = p
            svalid[i, s] = 1.0
    o_sp, abar_sp = kref.sparse_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(sidx), jnp.asarray(svalid))
    H = cfg.num_heads
    qh = rng.standard_normal((H, BLOCK_SIZE, D)).astype(np.float32)
    kh = rng.standard_normal((H, seq, D)).astype(np.float32)
    probe = kref.pattern_probe_ref(jnp.asarray(qh), jnp.asarray(kh))
    flexq = rng.standard_normal((H, seq, D)).astype(np.float32)
    flex = kref.flex_probe_ref(jnp.asarray(flexq), jnp.asarray(kh))
    tenstore.write(path, {
        "seq": np.array([seq], np.float32),
        "q": q, "k": k, "v": v,
        "dense_idx": np.asarray(idx, np.float32),
        "dense_valid": np.asarray(valid, np.float32),
        "dense_o": np.asarray(o_dense),
        "dense_abar": np.nan_to_num(np.asarray(abar_dense), neginf=-1e30),
        "sparse_idx": sidx.astype(np.float32),
        "sparse_valid": svalid,
        "sparse_o": np.asarray(o_sp),
        "sparse_abar": np.nan_to_num(np.asarray(abar_sp), neginf=-1e30),
        "probe_qh": qh, "probe_k": kh,
        "probe_ahat": np.asarray(probe),
        "flex_q": flexq,
        "flex_map": np.nan_to_num(np.asarray(flex), neginf=-1e30),
    })
    print(f"  wrote golden-{cfg.name}.bin")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out, args.force)
    names = list(CONFIGS) if args.models == "all" else args.models.split(",")
    for name in names:
        cfg = CONFIGS[name]
        print(f"model {name}")
        emit_model(em, cfg)
        if not args.skip_golden:
            emit_golden(em, cfg)
    em.write_manifest()


if __name__ == "__main__":
    main()
