"""Synthetic byte-level training corpus.

The corpus is engineered so the tiny models develop the attention behaviours
the paper's evaluation stresses (see DESIGN.md "Substitutions"):

  * retrieval / copy structure (``<KEY:name=digits> ... <GET:name>digits``)
    so induction-style heads form — these drive the Retr.* tasks;
  * locally-coherent "English-like" prose (vertical/slash/local patterns);
  * dialogue turns (staircase patterns, En.Dia analog);
  * code-like nested text (irregular long-range patterns, Code.Debug
    analog).

Tokens are raw bytes (0..255) inside a 512-entry vocab; the upper half of
the vocab is reserved/unused, matching the rust-side tokenizer
(``rust/src/workloads/``).  Generation is fully deterministic given a seed —
python (training) and rust (evaluation) implement the same generators with
the same archetype mix but independent seeds; only the *distribution*
matters, not byte-identity.
"""

import numpy as np

WORDS = (
    "the of and to in is was he for it with as his on be at by had not are "
    "but from or have an they which one you were all her she there would "
    "their we him been has when who will no more if out so up said what its "
    "about than into them can only other time new some could these two may "
    "first then do any like my now over such our man me even most made after "
    "also did many off before must well back through years where much your "
    "way down should because each just those people how too good".split()
)

NAMES = (
    "alder birch cedar dahlia elm fern gingko hazel iris juniper kale lotus "
    "maple nettle oak poplar quince rowan sage tulip".split()
)


class Corpus:
    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    # -- component generators -------------------------------------------
    def prose(self, n_words: int) -> str:
        words = self.rng.choice(WORDS, size=n_words)
        out, line = [], []
        for w in words:
            line.append(w)
            if self.rng.random() < 0.08:
                line[-1] += "."
            if sum(len(x) + 1 for x in line) > 70:
                out.append(" ".join(line))
                line = []
        if line:
            out.append(" ".join(line))
        return "\n".join(out)

    def kv_pairs(self, n: int):
        """Returns (definitions, queries) for retrieval structure."""
        defs, queries = [], []
        for _ in range(n):
            name = self.rng.choice(NAMES) + str(self.rng.integers(10, 99))
            val = "".join(str(d) for d in self.rng.integers(0, 10, size=6))
            defs.append(f"<KEY:{name}={val}>")
            queries.append((f"<GET:{name}>", val))
        return defs, queries

    def dialogue(self, n_turns: int) -> str:
        speakers = ["ann", "bob", "eve", "dan"]
        lines = []
        for _ in range(n_turns):
            s = self.rng.choice(speakers)
            lines.append(f"{s}: {self.prose(int(self.rng.integers(4, 12)))}")
        return "\n".join(lines)

    def codeish(self, n_stmts: int) -> str:
        lines = []
        depth = 0
        for _ in range(n_stmts):
            v = self.rng.choice(NAMES)
            r = self.rng.random()
            if r < 0.2 and depth < 3:
                lines.append("  " * depth + f"fn {v}() {{")
                depth += 1
            elif r < 0.3 and depth > 0:
                depth -= 1
                lines.append("  " * depth + "}")
            else:
                a, b = self.rng.choice(NAMES), self.rng.choice(NAMES)
                lines.append("  " * depth + f"let {v} = {a} + {b};")
        lines.extend("}" for _ in range(depth))
        return "\n".join(lines)

    # -- documents -------------------------------------------------------
    def document(self, approx_len: int) -> str:
        """One mixed document: prose with embedded kv retrieval, dialogue
        and code sections; queries appear *after* long spans so the model
        must learn long-range copy."""
        parts = []
        defs, queries = self.kv_pairs(int(self.rng.integers(2, 5)))
        parts.extend(defs)
        while sum(len(p) for p in parts) < approx_len * 0.8:
            r = self.rng.random()
            if r < 0.5:
                parts.append(self.prose(int(self.rng.integers(30, 90))))
            elif r < 0.75:
                parts.append(self.dialogue(int(self.rng.integers(3, 8))))
            else:
                parts.append(self.codeish(int(self.rng.integers(8, 24))))
        for qm, val in queries:
            parts.append(qm + val)
        return "\n".join(parts)

    def tokens(self, n_tokens: int) -> np.ndarray:
        """A contiguous token stream of length >= n_tokens."""
        chunks = []
        total = 0
        while total < n_tokens:
            doc = self.document(int(self.rng.integers(800, 3000)))
            b = np.frombuffer(doc.encode("utf-8", "ignore"), dtype=np.uint8)
            chunks.append(b.astype(np.int32))
            total += len(b)
        return np.concatenate(chunks)[:n_tokens]


def batches(seed: int, seq: int, batch: int, steps: int):
    """Yield (tokens[batch, seq+1] int32) training batches."""
    c = Corpus(seed)
    stream = c.tokens((seq + 1) * batch * steps + 1)
    per = seq + 1
    for s in range(steps):
        rows = []
        for b in range(batch):
            off = (s * batch + b) * per
            rows.append(stream[off:off + per])
        yield np.stack(rows)
