"""Probe computations: the cheap attention statistics the coordinator
uses to *decide* patterns before paying for sparse attention.

Three probes, all batched over query heads ``H`` (kv repeated to H by L2
for GQA models):

  * ``pattern_probe`` — the paper's :math:`\\hat a` (Alg. 3 line 3):
    softmax of the block-pooled scores of the *last query row-block*
    :math:`\\hat Q` against all of K.  Output ``[H, NB]``.  Feeds the JS
    sparsity / similarity tests.
  * ``vslash_probe`` — the softmaxed last-block attention map
    :math:`\\hat A` (Alg. 5 line 2), ``[H, BS, S]``.  The coordinator sums
    it along vertical / slash directions to search the conservative
    vertical-slash pattern (also the MInference baseline's dynamic index).
  * ``flex_probe`` — the FlexPrefill baseline's pooled block map
    ``pool(Q)·pool(K)`` over *all* row-blocks, ``[H, NB, NB]``, causal
    −inf, row-softmaxed.  This is the estimator whose token-alignment /
    smoothing inaccuracies Section 3 of the paper critiques — reproduced
    faithfully so the accuracy gap is measurable.

Unlike the attention hot-spot (the Pallas kernel in sparse_attn.py), the
probes are a single tiny batched matmul each (< 20 MFLOP at the largest
bucket) — they lower as plain fused XLA ops, where the CPU backend runs
them at memory bandwidth.  An earlier Pallas-interpret version cost
~30 ms/call from interpreter overhead vs ~2 ms fused (EXPERIMENTS.md
§Perf); on real TPUs these would live in the same Mosaic kernel family as
the attention kernel.
"""

import jax
import jax.numpy as jnp

from ..configs import BLOCK_SIZE

NEG_INF = float("-inf")


def _last_block_mask(bs: int, seq: int):
    """Causal mask of the last query row-block vs all keys: [bs, S]."""
    qpos = (seq - bs) + jnp.arange(bs)[:, None]
    kpos = jnp.arange(seq)[None, :]
    return kpos <= qpos


def pattern_probe(qh, k, *, block_size: int = BLOCK_SIZE,
                  interpret: bool = True):
    """Block-pooled last-row-block attention estimate per head.

    Args:
      qh: ``[H, BS, D]`` — the last query row-block per head.
      k:  ``[H, S, D]``.

    Returns:
      ``[H, NB]`` — softmax over kv blocks of the pooled scores.
    """
    del interpret  # plain jnp; kept for signature compatibility
    h, bs, d = qh.shape
    _, seq, _ = k.shape
    nb = seq // block_size
    s = jnp.einsum("hqd,hkd->hqk", qh, k) / (d ** 0.5)  # [H, bs, S]
    m = _last_block_mask(bs, seq)[None]
    blk = jnp.where(m, s, 0.0).reshape(h, bs, nb, block_size)
    cnt = m.reshape(1, bs, nb, block_size).sum((1, 3))  # [1, nb]
    pooled = blk.sum((1, 3)) / jnp.maximum(cnt, 1)      # [H, nb]
    return jax.nn.softmax(pooled, axis=-1)


def vslash_probe(qh, k, *, block_size: int = BLOCK_SIZE,
                 interpret: bool = True):
    """Softmaxed last-row-block attention map per head: ``[H, BS, S]``."""
    del interpret
    h, bs, d = qh.shape
    _, seq, _ = k.shape
    s = jnp.einsum("hqd,hkd->hqk", qh, k) / (d ** 0.5)
    s = jnp.where(_last_block_mask(bs, seq)[None], s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def flex_probe(q, k, *, block_size: int = BLOCK_SIZE, interpret: bool = True):
    """FlexPrefill-style pooled block map per head.

    Args:
      q, k: ``[H, S, D]``.

    Returns:
      ``[H, NB, NB]`` row-softmaxed pooled block scores (upper triangle
      masked).  Mean-pooling happens *before* the QK product — deliberately
      reproducing the estimator (and its failure modes) from the paper's
      Section 3.
    """
    del interpret
    h, seq, d = q.shape
    nb = seq // block_size
    qp = jnp.mean(q.reshape(h, nb, block_size, d), axis=2)
    kp = jnp.mean(k.reshape(h, nb, block_size, d), axis=2)
    s = jnp.einsum("hqd,hkd->hqk", qp, kp) / (d ** 0.5)
    i = jnp.arange(nb)[:, None]
    j = jnp.arange(nb)[None, :]
    s = jnp.where((j <= i)[None], s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)
