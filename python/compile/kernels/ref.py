"""Pure-jnp oracles for every L1 kernel — the correctness signal.

Nothing here uses Pallas; these are straight-line dense implementations the
pytest / hypothesis suites compare the kernels against (and that the rust
integration tests compare the *artifacts* against, via golden vectors
exported by aot.py).
"""

import jax
import jax.numpy as jnp

from ..configs import BLOCK_SIZE

NEG_INF = float("-inf")


def causal_mask(seq: int):
    i = jnp.arange(seq)[:, None]
    j = jnp.arange(seq)[None, :]
    return j <= i


def block_mask_from_indices(idx, valid, seq: int,
                            block_size: int = BLOCK_SIZE):
    """Expand ``(idx, valid)`` into a dense ``[S, S]`` boolean mask."""
    nb = seq // block_size
    bm = jnp.zeros((nb, nb), bool)
    for i in range(nb):
        for s in range(idx.shape[1]):
            bm = bm.at[i, idx[i, s]].set(
                jnp.logical_or(bm[i, idx[i, s]], valid[i, s] > 0))
    full = jnp.repeat(jnp.repeat(bm, block_size, 0), block_size, 1)
    return full & causal_mask(seq)


def dense_attention(q, k, v):
    """Vanilla causal attention for one head: ``[S, D]`` inputs."""
    seq, d = q.shape
    s = (q @ k.T) / (d ** 0.5)
    s = jnp.where(causal_mask(seq), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v


def sparse_attention_ref(q, k, v, idx, valid, block_size: int = BLOCK_SIZE):
    """Oracle for kernels.sparse_attn.sparse_attention.

    Returns ``(o, abar)`` with identical semantics: rows that attend to
    nothing produce zeros; ``abar`` is the block-mean of raw scaled scores
    over causally-valid positions of visited blocks, −inf elsewhere.
    """
    seq, d = q.shape
    nb, budget = idx.shape
    s = (q @ k.T) / (d ** 0.5)
    mask = block_mask_from_indices(idx, valid, seq, block_size)
    sm = jnp.where(mask, s, NEG_INF)
    rowmax = jnp.max(sm, axis=-1)
    any_row = jnp.isfinite(rowmax)
    p = jnp.where(
        jnp.isfinite(sm),
        jnp.exp(sm - jnp.where(any_row, rowmax, 0.0)[:, None]), 0.0)
    denom = jnp.sum(p, axis=-1)
    o = (p @ v) / jnp.maximum(denom, 1e-30)[:, None]

    cm = causal_mask(seq)
    abar = jnp.full((nb, budget), NEG_INF)
    for i in range(nb):
        for slot in range(budget):
            jb = idx[i, slot]
            blk_s = jax.lax.dynamic_slice(
                s, (i * block_size, jb * block_size),
                (block_size, block_size))
            blk_m = jax.lax.dynamic_slice(
                cm, (i * block_size, jb * block_size),
                (block_size, block_size))
            blk_m = blk_m & (valid[i, slot] > 0)
            n = jnp.sum(blk_m)
            val = jnp.where(
                n > 0,
                jnp.sum(jnp.where(blk_m, blk_s, 0.0)) / jnp.maximum(n, 1),
                NEG_INF)
            abar = abar.at[i, slot].set(val)
    return o, abar


def pattern_probe_ref(qh, k, block_size: int = BLOCK_SIZE):
    """Oracle for probes.pattern_probe: ``[H, NB]``."""
    h, bs, d = qh.shape
    _, seq, _ = k.shape
    nb = seq // block_size
    out = []
    for hh in range(h):
        s = (qh[hh] @ k[hh].T) / (d ** 0.5)  # [bs, S]
        qpos = (nb - 1) * block_size + jnp.arange(bs)[:, None]
        kpos = jnp.arange(seq)[None, :]
        m = kpos <= qpos
        pooled = []
        for j in range(nb):
            blk = s[:, j * block_size:(j + 1) * block_size]
            bm = m[:, j * block_size:(j + 1) * block_size]
            n = jnp.sum(bm)
            pooled.append(jnp.sum(jnp.where(bm, blk, 0.0)) / jnp.maximum(n, 1))
        out.append(jax.nn.softmax(jnp.stack(pooled)))
    return jnp.stack(out)


def vslash_probe_ref(qh, k, block_size: int = BLOCK_SIZE):
    """Oracle for probes.vslash_probe: ``[H, BS, S]``."""
    h, bs, d = qh.shape
    _, seq, _ = k.shape
    nb = seq // block_size
    out = []
    for hh in range(h):
        s = (qh[hh] @ k[hh].T) / (d ** 0.5)
        qpos = (nb - 1) * block_size + jnp.arange(bs)[:, None]
        kpos = jnp.arange(seq)[None, :]
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        out.append(jax.nn.softmax(s, axis=-1))
    return jnp.stack(out)


def flex_probe_ref(q, k, block_size: int = BLOCK_SIZE):
    """Oracle for probes.flex_probe: ``[H, NB, NB]``."""
    h, seq, d = q.shape
    nb = seq // block_size
    out = []
    for hh in range(h):
        qp = jnp.mean(q[hh].reshape(nb, block_size, d), axis=1)
        kp = jnp.mean(k[hh].reshape(nb, block_size, d), axis=1)
        s = (qp @ kp.T) / (d ** 0.5)
        i = jnp.arange(nb)[:, None]
        j = jnp.arange(nb)[None, :]
        s = jnp.where(j <= i, s, NEG_INF)
        out.append(jax.nn.softmax(s, axis=-1))
    return jnp.stack(out)


def block_average_map_ref(q, k, block_size: int = BLOCK_SIZE):
    """Full ``[NB, NB]`` block-averaged raw-score map (dense heads' Ã)."""
    seq, d = q.shape
    nb = seq // block_size
    s = (q @ k.T) / (d ** 0.5)
    cm = causal_mask(seq)
    out = jnp.full((nb, nb), NEG_INF)
    for i in range(nb):
        for j in range(i + 1):
            blk = s[i * block_size:(i + 1) * block_size,
                    j * block_size:(j + 1) * block_size]
            bm = cm[i * block_size:(i + 1) * block_size,
                    j * block_size:(j + 1) * block_size]
            n = jnp.sum(bm)
            out = out.at[i, j].set(
                jnp.sum(jnp.where(bm, blk, 0.0)) / jnp.maximum(n, 1))
    return out
