"""L1 Pallas kernel: block-sparse causal flash attention with a gather budget.

This is the paper's sparse attention kernel (Section 5.2 "Sparse Attention
Computation"): a FlashAttention-2-style block online-softmax loop that

  * visits, for every query row-block, only the kv-block indices supplied by
    the L3 coordinator (``idx``/``valid``), and
  * emits the block-averaged raw QK scores ``abar`` the paper calls
    :math:`\\tilde A` — the input to "Construct Pivotal Pattern" (Alg. 2).
    Skipped / unvisited blocks get ``-inf``.

Block-skipping is *executed*, not simulated: valid slots form a prefix of
each row (the rust ``BlockMask::pack`` invariant) and the inner loop is a
``lax.while_loop`` over that prefix, so the compiled HLO runs exactly
``cnt[i]`` block iterations per row-block — measured latency tracks the
sparsity the coordinator achieves, which is what the paper's latency
claims are about.

Structure note (CPU-interpret specific): the kernel is a *single program*
(``grid=()``) with an outer ``fori_loop`` over query row-blocks and
``pl.ds`` dynamic-slice gathers for kv tiles.  A grid-per-row-block
variant (the natural TPU mapping — see DESIGN.md §Hardware-Adaptation)
materializes its full-K/V block inputs per grid step under interpret mode,
which is memcpy-bound on CPU; the single-program form keeps K/V staged
once per call while expressing the identical HBM→VMEM tile schedule.

``interpret=True`` everywhere: the CPU PJRT backend cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the rust
runtime executes it (numerics identical, verified against ``ref.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BLOCK_SIZE

NEG_INF = float("-inf")


def _sparse_attn_kernel(idx_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                        abar_ref, *, budget: int, block_size: int,
                        head_dim: int, num_blocks: int, softscale: float):
    bs, d = block_size, head_dim
    abar_ref[...] = jnp.full((num_blocks, budget), NEG_INF, jnp.float32)

    def row(qb, _):
        q = pl.load(q_ref, (pl.ds(qb * bs, bs), slice(None)))  # [bs, d]
        valid_row = pl.load(valid_ref, (pl.ds(qb, 1), slice(None)))  # [1, B]
        idx_row = pl.load(idx_ref, (pl.ds(qb, 1), slice(None)))
        # padded slots are a suffix: run exactly cnt block iterations
        cnt = jnp.sum(valid_row > 0).astype(jnp.int32)

        def body(carry):
            j, m_i, l_i, acc = carry
            kb = idx_row[0, j]
            k = pl.load(k_ref, (pl.ds(kb * bs, bs), slice(None)))
            v = pl.load(v_ref, (pl.ds(kb * bs, bs), slice(None)))
            s = jnp.dot(q, k.T) * softscale  # [bs, bs]
            qpos = qb * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
            kpos = kb * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
            mask = kpos <= qpos
            nvalid = jnp.sum(mask)
            # block-mean of raw scaled scores over causally-valid positions
            abar = jnp.where(
                nvalid > 0,
                jnp.sum(jnp.where(mask, s, 0.0)) / jnp.maximum(nvalid, 1),
                NEG_INF)
            pl.store(abar_ref, (pl.ds(qb, 1), pl.ds(j, 1)),
                     abar[None, None])

            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(jnp.isfinite(s), jnp.exp(s - safe_m[:, None]), 0.0)
            alpha = jnp.where(jnp.isfinite(m_i), jnp.exp(m_i - safe_m),
                              jnp.zeros_like(m_i))
            l_new = l_i * alpha + jnp.sum(p, axis=1)
            acc = acc * alpha[:, None] + jnp.dot(p, v)
            return j + 1, m_new, l_new, acc

        m0 = jnp.full((bs,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((bs,), jnp.float32)
        acc0 = jnp.zeros((bs, d), jnp.float32)
        _, _, l, acc = jax.lax.while_loop(
            lambda c: c[0] < cnt, body, (jnp.int32(0), m0, l0, acc0))
        o = acc / jnp.maximum(l, 1e-30)[:, None]
        pl.store(o_ref, (pl.ds(qb * bs, bs), slice(None)), o)
        return 0

    jax.lax.fori_loop(0, num_blocks, row, 0)


def sparse_attention(q, k, v, idx, valid, *, block_size: int = BLOCK_SIZE,
                     interpret: bool = True):
    """Block-sparse causal attention for a single head.

    Args:
      q, k, v: ``[S, D]`` float32.
      idx: ``[NB, B]`` int32 — kv-block indices to visit per row-block
        (values in ``[0, NB)``).
      valid: ``[NB, B]`` float32 — 1.0 for live slots, 0.0 padding.  Live
        slots MUST form a prefix of each row (``BlockMask::pack`` packs
        them that way); suffix slots are never visited.

    Returns:
      ``(o [S, D], abar [NB, B])`` — attention output and block-averaged
      raw QK scores (−inf for unvisited slots / fully-masked blocks).
      Rows whose pattern visits nothing output zeros.
    """
    seq, head_dim = q.shape
    nb, budget = idx.shape
    assert seq % block_size == 0 and nb == seq // block_size
    kernel = functools.partial(
        _sparse_attn_kernel, budget=budget, block_size=block_size,
        head_dim=head_dim, num_blocks=nb, softscale=1.0 / (head_dim ** 0.5))
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((seq, head_dim), jnp.float32),
            jax.ShapeDtypeStruct((nb, budget), jnp.float32),
        ],
        interpret=interpret,
    )(idx, valid, q, k, v)


def dense_causal_indices(seq: int, block_size: int = BLOCK_SIZE):
    """Full causal ``(idx, valid)`` at budget == NB (the dense pattern).

    Row-block ``i`` visits blocks ``0..i`` (valid prefix) and pads the rest.
    Used for the paper's dense "pivotal" heads and the FlashAttn baseline.
    """
    nb = seq // block_size
    idx = jnp.tile(jnp.arange(nb, dtype=jnp.int32)[None, :], (nb, 1))
    valid = (jnp.arange(nb)[None, :] <= jnp.arange(nb)[:, None]).astype(
        jnp.float32)
    return idx, valid
