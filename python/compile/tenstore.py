"""Writer for the ``tenstore`` weight archive consumed by the rust runtime.

Format (little-endian):

    8 bytes   magic ``b"TENSTOR1"``
    8 bytes   u64 header length
    N bytes   JSON header: {"tensors": {name: {dtype, shape, offset, nbytes}}}
    payload   raw tensor bytes, offsets relative to payload start

Only float32 is stored (the whole stack runs f32 on the CPU backend — see
DESIGN.md §Hardware-Adaptation for the bf16 story on real hardware).
The rust-side reader lives in ``rust/src/substrate/tenstore.rs``; the two
are round-trip tested via golden files emitted by aot.py.
"""

import json
import struct

import numpy as np

MAGIC = b"TENSTOR1"


def write(path: str, tensors: dict) -> None:
    """Write ``{name: np.ndarray}`` to ``path``."""
    header = {"tensors": {}}
    payload = bytearray()
    for name, arr in sorted(tensors.items()):
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        header["tensors"][name] = {
            "dtype": "f32",
            "shape": list(arr.shape),
            "offset": len(payload),
            "nbytes": arr.nbytes,
        }
        payload.extend(arr.tobytes())
    hdr = json.dumps(header, sort_keys=True).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(hdr)))
        f.write(hdr)
        f.write(bytes(payload))


def read(path: str) -> dict:
    """Read back (python-side verification / tests)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC, "bad magic"
    (hlen,) = struct.unpack("<Q", data[8:16])
    header = json.loads(data[16:16 + hlen])
    base = 16 + hlen
    out = {}
    for name, meta in header["tensors"].items():
        raw = data[base + meta["offset"]: base + meta["offset"] + meta["nbytes"]]
        out[name] = np.frombuffer(raw, dtype=np.float32).reshape(meta["shape"])
    return out
