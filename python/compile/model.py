"""L2: the JAX model — a tiny Llama-style transformer decomposed into the
weight-as-input stage functions that aot.py lowers to HLO artifacts.

Design (see DESIGN.md): every stage takes its *weights as runtime inputs*,
so a single compiled executable serves all layers of a model; the rust
coordinator owns the weight store and feeds the right layer's tensors per
call.  Stages are shape-specialized per sequence bucket (and per budget
bucket for attention), which is the only compile-time specialization.

Stages
------
  embed       tokens[S] i32, table[V,Dm]                        -> x[S,Dm]
  qkv         x[S,Dm], ln_w, wq, wk, wv                         -> q[H,S,D] (roped),
                                                                   k[Hkv,S,D] (roped), v[Hkv,S,D]
  attention   (L1 kernel, per head)                             -> o[S,D], abar[NB,B]
  post_attn   attn_out[H,S,D], resid[S,Dm], wo, ln2_w, w_gate,
              w_up, w_down                                      -> x[S,Dm]
  lm_head     x[S,Dm], ln_w, w_out                              -> logits[S,V]
  decode_step x[1,Dm], layer weights, kcache, vcache, pos       -> x[1,Dm], k_new, v_new

``full_forward`` chains the stages in pure JAX (dense attention) — the
training forward and the oracle the integration tests compare the staged
pipeline against.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref as kref
from .kernels.sparse_attn import dense_causal_indices, sparse_attention


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope_tables(seq: int, head_dim: int, theta: float = 10000.0):
    """Standard RoPE sin/cos tables, computed in-graph from iota."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]  # [S, 1]
    ang = pos * freqs[None, :]  # [S, half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: [..., S, D] with D split into two halves (rotate-half convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def silu_mlp(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# --------------------------------------------------------------------------
# Stage functions (each lowered to one artifact by aot.py)
# --------------------------------------------------------------------------

def stage_embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def stage_qkv(cfg: ModelConfig):
    def fn(x, ln_w, wq, wk, wv):
        seq = x.shape[0]
        xn = rmsnorm(x, ln_w, cfg.norm_eps)
        q = (xn @ wq).reshape(seq, cfg.num_heads, cfg.head_dim)
        k = (xn @ wk).reshape(seq, cfg.num_kv_heads, cfg.head_dim)
        v = (xn @ wv).reshape(seq, cfg.num_kv_heads, cfg.head_dim)
        sin, cos = rope_tables(seq, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q.transpose(1, 0, 2), sin, cos)  # [H, S, D]
        k = apply_rope(k.transpose(1, 0, 2), sin, cos)  # [Hkv, S, D]
        v = v.transpose(1, 0, 2)
        return q, k, v
    return fn


def stage_post_attn(cfg: ModelConfig):
    def fn(attn_out, resid, wo, ln2_w, w_gate, w_up, w_down):
        seq = resid.shape[0]
        merged = attn_out.transpose(1, 0, 2).reshape(seq, cfg.q_dim)
        x = resid + merged @ wo
        x = x + silu_mlp(rmsnorm(x, ln2_w, cfg.norm_eps), w_gate, w_up, w_down)
        return x
    return fn


def stage_lm_head(cfg: ModelConfig):
    def fn(x, ln_w, w_out):
        return rmsnorm(x, ln_w, cfg.norm_eps) @ w_out
    return fn


def stage_decode_step(cfg: ModelConfig, max_seq: int):
    """Fused single-token transformer layer over a KV cache.

    Decode is not the paper's contribution (all baselines fall back to
    dense attention after prefill), so this is plain masked jnp attention.
    ``pos`` is the index of the new token; the cache rows ``[0, pos)`` are
    live.  Returns the layer output and the roped k / v rows for the rust
    side to write into its host cache at row ``pos``.
    """
    def fn(x, ln_w, wq, wk, wv, wo, ln2_w, w_gate, w_up, w_down,
           kcache, vcache, pos):
        xn = rmsnorm(x, ln_w, cfg.norm_eps)  # [1, Dm]
        q = (xn @ wq).reshape(cfg.num_heads, cfg.head_dim)
        k = (xn @ wk).reshape(cfg.num_kv_heads, cfg.head_dim)
        v = (xn @ wv).reshape(cfg.num_kv_heads, cfg.head_dim)
        half = cfg.head_dim // 2
        freqs = 1.0 / (cfg.rope_theta ** (
            jnp.arange(half, dtype=jnp.float32) / half))
        ang = pos.astype(jnp.float32) * freqs
        sin, cos = jnp.sin(ang), jnp.cos(ang)

        def rope1(t):
            t1, t2 = t[..., :half], t[..., half:]
            return jnp.concatenate([t1 * cos - t2 * sin, t2 * cos + t1 * sin], -1)

        q, k_new = rope1(q), rope1(k)
        # repeat kv heads to H query heads
        kc = jnp.repeat(kcache, cfg.group, axis=0)  # [H, Smax, D]
        vc = jnp.repeat(vcache, cfg.group, axis=0)
        kn = jnp.repeat(k_new, cfg.group, axis=0)   # [H, D]
        vn = jnp.repeat(v, cfg.group, axis=0)
        scale = 1.0 / (cfg.head_dim ** 0.5)
        s_cache = jnp.einsum("hd,hsd->hs", q, kc) * scale  # [H, Smax]
        live = jnp.arange(max_seq)[None, :] < pos
        s_cache = jnp.where(live, s_cache, -jnp.inf)
        s_self = jnp.sum(q * kn, axis=-1, keepdims=True) * scale  # [H, 1]
        s = jnp.concatenate([s_cache, s_self], axis=1)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hs,hsd->hd", p[:, :max_seq], vc) + p[:, max_seq:] * vn
        x = x + o.reshape(1, cfg.q_dim) @ wo
        x = x + silu_mlp(rmsnorm(x, ln2_w, cfg.norm_eps), w_gate, w_up, w_down)
        return x, k_new, v
    return fn


# --------------------------------------------------------------------------
# Whole-model forward (training + oracle for the staged pipeline)
# --------------------------------------------------------------------------

class LayerParams(NamedTuple):
    ln1: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ln2: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


class Params(NamedTuple):
    embed: jax.Array
    layers: list  # [LayerParams]
    ln_f: jax.Array
    w_out: jax.Array


def init_params(cfg: ModelConfig, key) -> Params:
    def dense(key, fan_in, shape):
        return jax.random.normal(key, shape, jnp.float32) / (fan_in ** 0.5)

    keys = jax.random.split(key, 3 + 9 * cfg.num_layers)
    layers = []
    for i in range(cfg.num_layers):
        k = keys[3 + 9 * i: 3 + 9 * (i + 1)]
        layers.append(LayerParams(
            ln1=jnp.ones(cfg.hidden),
            wq=dense(k[0], cfg.hidden, (cfg.hidden, cfg.q_dim)),
            wk=dense(k[1], cfg.hidden, (cfg.hidden, cfg.kv_dim)),
            wv=dense(k[2], cfg.hidden, (cfg.hidden, cfg.kv_dim)),
            wo=dense(k[3], cfg.q_dim, (cfg.q_dim, cfg.hidden)),
            ln2=jnp.ones(cfg.hidden),
            w_gate=dense(k[4], cfg.hidden, (cfg.hidden, cfg.ffn)),
            w_up=dense(k[5], cfg.hidden, (cfg.hidden, cfg.ffn)),
            w_down=dense(k[6], cfg.ffn, (cfg.ffn, cfg.hidden)),
        ))
    return Params(
        embed=0.02 * jax.random.normal(keys[0], (cfg.vocab, cfg.hidden)),
        layers=layers,
        ln_f=jnp.ones(cfg.hidden),
        w_out=dense(keys[1], cfg.hidden, (cfg.hidden, cfg.vocab)),
    )


def attention_dense(cfg: ModelConfig, q, k, v):
    """Dense causal attention used by the training forward: [H,S,D] inputs."""
    kq = jnp.repeat(k, cfg.group, axis=0)
    vq = jnp.repeat(v, cfg.group, axis=0)
    scale = 1.0 / (cfg.head_dim ** 0.5)
    s = jnp.einsum("hqd,hkd->hqk", q, kq) * scale
    seq = q.shape[1]
    mask = kref.causal_mask(seq)
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vq)


def full_forward(cfg: ModelConfig, params: Params, tokens):
    """Dense forward over a token batch element: tokens [S] -> logits [S,V]."""
    x = stage_embed(tokens, params.embed)
    qkv = stage_qkv(cfg)
    post = stage_post_attn(cfg)
    for lp in params.layers:
        q, k, v = qkv(x, lp.ln1, lp.wq, lp.wk, lp.wv)
        o = attention_dense(cfg, q, k, v)
        x = post(o, x, lp.wo, lp.ln2, lp.w_gate, lp.w_up, lp.w_down)
    return stage_lm_head(cfg)(x, params.ln_f, params.w_out)


def staged_forward_sparse(cfg: ModelConfig, params: Params, tokens,
                          idx, valid, interpret: bool = True):
    """Forward through the *staged* pipeline with the L1 sparse kernel using
    a shared (idx, valid) pattern for every head — a python-side mirror of
    what the rust coordinator executes, used by integration tests."""
    x = stage_embed(tokens, params.embed)
    qkv = stage_qkv(cfg)
    post = stage_post_attn(cfg)
    for lp in params.layers:
        q, k, v = qkv(x, lp.ln1, lp.wq, lp.wk, lp.wv)
        kq = jnp.repeat(k, cfg.group, axis=0)
        vq = jnp.repeat(v, cfg.group, axis=0)
        outs = []
        for h in range(cfg.num_heads):
            o, _ = sparse_attention(q[h], kq[h], vq[h], idx, valid,
                                    interpret=interpret)
            outs.append(o)
        x = post(jnp.stack(outs), x, lp.wo, lp.ln2, lp.w_gate, lp.w_up,
                 lp.w_down)
    return stage_lm_head(cfg)(x, params.ln_f, params.w_out)


def dense_pattern(seq: int):
    return dense_causal_indices(seq)
