"""Build-time trainer for the two tiny models.

Runs once under ``make artifacts`` (skipped when ``artifacts/weights-*.bin``
already exist).  AdamW + cosine schedule, next-byte cross-entropy on the
synthetic corpus.  The loss curve is appended to ``artifacts/train_log.txt``
and copied into EXPERIMENTS.md.

Usage: python -m compile.train [--model NAME] [--steps N] [--out DIR]
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, tenstore
from .configs import CONFIGS, ModelConfig
from .model import Params, full_forward, init_params

TRAIN_DEFAULTS = {
    # name: (phases [(seq, batch, steps)], lr, seed).  The bulk of training
    # runs at short context (cheap on 1 CPU core); a final long-context
    # phase teaches the RoPE range the evaluations use.
    "sim-llama": ([(512, 4, 240), (2048, 1, 40)], 3e-4, 1),
    "sim-qwen": ([(512, 4, 180), (1024, 2, 30)], 3e-4, 2),
}


def loss_fn(cfg: ModelConfig, params: Params, tokens):
    """tokens: [B, S+1] — next-byte CE averaged over the batch."""
    def one(row):
        logits = full_forward(cfg, params, row[:-1])
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(
            logp, row[1:, None], axis=-1))
    return jnp.mean(jax.vmap(one)(tokens))


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return zeros, jax.tree.map(jnp.zeros_like, params)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2, 3))
def train_step(cfg, params, m, v, tokens, lr, step):
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens))(params)
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    t = step + 1
    mhat = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mhat, vhat)
    return params, m, v, loss


def flatten_params(cfg: ModelConfig, params: Params) -> dict:
    out = {"embed": params.embed, "ln_f": params.ln_f, "w_out": params.w_out}
    for i, lp in enumerate(params.layers):
        for field in lp._fields:
            out[f"layer{i}.{field}"] = getattr(lp, field)
    return out


def train(cfg: ModelConfig, steps_override: int, out_dir: str, log) -> dict:
    phases, lr0, seed = TRAIN_DEFAULTS[cfg.name]
    if steps_override:
        phases = [(phases[0][0], phases[0][1], steps_override)]
    total = sum(p[2] for p in phases)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    m, v = adamw_init(params)
    t0 = time.time()
    step = 0
    for pi, (seq, batch, steps) in enumerate(phases):
        for rows in corpus.batches(seed * 1000 + 7 + pi, seq, batch, steps):
            warm = min(1.0, (step + 1) / 20)
            lr = lr0 * warm * 0.5 * (1 + np.cos(np.pi * step / total))
            params, m, v, loss = train_step(
                cfg, params, m, v, jnp.asarray(rows), jnp.float32(lr),
                jnp.int32(step))
            if step % 10 == 0 or step == total - 1:
                msg = (f"[{cfg.name}] step {step:4d}/{total} seq {seq} "
                       f"loss {float(loss):.4f} lr {lr:.2e} "
                       f"({time.time() - t0:.0f}s)")
                print(msg, flush=True)
                log.write(msg + "\n")
                log.flush()
            step += 1
    return flatten_params(cfg, params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = list(CONFIGS) if args.model == "all" else [args.model]
    with open(os.path.join(args.out, "train_log.txt"), "a") as log:
        for name in names:
            cfg = CONFIGS[name]
            path = os.path.join(args.out, f"weights-{name}.bin")
            if os.path.exists(path):
                print(f"{path} exists, skipping")
                continue
            tensors = train(cfg, args.steps, args.out, log)
            tenstore.write(path, {k: np.asarray(v)
                                  for k, v in tensors.items()})
            print(f"wrote {path}")


if __name__ == "__main__":
    main()
