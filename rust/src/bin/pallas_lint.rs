//! `pallas-lint` — architecture & invariant checker for this tree.
//!
//! CI runs it as a blocking job:
//!
//!     cargo run --release --bin pallas-lint -- --check rust/src
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.  Diagnostics
//! are `file:line: [rule] message` on stdout.  See the `lint` module
//! and DESIGN.md "Invariants & enforcement" for the rules.

use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::process::ExitCode;

use shareprefill::lint::{self, baseline};

const USAGE: &str = "\
pallas-lint — architecture & invariant checker

USAGE: pallas-lint --check <src-root> [options]

OPTIONS
  --baseline FILE     panic-hygiene ratchet file
                      (default: ./lint_baseline.toml if present)
  --design FILE       DESIGN.md for the knob-doc half of knob-hygiene
                      (default: ./DESIGN.md if present)
  --ops FILE          operator's handbook for the knob-table half of
                      knob-hygiene
                      (default: ./docs/OPERATIONS.md if present)
  --write-baseline    freeze the observed hot-path panic counts into
                      the baseline file instead of comparing

RULES   layering, determinism, panic-hygiene, knob-hygiene
EXIT    0 clean · 1 findings · 2 usage/IO error";

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("pallas-lint: error: {e:#}");
            ExitCode::from(2)
        }
    }
}

fn next_arg(args: &mut impl Iterator<Item = String>, flag: &str)
            -> Result<String> {
    args.next().ok_or_else(|| anyhow!("{flag} needs a value\n{USAGE}"))
}

fn run() -> Result<bool> {
    let mut root: Option<PathBuf> = None;
    let mut baseline_path: Option<PathBuf> = None;
    let mut design_path: Option<PathBuf> = None;
    let mut ops_path: Option<PathBuf> = None;
    let mut write = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => {
                root = Some(PathBuf::from(next_arg(&mut args, "--check")?));
            }
            "--baseline" => {
                baseline_path =
                    Some(PathBuf::from(next_arg(&mut args, "--baseline")?));
            }
            "--design" => {
                design_path =
                    Some(PathBuf::from(next_arg(&mut args, "--design")?));
            }
            "--ops" => {
                ops_path =
                    Some(PathBuf::from(next_arg(&mut args, "--ops")?));
            }
            "--write-baseline" => write = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(true);
            }
            other if !other.starts_with('-') && root.is_none() => {
                root = Some(PathBuf::from(other));
            }
            other => bail!("unknown argument '{other}'\n{USAGE}"),
        }
    }
    let Some(root) = root else {
        bail!("no source root given\n{USAGE}");
    };
    if !root.is_dir() {
        bail!("source root {} is not a directory", root.display());
    }

    // Defaults resolve against the working directory (CI runs from the
    // repo root) and are skipped quietly when absent, so the binary
    // also works on bare fixture trees.
    let baseline_path = baseline_path.or_else(|| {
        let p = PathBuf::from("lint_baseline.toml");
        p.is_file().then_some(p)
    });
    let design_path = design_path.or_else(|| {
        let p = PathBuf::from("DESIGN.md");
        p.is_file().then_some(p)
    });
    let ops_path = ops_path.or_else(|| {
        let p = PathBuf::from("docs/OPERATIONS.md");
        p.is_file().then_some(p)
    });
    let design_text = match &design_path {
        Some(p) => Some(std::fs::read_to_string(p)?),
        None => {
            eprintln!("pallas-lint: note: no DESIGN.md — knob \
                       documentation check skipped");
            None
        }
    };
    let ops_text = match &ops_path {
        Some(p) => Some(std::fs::read_to_string(p)?),
        None => {
            eprintln!("pallas-lint: note: no docs/OPERATIONS.md — \
                       operator knob-table check skipped");
            None
        }
    };

    if write {
        let report = lint::check_tree(&root, None, design_text.as_deref(),
                                      ops_text.as_deref())?;
        let path = baseline_path
            .unwrap_or_else(|| PathBuf::from("lint_baseline.toml"));
        std::fs::write(&path, baseline::render(&report.panic_counts))?;
        println!("pallas-lint: wrote {} ({} file(s) with frozen sites)",
                 path.display(), report.panic_counts.len());
        for d in &report.diagnostics {
            println!("{d}");
        }
        return Ok(report.diagnostics.is_empty());
    }

    let base = match &baseline_path {
        Some(p) => baseline::load(p)?,
        None => {
            eprintln!("pallas-lint: note: no baseline file — the hot \
                       path must be panic-free");
            baseline::Baseline::default()
        }
    };
    let report = lint::check_tree(&root, Some(&base),
                                  design_text.as_deref(),
                                  ops_text.as_deref())?;
    for d in &report.diagnostics {
        println!("{d}");
    }
    if report.diagnostics.is_empty() {
        println!("pallas-lint: clean ({} file(s) checked)", report.files);
        Ok(true)
    } else {
        eprintln!("pallas-lint: {} finding(s)", report.diagnostics.len());
        Ok(false)
    }
}
