//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! Everything in the workload generators, the mini property-testing
//! framework and the schedulers' jitter derives from this one generator so
//! every run is reproducible from a seed.

/// xoshiro256** — fast, high-quality, tiny; seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough bound for our use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Derive an independent generator (for splitting work deterministically).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
