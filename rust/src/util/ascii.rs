//! ASCII rendering: markdown tables, block-pattern heatmaps and simple
//! line charts. The paper's figures are regenerated as text artifacts
//! (CSV + ASCII) since the harness is terminal-only.

/// Render a markdown table. `align_right` applies to all non-first columns.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&headers.join(" | "));
    out.push_str(" |\n|");
    for (i, _) in headers.iter().enumerate() {
        out.push_str(if i == 0 { "---|" } else { "---:|" });
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Heatmap of a row-major matrix using a density ramp (dark = large).
/// Used for Figure 2-style attention-pattern dumps.
pub fn heatmap(data: &[f32], rows: usize, cols: usize) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    assert_eq!(data.len(), rows * cols);
    let max = data.iter().copied().fold(f32::MIN, f32::max).max(1e-30);
    let mut out = String::with_capacity(rows * (cols + 1));
    for r in 0..rows {
        for c in 0..cols {
            let v = (data[r * cols + c] / max).clamp(0.0, 1.0);
            let i = ((v * (RAMP.len() - 1) as f32).round()) as usize;
            out.push(RAMP[i] as char);
        }
        out.push('\n');
    }
    out
}

/// Binary block-mask rendering: `#` computed, `.` skipped, ` ` above diag.
pub fn mask_map(mask: &[bool], nb: usize) -> String {
    let mut out = String::new();
    for i in 0..nb {
        for j in 0..nb {
            out.push(if j > i {
                ' '
            } else if mask[i * nb + j] {
                '#'
            } else {
                '.'
            });
        }
        out.push('\n');
    }
    out
}

/// Minimal multi-series line chart on a character grid; series are labeled
/// a, b, c… and scaled to the global y range. x values are implicit ranks.
pub fn line_chart(series: &[(&str, Vec<f64>)], width: usize, height: usize)
                  -> String {
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for (_, ys) in series {
        for &y in ys {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !ymin.is_finite() || ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![b' '; width * height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let n = ys.len().max(2);
        for (i, &y) in ys.iter().enumerate() {
            let x = i * (width - 1) / (n - 1);
            let fy = (y - ymin) / (ymax - ymin);
            let row = height - 1 - (fy * (height - 1) as f64).round() as usize;
            grid[row * width + x] = b'a' + (si as u8 % 26);
        }
    }
    let mut out = String::new();
    out.push_str(&format!("  ymax={ymax:.3}\n"));
    for r in 0..height {
        out.push_str("  |");
        out.push_str(std::str::from_utf8(&grid[r * width..(r + 1) * width])
            .unwrap());
        out.push('\n');
    }
    out.push_str(&format!("  +{} ymin={ymin:.3}\n", "-".repeat(width)));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {}={}\n", (b'a' + si as u8) as char, name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let t = markdown_table(&["a", "b"],
                               &[vec!["1".into(), "2".into()]]);
        assert!(t.starts_with("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn heatmap_dims() {
        let h = heatmap(&[0.0, 1.0, 0.5, 0.25], 2, 2);
        assert_eq!(h.lines().count(), 2);
        assert!(h.contains('@'));
    }

    #[test]
    fn mask_map_triangle() {
        let m = mask_map(&[true, false, true, true], 2);
        let lines: Vec<&str> = m.lines().collect();
        assert_eq!(lines[0], "# ");
        assert_eq!(lines[1], "##");
    }

    #[test]
    fn chart_renders_all_series() {
        let c = line_chart(&[("x", vec![0.0, 1.0]), ("y", vec![1.0, 0.0])],
                           20, 5);
        assert!(c.contains('a') && c.contains('b'));
        assert!(c.contains("a=x"));
    }
}
