//! Scoped wall-clock timers + a named stage profiler used to attribute
//! prefill time to pattern-search / attention / projection stages (the
//! §Perf breakdowns in EXPERIMENTS.md come from this).

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

/// Accumulates time per named stage. Cheap enough for the hot path
/// (one `Instant::now()` pair per scope).
#[derive(Debug, Default, Clone)]
pub struct StageProfiler {
    totals_us: BTreeMap<&'static str, u64>,
    counts: BTreeMap<&'static str, u64>,
}

impl StageProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&mut self, stage: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        *self.totals_us.entry(stage).or_default() +=
            t.elapsed().as_micros() as u64;
        *self.counts.entry(stage).or_default() += 1;
        out
    }

    pub fn add_us(&mut self, stage: &'static str, us: u64) {
        *self.totals_us.entry(stage).or_default() += us;
        *self.counts.entry(stage).or_default() += 1;
    }

    pub fn total_us(&self, stage: &str) -> u64 {
        self.totals_us.get(stage).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &StageProfiler) {
        for (k, v) in &other.totals_us {
            *self.totals_us.entry(k).or_default() += v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += v;
        }
    }

    /// Markdown table of stage → total ms / calls / mean µs, sorted by time.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.totals_us.iter().collect();
        rows.sort_by_key(|(_, v)| std::cmp::Reverse(**v));
        let mut out = String::from(
            "| stage | total ms | calls | mean µs |\n|---|---:|---:|---:|\n");
        for (k, v) in rows {
            let n = self.counts[k];
            out.push_str(&format!(
                "| {} | {:.2} | {} | {:.1} |\n",
                k,
                **&v / 1000,
                n,
                *v as f64 / n as f64
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_us() >= 2000);
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = StageProfiler::new();
        p.add_us("attn", 100);
        p.add_us("attn", 50);
        p.add_us("probe", 10);
        assert_eq!(p.total_us("attn"), 150);
        assert_eq!(p.total_us("probe"), 10);
        assert_eq!(p.total_us("missing"), 0);
        let rep = p.report();
        assert!(rep.contains("attn") && rep.contains("probe"));
    }

    #[test]
    fn profiler_merge() {
        let mut a = StageProfiler::new();
        a.add_us("x", 5);
        let mut b = StageProfiler::new();
        b.add_us("x", 7);
        a.merge(&b);
        assert_eq!(a.total_us("x"), 12);
    }
}
