//! Math helpers shared by the pattern engine: numerically-stable softmax,
//! KL / Jensen–Shannon divergence, top-k and cumulative-mass selection.
//!
//! These implement the scalar machinery of the paper's Algorithms 2, 3, 5:
//! `softmax` turns block-averaged QK values (Ã) into block-averaged
//! attention scores; `js_distance` is the sparsity / similarity test
//! (Alg. 3 line 6); `cumulative_select` is the minimal-budget selection
//! (`min { k : Σ a[I[1:k]] >= γ }`) used by both pivotal-pattern
//! construction (Alg. 2) and vertical-slash search (Alg. 5).

pub const NEG_INF: f32 = f32::NEG_INFINITY;

/// In-place numerically-stable softmax over a slice; `-inf` entries get 0.
/// A fully `-inf` slice becomes all-zero (not NaN).
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().copied().fold(NEG_INF, f32::max);
    if !m.is_finite() {
        xs.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = if x.is_finite() { (*x - m).exp() } else { 0.0 };
        sum += *x;
    }
    if sum > 0.0 {
        xs.iter_mut().for_each(|x| *x /= sum);
    }
}

pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let mut v = xs.to_vec();
    softmax_inplace(&mut v);
    v
}

/// Normalize a non-negative slice to sum 1 (no-op on all-zero input).
pub fn normalize(xs: &mut [f32]) {
    let s: f32 = xs.iter().sum();
    if s > 0.0 {
        xs.iter_mut().for_each(|x| *x /= s);
    }
}

/// KL(p ‖ q) with the 0·log(0/·) = 0 convention; q entries are floored to
/// avoid infinities from empirical zeros.
fn kl(p: &[f32], q: &[f32]) -> f64 {
    let eps = 1e-12f64;
    p.iter()
        .zip(q)
        .filter(|(pi, _)| **pi > 0.0)
        .map(|(pi, qi)| {
            let pi = *pi as f64;
            let qi = (*qi as f64).max(eps);
            pi * (pi / qi).ln()
        })
        .sum()
}

/// Jensen–Shannon *divergence* (natural log): `0 ≤ JSD ≤ ln 2`.
pub fn js_divergence(p: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let m: Vec<f32> = p.iter().zip(q).map(|(a, b)| 0.5 * (a + b)).collect();
    0.5 * kl(p, &m) + 0.5 * kl(q, &m)
}

/// The paper's distance: `sqrt(JSD(p ‖ q))` (Alg. 3 line 6), normalized by
/// `sqrt(ln 2)` so thresholds τ, δ live in [0, 1] like the JS *distance*
/// literature (and the paper's τ=0.2 / δ=0.3 defaults) expect.
pub fn js_distance(p: &[f32], q: &[f32]) -> f64 {
    (js_divergence(p, q) / std::f64::consts::LN_2).max(0.0).sqrt()
}

/// Uniform distribution of length n.
pub fn uniform(n: usize) -> Vec<f32> {
    vec![1.0 / n as f32; n]
}

/// Indices sorted by value descending.
pub fn argsort_desc(xs: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

/// Minimal prefix of the descending-sorted indices whose mass reaches
/// `gamma * total`; returns the selected indices. Always selects at least
/// one element when the slice is non-empty with positive mass.
pub fn cumulative_select(xs: &[f32], gamma: f32) -> Vec<usize> {
    let total: f32 = xs.iter().filter(|x| x.is_finite()).sum();
    if total <= 0.0 {
        return Vec::new();
    }
    let order = argsort_desc(xs);
    let mut acc = 0.0f32;
    let mut out = Vec::new();
    for i in order {
        if !xs[i].is_finite() || xs[i] <= 0.0 {
            break;
        }
        out.push(i);
        acc += xs[i];
        if acc >= gamma * total {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let s = softmax(&[1.0, 2.0, 3.0]);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn softmax_handles_neg_inf() {
        let s = softmax(&[0.0, NEG_INF, 0.0]);
        assert_eq!(s[1], 0.0);
        assert!((s[0] - 0.5).abs() < 1e-6);
        let z = softmax(&[NEG_INF, NEG_INF]);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_large_values_stable() {
        let s = softmax(&[1000.0, 1000.0]);
        assert!((s[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn jsd_bounds_and_symmetry() {
        let p = [0.7, 0.2, 0.1];
        let q = [0.1, 0.2, 0.7];
        let d1 = js_divergence(&p, &q);
        let d2 = js_divergence(&q, &p);
        assert!((d1 - d2).abs() < 1e-12);
        assert!(d1 > 0.0 && d1 <= std::f64::consts::LN_2 + 1e-9);
        assert!(js_divergence(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn js_distance_normalized() {
        // disjoint distributions hit the maximum: distance 1.0
        let p = [1.0, 0.0];
        let q = [0.0, 1.0];
        assert!((js_distance(&p, &q) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cumulative_select_minimal() {
        let xs = [0.5, 0.3, 0.15, 0.05];
        assert_eq!(cumulative_select(&xs, 0.5), vec![0]);
        assert_eq!(cumulative_select(&xs, 0.8), vec![0, 1]);
        assert_eq!(cumulative_select(&xs, 0.9), vec![0, 1, 2]);
        assert_eq!(cumulative_select(&xs, 1.0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn cumulative_select_ignores_neg_inf() {
        let xs = [NEG_INF, 1.0, NEG_INF, 1.0];
        let sel = cumulative_select(&xs, 0.9);
        assert_eq!(sel.len(), 2);
        assert!(sel.contains(&1) && sel.contains(&3));
    }

    #[test]
    fn argsort_desc_orders() {
        assert_eq!(argsort_desc(&[0.1, 0.9, 0.5]), vec![1, 2, 0]);
    }
}
