//! Math helpers shared by the pattern engine: numerically-stable softmax,
//! KL / Jensen–Shannon divergence, top-k and cumulative-mass selection.
//!
//! These implement the scalar machinery of the paper's Algorithms 2, 3, 5:
//! `softmax` turns block-averaged QK values (Ã) into block-averaged
//! attention scores; `js_distance` is the sparsity / similarity test
//! (Alg. 3 line 6); `cumulative_select` is the minimal-budget selection
//! (`min { k : Σ a[I[1:k]] >= γ }`) used by both pivotal-pattern
//! construction (Alg. 2) and vertical-slash search (Alg. 5);
//! `threshold_select` is the sort-free FlashPrefill-style variant that
//! calibrates the same γ knob to a per-score threshold.

pub const NEG_INF: f32 = f32::NEG_INFINITY;

/// In-place numerically-stable softmax over a slice; `-inf` entries get 0.
/// A fully `-inf` slice becomes all-zero (not NaN).
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().copied().fold(NEG_INF, f32::max);
    if !m.is_finite() {
        xs.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = if x.is_finite() { (*x - m).exp() } else { 0.0 };
        sum += *x;
    }
    if sum > 0.0 {
        xs.iter_mut().for_each(|x| *x /= sum);
    }
}

pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let mut v = xs.to_vec();
    softmax_inplace(&mut v);
    v
}

/// Normalize a non-negative slice to sum 1 (no-op on all-zero input).
pub fn normalize(xs: &mut [f32]) {
    let s: f32 = xs.iter().sum();
    if s > 0.0 {
        xs.iter_mut().for_each(|x| *x /= s);
    }
}

/// KL(p ‖ q) with the 0·log(0/·) = 0 convention; q entries are floored to
/// avoid infinities from empirical zeros.
fn kl(p: &[f32], q: &[f32]) -> f64 {
    let eps = 1e-12f64;
    p.iter()
        .zip(q)
        .filter(|(pi, _)| **pi > 0.0)
        .map(|(pi, qi)| {
            let pi = *pi as f64;
            let qi = (*qi as f64).max(eps);
            pi * (pi / qi).ln()
        })
        .sum()
}

/// Jensen–Shannon *divergence* (natural log): `0 ≤ JSD ≤ ln 2`.
pub fn js_divergence(p: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let m: Vec<f32> = p.iter().zip(q).map(|(a, b)| 0.5 * (a + b)).collect();
    0.5 * kl(p, &m) + 0.5 * kl(q, &m)
}

/// The paper's distance: `sqrt(JSD(p ‖ q))` (Alg. 3 line 6), normalized by
/// `sqrt(ln 2)` so thresholds τ, δ live in [0, 1] like the JS *distance*
/// literature (and the paper's τ=0.2 / δ=0.3 defaults) expect.
pub fn js_distance(p: &[f32], q: &[f32]) -> f64 {
    (js_divergence(p, q) / std::f64::consts::LN_2).max(0.0).sqrt()
}

/// Uniform distribution of length n.
pub fn uniform(n: usize) -> Vec<f32> {
    vec![1.0 / n as f32; n]
}

/// Indices sorted by value descending.
pub fn argsort_desc(xs: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

/// Minimal prefix of the descending-sorted indices whose mass reaches
/// `gamma * total`; returns the selected indices. Always selects at least
/// one element when the slice is non-empty with positive mass.
///
/// Partial selection, not a full `argsort_desc`: positive entries are
/// packed into `(!value_bits, index)` u64 keys whose ascending order is
/// exactly the stable descending argsort order (positive-f32 bit
/// patterns are monotone in value; the low index word breaks ties the
/// way a stable sort does).  A threshold prepass bounds where the γ-stop
/// can land — every entry below `(1-γ)·total/len` together carries less
/// than `(1-γ)·total` mass, so the selection fits inside the at-least-θ
/// head — and only that head is partitioned (`select_nth_unstable`) and
/// sorted.  The accumulation visits the same values in the same order as
/// the full sort did, so the output is bit-identical (property-tested
/// against the reference below).
pub fn cumulative_select(xs: &[f32], gamma: f32) -> Vec<usize> {
    let total: f32 = xs.iter().filter(|x| x.is_finite()).sum();
    if total <= 0.0 {
        return Vec::new();
    }
    let target = gamma * total;
    let theta = (1.0 - gamma) * total / xs.len() as f32;
    let mut keys: Vec<u64> = Vec::with_capacity(xs.len());
    let mut head = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_finite() && x > 0.0 {
            keys.push((((x.to_bits() ^ u32::MAX) as u64) << 32) | i as u64);
            if x >= theta {
                head += 1;
            }
        }
    }
    let n = keys.len();
    if n == 0 {
        return Vec::new();
    }
    let head = head.clamp(1, n);
    if head < n {
        keys.select_nth_unstable(head - 1);
    }
    keys[..head].sort_unstable();
    let mut sorted_to = head;
    let mut out = Vec::with_capacity(head);
    let mut acc = 0.0f32;
    let mut pos = 0usize;
    while pos < n {
        if pos == sorted_to {
            // The prepass bound holds in exact arithmetic; if f32
            // rounding makes the running sum miss the target inside the
            // head, finish over the (already partitioned-away) tail.
            keys[sorted_to..].sort_unstable();
            sorted_to = n;
        }
        let i = (keys[pos] & 0xFFFF_FFFF) as usize;
        out.push(i);
        acc += xs[i];
        if acc >= target {
            break;
        }
        pos += 1;
    }
    out
}

/// Thresholded selection (FlashPrefill, arxiv 2603.06199): keep every
/// index whose value meets the calibrated threshold
/// `θ(γ) = (1-γ)·total/len` — one branch per entry, no sort, no
/// cumulative scan.  Calibration: each rejected entry carries less than
/// θ, so the rejected mass stays below `len·θ = (1-γ)·total` and the
/// kept set always covers ≥ γ of the mass — the same guarantee
/// `cumulative_select` meets by sorting, traded for a denser selection
/// on flat distributions (in exact arithmetic the kept set is a
/// superset of the minimal cumulative-γ prefix).  Indices return in
/// ascending order.
pub fn threshold_select(xs: &[f32], gamma: f32) -> Vec<usize> {
    let total: f32 = xs.iter().filter(|x| x.is_finite()).sum();
    if total <= 0.0 {
        return Vec::new();
    }
    let theta = (1.0 - gamma) * total / xs.len() as f32;
    let mut out = Vec::new();
    for (i, &x) in xs.iter().enumerate() {
        if x.is_finite() && x > 0.0 && x >= theta {
            out.push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let s = softmax(&[1.0, 2.0, 3.0]);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn softmax_handles_neg_inf() {
        let s = softmax(&[0.0, NEG_INF, 0.0]);
        assert_eq!(s[1], 0.0);
        assert!((s[0] - 0.5).abs() < 1e-6);
        let z = softmax(&[NEG_INF, NEG_INF]);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_large_values_stable() {
        let s = softmax(&[1000.0, 1000.0]);
        assert!((s[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn jsd_bounds_and_symmetry() {
        let p = [0.7, 0.2, 0.1];
        let q = [0.1, 0.2, 0.7];
        let d1 = js_divergence(&p, &q);
        let d2 = js_divergence(&q, &p);
        assert!((d1 - d2).abs() < 1e-12);
        assert!(d1 > 0.0 && d1 <= std::f64::consts::LN_2 + 1e-9);
        assert!(js_divergence(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn js_distance_normalized() {
        // disjoint distributions hit the maximum: distance 1.0
        let p = [1.0, 0.0];
        let q = [0.0, 1.0];
        assert!((js_distance(&p, &q) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cumulative_select_minimal() {
        let xs = [0.5, 0.3, 0.15, 0.05];
        assert_eq!(cumulative_select(&xs, 0.5), vec![0]);
        assert_eq!(cumulative_select(&xs, 0.8), vec![0, 1]);
        assert_eq!(cumulative_select(&xs, 0.9), vec![0, 1, 2]);
        assert_eq!(cumulative_select(&xs, 1.0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn cumulative_select_ignores_neg_inf() {
        let xs = [NEG_INF, 1.0, NEG_INF, 1.0];
        let sel = cumulative_select(&xs, 0.9);
        assert_eq!(sel.len(), 2);
        assert!(sel.contains(&1) && sel.contains(&3));
    }

    #[test]
    fn argsort_desc_orders() {
        assert_eq!(argsort_desc(&[0.1, 0.9, 0.5]), vec![1, 2, 0]);
    }

    /// The pre-optimization `cumulative_select`: full stable argsort +
    /// linear scan.  Kept verbatim as the equivalence oracle.
    fn cumulative_select_reference(xs: &[f32], gamma: f32) -> Vec<usize> {
        let total: f32 = xs.iter().filter(|x| x.is_finite()).sum();
        if total <= 0.0 {
            return Vec::new();
        }
        let order = argsort_desc(xs);
        let mut acc = 0.0f32;
        let mut out = Vec::new();
        for i in order {
            if !xs[i].is_finite() || xs[i] <= 0.0 {
                break;
            }
            out.push(i);
            acc += xs[i];
            if acc >= gamma * total {
                break;
            }
        }
        out
    }

    /// Seeded random input with ties (values quantized to 1/8 steps),
    /// zeros, and -inf holes — the shapes probe maps actually take.
    fn gen_xs(g: &mut crate::util::proptest::Gen) -> Vec<f32> {
        let n = g.usize_in(1..200);
        (0..n)
            .map(|_| match g.usize_in(0..8) {
                0 => NEG_INF,
                1 => 0.0,
                _ => (g.f32_in(0.0, 4.0) * 8.0).round() / 8.0,
            })
            .collect()
    }

    #[test]
    fn prop_partial_select_bit_identical_to_reference() {
        crate::util::proptest::property(
            "cumulative_select == full-argsort reference", 200, |g| {
                let xs = gen_xs(g);
                for gamma in [0.0, 0.3, 0.65, 0.9, 0.99, 1.0] {
                    assert_eq!(cumulative_select(&xs, gamma),
                               cumulative_select_reference(&xs, gamma),
                               "xs={xs:?} gamma={gamma}");
                }
            });
    }

    #[test]
    fn prop_threshold_select_covers_gamma() {
        crate::util::proptest::property(
            "threshold_select covers >= gamma of the mass", 200, |g| {
                let xs = gen_xs(g);
                let gamma = g.f32_in(0.0, 1.0);
                let sel = threshold_select(&xs, gamma);
                let total: f32 =
                    xs.iter().filter(|x| x.is_finite()).sum();
                if total <= 0.0 {
                    assert!(sel.is_empty());
                    return;
                }
                let covered: f32 = sel.iter().map(|&i| xs[i]).sum();
                assert!(covered >= gamma * total - 1e-3 * total.abs(),
                        "covered {covered} < {gamma} * {total}");
                // ascending, deduplicated, in range, positive entries
                assert!(sel.windows(2).all(|w| w[0] < w[1]));
                assert!(sel.iter().all(|&i| xs[i] > 0.0));
            });
    }

    #[test]
    fn threshold_select_supersets_cumulative() {
        let xs = [0.5, 0.3, 0.15, 0.05];
        for gamma in [0.5, 0.8, 0.9, 1.0] {
            let cum = cumulative_select(&xs, gamma);
            let thr = threshold_select(&xs, gamma);
            assert!(cum.iter().all(|i| thr.contains(i)),
                    "gamma={gamma}: {thr:?} must cover {cum:?}");
        }
        // γ=1 keeps every positive entry, like the cumulative path
        assert_eq!(threshold_select(&xs, 1.0), vec![0, 1, 2, 3]);
        // -inf and zeros are never selected
        assert_eq!(threshold_select(&[NEG_INF, 1.0, 0.0, 1.0], 0.9),
                   vec![1, 3]);
    }
}
