//! Summary statistics + latency histogram used by the metrics pipeline and
//! the bench harness.

/// Streaming summary of a set of f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Merge another summary's samples into this one (fleet metrics
    /// aggregation: percentiles over the union, not a mean of means).
    pub fn absorb(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Fixed-bucket log-scale histogram for latencies (µs granularity).
#[derive(Debug, Clone)]
pub struct Histogram {
    // bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { buckets: vec![0; 40], count: 0, sum_us: 0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(39);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Merge another histogram bucket-for-bucket (fleet metrics
    /// aggregation; both sides share the fixed log-bucket layout).
    pub fn absorb(&mut self, other: &Histogram) {
        for (b, n) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += n;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }

    /// Upper bound (µs) of the bucket containing the q-quantile.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 40
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.p50() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(10.0);
        assert!((s.percentile(25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 40, 80, 160, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn histogram_absorb_merges_buckets() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for us in [10u64, 1000] {
            a.record_us(us);
        }
        for us in [20u64, 5000, 80] {
            b.record_us(us);
        }
        let mut merged = Histogram::new();
        for us in [10u64, 1000, 20, 5000, 80] {
            merged.record_us(us);
        }
        a.absorb(&b);
        assert_eq!(a.count(), merged.count());
        assert_eq!(a.mean_us(), merged.mean_us());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(a.quantile_us(q), merged.quantile_us(q));
        }
    }

    #[test]
    fn summary_absorb_merges_samples() {
        let mut a = Summary::new();
        a.add(1.0);
        a.add(2.0);
        let mut b = Summary::new();
        b.add(10.0);
        a.absorb(&b);
        assert_eq!(a.count(), 3);
        assert!((a.mean() - 13.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.max(), 10.0);
        // absorbing an empty summary is a no-op
        a.absorb(&Summary::new());
        assert_eq!(a.count(), 3);
    }
}
