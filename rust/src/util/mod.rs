//! Small shared utilities: deterministic RNG, summary statistics, timers,
//! math helpers (softmax / JS divergence), ASCII rendering, and a mini
//! property-testing framework (the offline vendor set has no `proptest`;
//! see DESIGN.md "Substitutions").

pub mod ascii;
pub mod math;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
