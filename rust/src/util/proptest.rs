//! Mini property-testing framework (the offline vendor set has no
//! `proptest`; DESIGN.md documents the substitution).
//!
//! Usage:
//! ```no_run
//! use shareprefill::util::proptest::{property, Gen};
//! property("sorted stays sorted", 200, |g: &mut Gen| {
//!     let mut v = g.vec_usize(0..50, 0..100);
//!     v.sort_unstable();
//!     for w in v.windows(2) { assert!(w[0] <= w[1]); }
//! });
//! ```
//!
//! On failure the property panics with the seed of the failing case so it
//! can be replayed deterministically (`Gen::from_seed`). Shrinking is
//! deliberately out of scope — cases are kept small by construction.

use super::rng::Rng;
use std::ops::Range;

/// Random-input generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.rng.range(r.start, r.end)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_usize(&mut self, len: Range<usize>, val: Range<usize>)
                     -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(val.clone())).collect()
    }

    pub fn vec_f32(&mut self, len: Range<usize>, lo: f32, hi: f32)
                   -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// A probability distribution of length n (non-negative, sums to 1).
    pub fn distribution(&mut self, n: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..n).map(|_| self.rng.f32() + 1e-6).collect();
        let s: f32 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    }
}

/// Run `cases` random cases of `f`. Panics (with the failing seed) on the
/// first failure. Base seed is derived from the property name so adding
/// properties doesn't perturb existing ones.
pub fn property(name: &str, cases: u64, mut f: impl FnMut(&mut Gen)) {
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::from_seed(seed);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = res {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (replay seed \
                 {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn properties_run_all_cases() {
        let mut n = 0;
        property("counter", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn distribution_sums_to_one() {
        property("distribution sums", 50, |g| {
            let n = g.rng.range(1, 20);
            let d = g.distribution(n);
            assert!((d.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            assert!(d.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failure_reports_seed() {
        property("always fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn deterministic_given_name() {
        let mut a = Vec::new();
        property("det", 5, |g| a.push(g.rng.next_u64()));
        let mut b = Vec::new();
        property("det", 5, |g| b.push(g.rng.next_u64()));
        assert_eq!(a, b);
    }
}
