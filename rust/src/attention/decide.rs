//! Algorithm 3 — **Determine Sparse Pattern** — and Algorithm 4's
//! dense-bootstrap rule.
//!
//! Per head: compare the probe distribution â (block-pooled last-row-block
//! attention) against (a) the uniform distribution — the *sparsity* test
//! `d_sparse = sqrt(JSD(â ‖ u))` — and (b) the cluster's pivotal
//! representative ã — the *similarity* test `d_sim = sqrt(JSD(â ‖ ã))`.
//!
//! * noise cluster, or `d_sparse ≥ δ` (highly sparse head, excluded for
//!   efficiency) → conservative vertical-slash pattern;
//! * pivot exists and `d_sim < τ` → share the pivotal pattern;
//! * pivot exists but dissimilar → vertical-slash;
//! * no pivot yet → this head runs **dense** and becomes the cluster's
//!   pivot (Alg. 4: "assign a dense pattern to the first head").

use super::pivotal::PivotalDict;
use crate::util::math::{js_distance, uniform};

/// Outcome of the per-head pattern decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Compute full attention; construct + publish the pivotal pattern.
    Dense,
    /// Reuse the cluster's pivotal mask.
    SharedPivot,
    /// Fall back to vertical-slash search.
    VSlash,
}

/// Diagnostic record of one decision (drives Figure 6 and the metrics).
#[derive(Debug, Clone)]
pub struct DecisionInfo {
    pub decision: Decision,
    pub d_sparse: f64,
    pub d_sim: Option<f64>,
    pub cluster: Option<usize>,
}

/// Apply Algorithm 3 for one head.
///
/// * `ahat` — probe distribution over kv blocks (sums to 1).
/// * `cluster` — offline cluster id; `None` = noise cluster.
/// * `dict` — the evolving pivotal dictionary.
pub fn decide_pattern(ahat: &[f32], cluster: Option<usize>,
                      dict: &PivotalDict, delta: f64, tau: f64)
                      -> DecisionInfo {
    let u = uniform(ahat.len());
    let d_sparse = js_distance(ahat, &u);
    let Some(c) = cluster else {
        return DecisionInfo {
            decision: Decision::VSlash, d_sparse, d_sim: None, cluster: None,
        };
    };
    // Highly sparse heads are excluded from sharing: full attention on them
    // is not cost-effective, and vslash approximates them well (§5.2).
    if d_sparse >= delta {
        return DecisionInfo {
            decision: Decision::VSlash, d_sparse, d_sim: None,
            cluster: Some(c),
        };
    }
    match dict.get(&c) {
        Some(entry) => {
            // Guard against bucket-length mismatch (cannot happen within one
            // prefill; defensive for reuse across requests).
            if entry.ahat_last.len() != ahat.len() {
                return DecisionInfo {
                    decision: Decision::VSlash, d_sparse, d_sim: None,
                    cluster: Some(c),
                };
            }
            let d_sim = js_distance(ahat, &entry.ahat_last);
            let decision = if d_sim < tau {
                Decision::SharedPivot
            } else {
                Decision::VSlash
            };
            DecisionInfo { decision, d_sparse, d_sim: Some(d_sim),
                           cluster: Some(c) }
        }
        None => DecisionInfo {
            decision: Decision::Dense, d_sparse, d_sim: None,
            cluster: Some(c),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::pivotal::PivotalEntry;
    use crate::attention::BlockMask;

    fn peaked(n: usize, at: usize) -> Vec<f32> {
        let mut v = vec![0.01 / (n - 1) as f32; n];
        v[at] = 0.99;
        v
    }

    fn flat(n: usize) -> Vec<f32> {
        vec![1.0 / n as f32; n]
    }

    fn dict_with(c: usize, ahat: Vec<f32>) -> PivotalDict {
        let nb = ahat.len();
        let mut d = PivotalDict::new();
        d.insert(c, PivotalEntry {
            ahat_last: ahat,
            mask: BlockMask::dense(nb),
            source: (0, 0),
        });
        d
    }

    #[test]
    fn noise_cluster_goes_vslash() {
        let info = decide_pattern(&flat(8), None, &PivotalDict::new(),
                                  0.3, 0.2);
        assert_eq!(info.decision, Decision::VSlash);
        assert!(info.cluster.is_none());
    }

    #[test]
    fn first_head_in_cluster_goes_dense() {
        let info = decide_pattern(&flat(8), Some(3), &PivotalDict::new(),
                                  0.3, 0.2);
        assert_eq!(info.decision, Decision::Dense);
    }

    #[test]
    fn similar_head_shares() {
        let dict = dict_with(1, flat(8));
        let info = decide_pattern(&flat(8), Some(1), &dict, 0.3, 0.2);
        assert_eq!(info.decision, Decision::SharedPivot);
        assert!(info.d_sim.unwrap() < 1e-6);
    }

    #[test]
    fn dissimilar_head_falls_back() {
        let dict = dict_with(1, peaked(8, 0));
        // flat â vs peaked ã: very different, but flat is NOT highly sparse
        let info = decide_pattern(&flat(8), Some(1), &dict, 0.9, 0.1);
        assert_eq!(info.decision, Decision::VSlash);
        assert!(info.d_sim.unwrap() > 0.1);
    }

    #[test]
    fn highly_sparse_head_excluded() {
        // peaked â = far from uniform = highly sparse -> vslash even though
        // the dict has an identical pivot (δ gate comes first)
        let dict = dict_with(1, peaked(8, 2));
        let info = decide_pattern(&peaked(8, 2), Some(1), &dict, 0.3, 0.9);
        assert_eq!(info.decision, Decision::VSlash);
        assert!(info.d_sparse >= 0.3);
        assert!(info.d_sim.is_none());
    }

    #[test]
    fn delta_above_one_disables_exclusion() {
        // the paper's "w/o exclusion" ablation: δ=1.01 (d_sparse ≤ 1 always)
        let dict = dict_with(1, peaked(8, 2));
        let info = decide_pattern(&peaked(8, 2), Some(1), &dict, 1.01, 0.9);
        assert_eq!(info.decision, Decision::SharedPivot);
    }

    #[test]
    fn tau_zero_disables_sharing() {
        // the paper's "w/o sharing" ablation: τ=0 → nothing passes d_sim<τ…
        let dict = dict_with(1, flat(8));
        let info = decide_pattern(&flat(8), Some(1), &dict, 1.01, 0.0);
        assert_eq!(info.decision, Decision::VSlash);
    }

    #[test]
    fn mismatched_pivot_length_is_safe() {
        let dict = dict_with(1, flat(4));
        let info = decide_pattern(&flat(8), Some(1), &dict, 1.01, 0.5);
        assert_eq!(info.decision, Decision::VSlash);
    }
}
