//! Causal block-sparse pattern: per query row-block, the set of kv
//! blocks to compute.  This is the paper's mask `M` at block granularity,
//! plus the packing that turns it into the L1 kernel's `(idx, valid)`
//! budget tensors.
//!
//! Rows are packed `u64` bitset words (bit `j & 63` of word `j >> 6` =
//! kv block `j` computed): insert/contains are one OR/AND, union and
//! jaccard are word-wise OR/AND + popcount, and pack walks set bits with
//! `trailing_zeros`.  The observable semantics are identical to the
//! earlier sorted-`Vec<u32>` row representation — equivalence
//! property-tested below against a verbatim copy of it.

use crate::exec::WorkerPool;
use crate::runtime::Tensor;

/// Block-sparse causal mask over an `nb × nb` grid.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockMask {
    pub nb: usize,
    /// `u64` words per row (`ceil(nb / 64)`).
    wpr: usize,
    /// `nb * wpr` words, row-major; only causal bits (`col <= row`) set.
    bits: Vec<u64>,
}

/// Bits of row word `w` whose columns are causal (`col <= row`).
fn causal_word(row: usize, w: usize) -> u64 {
    let lo = w << 6;
    if row < lo {
        0
    } else if row - lo >= 63 {
        u64::MAX
    } else {
        (1u64 << (row - lo + 1)) - 1
    }
}

impl BlockMask {
    /// Words needed per row for an `nb`-wide grid.
    pub(crate) fn words_per_row(nb: usize) -> usize {
        nb.div_ceil(64)
    }

    pub fn empty(nb: usize) -> Self {
        let wpr = Self::words_per_row(nb);
        BlockMask { nb, wpr, bits: vec![0u64; nb * wpr] }
    }

    /// Full causal (dense) pattern: row i computes blocks 0..=i.
    pub fn dense(nb: usize) -> Self {
        let mut m = BlockMask::empty(nb);
        for i in 0..nb {
            let base = i * m.wpr;
            for w in 0..m.wpr {
                m.bits[base + w] = causal_word(i, w);
            }
        }
        m
    }

    /// Build from an iterator of (row, col) pairs; clamps to causal.
    pub fn from_pairs(nb: usize, pairs: impl IntoIterator<Item = (usize, usize)>)
                      -> Self {
        let mut m = BlockMask::empty(nb);
        for (i, j) in pairs {
            m.insert(i, j);
        }
        m
    }

    /// Insert block (row, col); ignored if above the diagonal or OOB.
    pub fn insert(&mut self, row: usize, col: usize) {
        if row >= self.nb || col > row {
            return;
        }
        self.bits[row * self.wpr + (col >> 6)] |= 1u64 << (col & 63);
    }

    pub fn contains(&self, row: usize, col: usize) -> bool {
        if row >= self.nb || col >= self.nb {
            return false;
        }
        self.bits[row * self.wpr + (col >> 6)] & (1u64 << (col & 63)) != 0
    }

    /// Sorted kv-block indices of one row, materialized from the bitset
    /// words.  Callers are cold paths (metrics, cache validation,
    /// rendering, tests); the hot paths stay word-level.
    pub fn row(&self, i: usize) -> Vec<u32> {
        let base = i * self.wpr;
        let mut out = Vec::new();
        for w in 0..self.wpr {
            let mut word = self.bits[base + w];
            while word != 0 {
                out.push(((w as u32) << 6) | word.trailing_zeros());
                word &= word - 1;
            }
        }
        out
    }

    /// Ensure every row contains its diagonal block (self-attention is
    /// always computed — keeps softmax well-defined for every query).
    pub fn ensure_diagonal(&mut self) {
        for i in 0..self.nb {
            self.bits[i * self.wpr + (i >> 6)] |= 1u64 << (i & 63);
        }
    }

    /// Union in-place with another mask of the same grid.
    pub fn union(&mut self, other: &BlockMask) {
        assert_eq!(self.nb, other.nb);
        for (a, b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= *b;
        }
    }

    /// OR a full row of bitset words in, clamped to the causal prefix
    /// `col <= row` — the word-granular entry point the closed-form
    /// vslash mask construction builds rows with.
    pub(crate) fn or_row_words(&mut self, row: usize, words: &[u64]) {
        debug_assert_eq!(words.len(), self.wpr);
        let base = row * self.wpr;
        for w in 0..self.wpr {
            self.bits[base + w] |= words[w] & causal_word(row, w);
        }
    }

    /// Number of computed blocks.
    pub fn count(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    fn row_count(&self, i: usize) -> usize {
        self.bits[i * self.wpr..(i + 1) * self.wpr]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    /// Max row population — determines the budget bucket.
    pub fn max_row(&self) -> usize {
        (0..self.nb).map(|i| self.row_count(i)).max().unwrap_or(0)
    }

    /// Fraction of the causal lower triangle that is computed.
    pub fn density(&self) -> f64 {
        let total = self.nb * (self.nb + 1) / 2;
        self.count() as f64 / total.max(1) as f64
    }

    /// Jaccard similarity of computed-block sets (paper Figure 2b metric:
    /// |intersection| / |union| — robust to the many zeros in sparse maps).
    pub fn jaccard(&self, other: &BlockMask) -> f64 {
        assert_eq!(self.nb, other.nb);
        let mut inter = 0u64;
        let mut uni = 0u64;
        for (a, b) in self.bits.iter().zip(&other.bits) {
            inter += (a & b).count_ones() as u64;
            uni += (a | b).count_ones() as u64;
        }
        if uni == 0 {
            1.0
        } else {
            inter as f64 / uni as f64
        }
    }

    /// Pack into the L1 kernel's `(idx, valid)` tensors at `budget` slots
    /// per row.  Rows with more than `budget` live blocks are truncated
    /// keeping the **latest** blocks (the local/diagonal end carries the
    /// most attention mass under causal masking); rows with fewer are
    /// padded with `valid = 0` (idx repeats the row's diagonal, harmless).
    pub fn pack(&self, budget: usize) -> (Tensor, Tensor) {
        let nb = self.nb;
        let mut idx = vec![0i32; nb * budget];
        let mut valid = vec![0f32; nb * budget];
        for i in 0..nb {
            // skip the lowest (len - budget) set bits, word-at-a-time
            let mut skip = self.row_count(i).saturating_sub(budget);
            let mut s = 0usize;
            let base = i * self.wpr;
            for w in 0..self.wpr {
                let mut word = self.bits[base + w];
                let pop = word.count_ones() as usize;
                if skip >= pop {
                    skip -= pop;
                    continue;
                }
                while word != 0 {
                    let j = (w << 6) | word.trailing_zeros() as usize;
                    word &= word - 1;
                    if skip > 0 {
                        skip -= 1;
                        continue;
                    }
                    idx[i * budget + s] = j as i32;
                    valid[i * budget + s] = 1.0;
                    s += 1;
                }
            }
            // pad remaining slots with the diagonal index (masked out)
            for slot in s..budget {
                idx[i * budget + slot] = i as i32;
            }
        }
        (Tensor::i32(vec![nb, budget], idx),
         Tensor::f32(vec![nb, budget], valid))
    }

    /// Flatten to a row-major boolean grid (for rendering / features).
    pub fn to_grid(&self) -> Vec<bool> {
        let mut g = vec![false; self.nb * self.nb];
        for i in 0..self.nb {
            for j in self.row(i) {
                g[i * self.nb + j as usize] = true;
            }
        }
        g
    }
}

/// Head-sliced entry point: one [`BlockMask::pack`] per `(mask, budget)`
/// job, fanned out across the pool with head-indexed result slots —
/// the per-head packing that precedes every budgeted L1 kernel call.
pub fn pack_heads(pool: &WorkerPool, jobs: &[(&BlockMask, usize)])
                  -> Vec<(Tensor, Tensor)> {
    pool.fan_out(jobs.len(), |k| {
        let (mask, budget) = jobs[k];
        mask.pack(budget)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    #[test]
    fn dense_counts() {
        let m = BlockMask::dense(4);
        assert_eq!(m.count(), 10);
        assert_eq!(m.max_row(), 4);
        assert!((m.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn insert_respects_causality() {
        let mut m = BlockMask::empty(4);
        m.insert(1, 3); // above diagonal -> ignored
        assert_eq!(m.count(), 0);
        m.insert(3, 1);
        assert!(m.contains(3, 1));
        m.insert(3, 1); // dedup
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn jaccard_basics() {
        let a = BlockMask::dense(4);
        assert!((a.jaccard(&a) - 1.0).abs() < 1e-12);
        let b = BlockMask::empty(4);
        assert_eq!(a.jaccard(&b), 0.0);
        assert_eq!(b.jaccard(&b), 1.0); // empty vs empty
    }

    #[test]
    fn pack_roundtrip() {
        let m = BlockMask::from_pairs(4, [(0, 0), (2, 0), (2, 2), (3, 1)]);
        let (idx, valid) = m.pack(2);
        let idx = idx.as_i32().unwrap().to_vec();
        let valid = valid.as_f32().unwrap().to_vec();
        // row 2: blocks {0, 2}
        assert_eq!(&idx[4..6], &[0, 2]);
        assert_eq!(&valid[4..6], &[1.0, 1.0]);
        // row 1: nothing
        assert_eq!(&valid[2..4], &[0.0, 0.0]);
        // row 3: one block
        assert_eq!(idx[6], 1);
        assert_eq!(valid[7], 0.0);
    }

    #[test]
    fn pack_truncates_keeping_latest() {
        let m = BlockMask::dense(4);
        let (idx, valid) = m.pack(2);
        let idx = idx.as_i32().unwrap();
        // row 3 has 4 blocks, keeps {2, 3}
        assert_eq!(&idx[6..8], &[2, 3]);
        assert!(valid.as_f32().unwrap()[6..8].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn union_monotone() {
        let mut a = BlockMask::from_pairs(4, [(1, 0)]);
        let b = BlockMask::from_pairs(4, [(2, 1), (1, 0)]);
        a.union(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn prop_pack_valid_entries_match_mask() {
        property("pack validity", 100, |g: &mut Gen| {
            let nb = g.usize_in(1..12);
            let mut m = BlockMask::empty(nb);
            for _ in 0..g.usize_in(0..30) {
                let i = g.usize_in(0..nb);
                let j = g.usize_in(0..nb);
                m.insert(i, j);
            }
            let budget = g.usize_in(1..nb + 1);
            let (idx, valid) = m.pack(budget);
            let idx = idx.as_i32().unwrap();
            let valid = valid.as_f32().unwrap();
            for i in 0..nb {
                for s in 0..budget {
                    let v = valid[i * budget + s];
                    let j = idx[i * budget + s] as usize;
                    assert!(j < nb);
                    if v > 0.0 {
                        assert!(m.contains(i, j),
                                "valid slot not in mask ({i},{j})");
                        assert!(j <= i, "causality violated");
                    }
                }
                // all live slots present when budget suffices
                if m.row(i).len() <= budget {
                    let live = valid[i * budget..(i + 1) * budget]
                        .iter().filter(|&&v| v > 0.0).count();
                    assert_eq!(live, m.row(i).len());
                }
            }
        });
    }

    #[test]
    fn prop_jaccard_bounds_and_symmetry() {
        property("jaccard bounds", 100, |g: &mut Gen| {
            let nb = g.usize_in(1..10);
            let mut a = BlockMask::empty(nb);
            let mut b = BlockMask::empty(nb);
            for _ in 0..g.usize_in(0..20) {
                let (i, j) = (g.usize_in(0..nb), g.usize_in(0..nb));
                if g.bool() {
                    a.insert(i, j);
                } else {
                    b.insert(i, j);
                }
            }
            let jab = a.jaccard(&b);
            let jba = b.jaccard(&a);
            assert!((jab - jba).abs() < 1e-12);
            assert!((0.0..=1.0).contains(&jab));
            assert!((a.jaccard(&a) - 1.0).abs() < 1e-12);
        });
    }

    // ------------------------------------------------------------------
    // Equivalence against the pre-bitset representation
    // ------------------------------------------------------------------

    /// Verbatim copy of the sorted-`Vec<u32>`-rows `BlockMask` this
    /// bitset representation replaced — the equivalence oracle.
    struct RefMask {
        nb: usize,
        rows: Vec<Vec<u32>>,
    }

    impl RefMask {
        fn empty(nb: usize) -> Self {
            RefMask { nb, rows: vec![Vec::new(); nb] }
        }

        fn insert(&mut self, row: usize, col: usize) {
            if row >= self.nb || col > row {
                return;
            }
            let r = &mut self.rows[row];
            match r.binary_search(&(col as u32)) {
                Ok(_) => {}
                Err(pos) => r.insert(pos, col as u32),
            }
        }

        fn union(&mut self, other: &RefMask) {
            for i in 0..self.nb {
                for &j in &other.rows[i] {
                    self.insert(i, j as usize);
                }
            }
        }

        fn pack(&self, budget: usize) -> (Vec<i32>, Vec<f32>) {
            let nb = self.nb;
            let mut idx = vec![0i32; nb * budget];
            let mut valid = vec![0f32; nb * budget];
            for i in 0..nb {
                let r = &self.rows[i];
                let keep = if r.len() > budget {
                    &r[r.len() - budget..]
                } else {
                    &r[..]
                };
                for (s, &j) in keep.iter().enumerate() {
                    idx[i * budget + s] = j as i32;
                    valid[i * budget + s] = 1.0;
                }
                for s in keep.len()..budget {
                    idx[i * budget + s] = i as i32;
                }
            }
            (idx, valid)
        }
    }

    /// Random op sequences drive the bitset and Vec representations in
    /// lockstep; every observable (rows, count, contains, pack tensors,
    /// jaccard) must agree exactly.  `nb` runs past 64 so multi-word
    /// rows and word boundaries are exercised.
    #[test]
    fn prop_bitset_matches_vec_reference() {
        property("bitset == vec reference", 60, |g: &mut Gen| {
            let nb = g.usize_in(1..100);
            let mut m = BlockMask::empty(nb);
            let mut r = RefMask::empty(nb);
            for _ in 0..g.usize_in(0..120) {
                let (i, j) = (g.usize_in(0..nb), g.usize_in(0..nb));
                m.insert(i, j);
                r.insert(i, j);
            }
            if g.bool() {
                let mut m2 = BlockMask::empty(nb);
                let mut r2 = RefMask::empty(nb);
                for _ in 0..g.usize_in(0..40) {
                    let (i, j) = (g.usize_in(0..nb), g.usize_in(0..nb));
                    m2.insert(i, j);
                    r2.insert(i, j);
                }
                m.union(&m2);
                r.union(&r2);
            }
            if g.bool() {
                m.ensure_diagonal();
                for i in 0..nb {
                    r.insert(i, i);
                }
            }
            assert_eq!(m.count(),
                       r.rows.iter().map(Vec::len).sum::<usize>());
            assert_eq!(m.max_row(),
                       r.rows.iter().map(Vec::len).max().unwrap_or(0));
            for i in 0..nb {
                assert_eq!(m.row(i), r.rows[i], "row {i} diverged");
            }
            for _ in 0..30 {
                let (i, j) = (g.usize_in(0..nb), g.usize_in(0..nb));
                assert_eq!(m.contains(i, j),
                           r.rows[i].binary_search(&(j as u32)).is_ok());
            }
            let budget = g.usize_in(1..nb + 1);
            let (idx, valid) = m.pack(budget);
            let (ridx, rvalid) = r.pack(budget);
            assert_eq!(idx.as_i32().unwrap(), &ridx[..]);
            assert_eq!(valid.as_f32().unwrap(), &rvalid[..]);
        });
    }
}
