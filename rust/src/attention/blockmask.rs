//! Causal block-sparse pattern: per query row-block, the sorted set of kv
//! blocks to compute.  This is the paper's mask `M` at block granularity,
//! plus the packing that turns it into the L1 kernel's `(idx, valid)`
//! budget tensors.

use crate::exec::WorkerPool;
use crate::runtime::Tensor;

/// Block-sparse causal mask over an `nb × nb` grid.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockMask {
    pub nb: usize,
    /// Sorted, deduped kv-block indices per row-block; all entries `<= row`.
    rows: Vec<Vec<u32>>,
}

impl BlockMask {
    pub fn empty(nb: usize) -> Self {
        BlockMask { nb, rows: vec![Vec::new(); nb] }
    }

    /// Full causal (dense) pattern: row i computes blocks 0..=i.
    pub fn dense(nb: usize) -> Self {
        BlockMask {
            nb,
            rows: (0..nb).map(|i| (0..=i as u32).collect()).collect(),
        }
    }

    /// Build from an iterator of (row, col) pairs; clamps to causal.
    pub fn from_pairs(nb: usize, pairs: impl IntoIterator<Item = (usize, usize)>)
                      -> Self {
        let mut m = BlockMask::empty(nb);
        for (i, j) in pairs {
            m.insert(i, j);
        }
        m
    }

    /// Insert block (row, col); ignored if above the diagonal or OOB.
    pub fn insert(&mut self, row: usize, col: usize) {
        if row >= self.nb || col > row {
            return;
        }
        let r = &mut self.rows[row];
        match r.binary_search(&(col as u32)) {
            Ok(_) => {}
            Err(pos) => r.insert(pos, col as u32),
        }
    }

    pub fn contains(&self, row: usize, col: usize) -> bool {
        self.rows[row].binary_search(&(col as u32)).is_ok()
    }

    pub fn row(&self, i: usize) -> &[u32] {
        &self.rows[i]
    }

    /// Ensure every row contains its diagonal block (self-attention is
    /// always computed — keeps softmax well-defined for every query).
    pub fn ensure_diagonal(&mut self) {
        for i in 0..self.nb {
            self.insert(i, i);
        }
    }

    /// Union in-place with another mask of the same grid.
    pub fn union(&mut self, other: &BlockMask) {
        assert_eq!(self.nb, other.nb);
        for i in 0..self.nb {
            for &j in &other.rows[i] {
                self.insert(i, j as usize);
            }
        }
    }

    /// Number of computed blocks.
    pub fn count(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Max row population — determines the budget bucket.
    pub fn max_row(&self) -> usize {
        self.rows.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Fraction of the causal lower triangle that is computed.
    pub fn density(&self) -> f64 {
        let total = self.nb * (self.nb + 1) / 2;
        self.count() as f64 / total.max(1) as f64
    }

    /// Jaccard similarity of computed-block sets (paper Figure 2b metric:
    /// |intersection| / |union| — robust to the many zeros in sparse maps).
    pub fn jaccard(&self, other: &BlockMask) -> f64 {
        assert_eq!(self.nb, other.nb);
        let mut inter = 0usize;
        let mut uni = 0usize;
        for i in 0..self.nb {
            let a = &self.rows[i];
            let b = &other.rows[i];
            let (mut x, mut y) = (0usize, 0usize);
            while x < a.len() && y < b.len() {
                match a[x].cmp(&b[y]) {
                    std::cmp::Ordering::Equal => {
                        inter += 1;
                        uni += 1;
                        x += 1;
                        y += 1;
                    }
                    std::cmp::Ordering::Less => {
                        uni += 1;
                        x += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        uni += 1;
                        y += 1;
                    }
                }
            }
            uni += a.len() - x + b.len() - y;
        }
        if uni == 0 {
            1.0
        } else {
            inter as f64 / uni as f64
        }
    }

    /// Pack into the L1 kernel's `(idx, valid)` tensors at `budget` slots
    /// per row.  Rows with more than `budget` live blocks are truncated
    /// keeping the **latest** blocks (the local/diagonal end carries the
    /// most attention mass under causal masking); rows with fewer are
    /// padded with `valid = 0` (idx repeats the row's diagonal, harmless).
    pub fn pack(&self, budget: usize) -> (Tensor, Tensor) {
        let nb = self.nb;
        let mut idx = vec![0i32; nb * budget];
        let mut valid = vec![0f32; nb * budget];
        for i in 0..nb {
            let r = &self.rows[i];
            let keep = if r.len() > budget {
                &r[r.len() - budget..]
            } else {
                &r[..]
            };
            for (s, &j) in keep.iter().enumerate() {
                idx[i * budget + s] = j as i32;
                valid[i * budget + s] = 1.0;
            }
            // pad remaining slots with the diagonal index (masked out)
            for s in keep.len()..budget {
                idx[i * budget + s] = i as i32;
            }
        }
        (Tensor::i32(vec![nb, budget], idx),
         Tensor::f32(vec![nb, budget], valid))
    }

    /// Flatten to a row-major boolean grid (for rendering / features).
    pub fn to_grid(&self) -> Vec<bool> {
        let mut g = vec![false; self.nb * self.nb];
        for i in 0..self.nb {
            for &j in &self.rows[i] {
                g[i * self.nb + j as usize] = true;
            }
        }
        g
    }
}

/// Head-sliced entry point: one [`BlockMask::pack`] per `(mask, budget)`
/// job, fanned out across the pool with head-indexed result slots —
/// the per-head packing that precedes every budgeted L1 kernel call.
pub fn pack_heads(pool: &WorkerPool, jobs: &[(&BlockMask, usize)])
                  -> Vec<(Tensor, Tensor)> {
    pool.fan_out(jobs.len(), |k| {
        let (mask, budget) = jobs[k];
        mask.pack(budget)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    #[test]
    fn dense_counts() {
        let m = BlockMask::dense(4);
        assert_eq!(m.count(), 10);
        assert_eq!(m.max_row(), 4);
        assert!((m.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn insert_respects_causality() {
        let mut m = BlockMask::empty(4);
        m.insert(1, 3); // above diagonal -> ignored
        assert_eq!(m.count(), 0);
        m.insert(3, 1);
        assert!(m.contains(3, 1));
        m.insert(3, 1); // dedup
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn jaccard_basics() {
        let a = BlockMask::dense(4);
        assert!((a.jaccard(&a) - 1.0).abs() < 1e-12);
        let b = BlockMask::empty(4);
        assert_eq!(a.jaccard(&b), 0.0);
        assert_eq!(b.jaccard(&b), 1.0); // empty vs empty
    }

    #[test]
    fn pack_roundtrip() {
        let m = BlockMask::from_pairs(4, [(0, 0), (2, 0), (2, 2), (3, 1)]);
        let (idx, valid) = m.pack(2);
        let idx = idx.as_i32().unwrap().to_vec();
        let valid = valid.as_f32().unwrap().to_vec();
        // row 2: blocks {0, 2}
        assert_eq!(&idx[4..6], &[0, 2]);
        assert_eq!(&valid[4..6], &[1.0, 1.0]);
        // row 1: nothing
        assert_eq!(&valid[2..4], &[0.0, 0.0]);
        // row 3: one block
        assert_eq!(idx[6], 1);
        assert_eq!(valid[7], 0.0);
    }

    #[test]
    fn pack_truncates_keeping_latest() {
        let m = BlockMask::dense(4);
        let (idx, valid) = m.pack(2);
        let idx = idx.as_i32().unwrap();
        // row 3 has 4 blocks, keeps {2, 3}
        assert_eq!(&idx[6..8], &[2, 3]);
        assert!(valid.as_f32().unwrap()[6..8].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn union_monotone() {
        let mut a = BlockMask::from_pairs(4, [(1, 0)]);
        let b = BlockMask::from_pairs(4, [(2, 1), (1, 0)]);
        a.union(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn prop_pack_valid_entries_match_mask() {
        property("pack validity", 100, |g: &mut Gen| {
            let nb = g.usize_in(1..12);
            let mut m = BlockMask::empty(nb);
            for _ in 0..g.usize_in(0..30) {
                let i = g.usize_in(0..nb);
                let j = g.usize_in(0..nb);
                m.insert(i, j);
            }
            let budget = g.usize_in(1..nb + 1);
            let (idx, valid) = m.pack(budget);
            let idx = idx.as_i32().unwrap();
            let valid = valid.as_f32().unwrap();
            for i in 0..nb {
                for s in 0..budget {
                    let v = valid[i * budget + s];
                    let j = idx[i * budget + s] as usize;
                    assert!(j < nb);
                    if v > 0.0 {
                        assert!(m.contains(i, j),
                                "valid slot not in mask ({i},{j})");
                        assert!(j <= i, "causality violated");
                    }
                }
                // all live slots present when budget suffices
                if m.row(i).len() <= budget {
                    let live = valid[i * budget..(i + 1) * budget]
                        .iter().filter(|&&v| v > 0.0).count();
                    assert_eq!(live, m.row(i).len());
                }
            }
        });
    }

    #[test]
    fn prop_jaccard_bounds_and_symmetry() {
        property("jaccard bounds", 100, |g: &mut Gen| {
            let nb = g.usize_in(1..10);
            let mut a = BlockMask::empty(nb);
            let mut b = BlockMask::empty(nb);
            for _ in 0..g.usize_in(0..20) {
                let (i, j) = (g.usize_in(0..nb), g.usize_in(0..nb));
                if g.bool() {
                    a.insert(i, j);
                } else {
                    b.insert(i, j);
                }
            }
            let jab = a.jaccard(&b);
            let jba = b.jaccard(&a);
            assert!((jab - jba).abs() < 1e-12);
            assert!((0.0..=1.0).contains(&jab));
            assert!((a.jaccard(&a) - 1.0).abs() < 1e-12);
        });
    }
}
