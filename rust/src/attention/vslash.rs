//! Algorithm 5 — **Search Vertical Slash Pattern** (also the MInference
//! baseline's dynamic index, and SharePrefill's conservative fallback).
//!
//! Input: the softmaxed last-row-block attention map Â `[BS, S]` from the
//! vslash probe.  Vertical scores sum Â per key column; slash scores sum Â
//! per diagonal offset (qpos − kpos).  Each is normalized; the minimal
//! cumulative-γ prefix of each sorted list is selected; the union of the
//! chosen vertical columns and slash diagonals, mapped to block
//! granularity, forms the mask.

use crate::exec::WorkerPool;
use crate::util::math::cumulative_select;
use crate::BLOCK_SIZE;

use super::BlockMask;

/// Search a vertical-slash pattern from the probe map.
///
/// * `amap` — `[bs, seq]` row-softmaxed last-block attention.
/// * `seq` — sequence length; `nb = seq / BLOCK_SIZE`.
/// * `gamma` — cumulative attention threshold.
pub fn search_vslash(amap: &[f32], bs: usize, seq: usize, gamma: f32)
                     -> BlockMask {
    let nb = seq / BLOCK_SIZE;
    debug_assert_eq!(amap.len(), bs * seq);
    let q0 = seq - bs; // qpos of probe row 0

    // vertical: total mass per key position
    let mut vert = vec![0f32; seq];
    // slash: total mass per diagonal offset d = qpos - kpos ∈ [0, seq)
    let mut slash = vec![0f32; seq];
    for r in 0..bs {
        let qpos = q0 + r;
        let row = &amap[r * seq..(r + 1) * seq];
        for (kpos, &a) in row.iter().enumerate().take(qpos + 1) {
            vert[kpos] += a;
            slash[qpos - kpos] += a;
        }
    }
    let sel_v = cumulative_select(&vert, gamma);
    let sel_s = cumulative_select(&slash, gamma);

    let mut mask = BlockMask::empty(nb);
    // vertical token columns -> block columns, for every row-block at or
    // below which the column is causal
    for &col in &sel_v {
        let jb = col / BLOCK_SIZE;
        for i in jb..nb {
            mask.insert(i, jb);
        }
    }
    // slash offsets -> per row-block, the kv blocks its tokens reach at
    // that offset (the diagonal stripe crosses up to two blocks per row)
    for &d in &sel_s {
        for i in 0..nb {
            let row_lo = i * BLOCK_SIZE;
            let row_hi = row_lo + BLOCK_SIZE - 1;
            if row_hi < d {
                continue; // offset reaches above position 0 for all rows
            }
            let k_hi = row_hi - d;
            let jb_hi = k_hi / BLOCK_SIZE;
            mask.insert(i, jb_hi.min(i));
            if row_lo >= d {
                let jb_lo = (row_lo - d) / BLOCK_SIZE;
                mask.insert(i, jb_lo.min(i));
            }
        }
    }
    mask.ensure_diagonal();
    mask
}

/// Head-sliced entry point: one [`search_vslash`] per `(head, γ)` job,
/// fanned out across the pool with head-indexed result slots (result
/// `k` is always job `k`'s mask, so the worker count cannot reorder or
/// change anything).
///
/// * `amap` — the full `[H, bs, seq]` vslash probe, flattened.
/// * `jobs` — `(head index, gamma)` per head that needs a search.
pub fn search_vslash_heads(pool: &WorkerPool, amap: &[f32],
                           jobs: &[(usize, f32)], bs: usize, seq: usize)
                           -> Vec<BlockMask> {
    let per_head = bs * seq;
    pool.fan_out(jobs.len(), |k| {
        let (h, gamma) = jobs[k];
        let head_map = &amap[h * per_head..(h + 1) * per_head];
        search_vslash(head_map, bs, seq, gamma)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    /// Â with all mass on key column `col`.
    fn column_map(bs: usize, seq: usize, col: usize) -> Vec<f32> {
        let mut m = vec![0f32; bs * seq];
        for r in 0..bs {
            m[r * seq + col] = 1.0;
        }
        m
    }

    #[test]
    fn pure_vertical_selects_column_block() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        let m = column_map(bs, seq, 10); // block 0
        let mask = search_vslash(&m, bs, seq, 0.9);
        let nb = seq / BLOCK_SIZE;
        for i in 0..nb {
            assert!(mask.contains(i, 0), "vertical col missing at row {i}");
        }
    }

    #[test]
    fn pure_slash_selects_diagonal_stripe() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        // all mass on the self-position (offset 0 diagonal)
        let mut m = vec![0f32; bs * seq];
        let q0 = seq - bs;
        for r in 0..bs {
            m[r * seq + q0 + r] = 1.0;
        }
        let mask = search_vslash(&m, bs, seq, 0.9);
        let nb = seq / BLOCK_SIZE;
        for i in 0..nb {
            assert!(mask.contains(i, i), "diag missing at row {i}");
        }
        // offset-0 slash shouldn't light distant off-diagonal blocks
        assert!(!mask.contains(nb - 1, 1) || nb <= 2);
    }

    #[test]
    fn gamma_monotone_in_mask_size() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        let mut g = Gen::from_seed(9);
        let mut m = vec![0f32; bs * seq];
        let q0 = seq - bs;
        for r in 0..bs {
            for k in 0..=q0 + r {
                m[r * seq + k] = g.f32_in(0.0, 1.0);
            }
        }
        let small = search_vslash(&m, bs, seq, 0.5).count();
        let large = search_vslash(&m, bs, seq, 0.95).count();
        assert!(small <= large, "γ=0.5 -> {small}, γ=0.95 -> {large}");
    }

    #[test]
    fn prop_mask_causal_and_diagonal() {
        property("vslash causal+diag", 40, |g: &mut Gen| {
            let nbs = [2usize, 3, 4];
            let nb = nbs[g.usize_in(0..3)];
            let seq = nb * BLOCK_SIZE;
            let bs = BLOCK_SIZE;
            let q0 = seq - bs;
            let mut m = vec![0f32; bs * seq];
            for r in 0..bs {
                for k in 0..=q0 + r {
                    m[r * seq + k] = g.f32_in(0.0, 1.0);
                }
            }
            let gamma = g.f32_in(0.3, 0.99);
            let mask = search_vslash(&m, bs, seq, gamma);
            for i in 0..nb {
                assert!(mask.contains(i, i));
                for &j in mask.row(i) {
                    assert!((j as usize) <= i);
                }
            }
        });
    }

    #[test]
    fn head_fanout_matches_serial_per_head_searches() {
        use crate::util::proptest::Gen;
        let (bs, seq, heads) = (BLOCK_SIZE, 4 * BLOCK_SIZE, 5);
        let q0 = seq - bs;
        let mut g = Gen::from_seed(13);
        let mut amap = vec![0f32; heads * bs * seq];
        for h in 0..heads {
            for r in 0..bs {
                for k in 0..=q0 + r {
                    amap[h * bs * seq + r * seq + k] = g.f32_in(0.0, 1.0);
                }
            }
        }
        let jobs: Vec<(usize, f32)> =
            (0..heads).map(|h| (h, 0.5 + 0.1 * h as f32)).collect();
        let serial: Vec<BlockMask> = jobs.iter()
            .map(|&(h, gamma)| {
                search_vslash(&amap[h * bs * seq..(h + 1) * bs * seq],
                              bs, seq, gamma)
            })
            .collect();
        for workers in [1usize, 2, 4] {
            let pool = crate::exec::WorkerPool::new(workers);
            let got = search_vslash_heads(&pool, &amap, &jobs, bs, seq);
            assert_eq!(got, serial,
                       "fan-out at {workers} workers changed a mask");
        }
    }

    #[test]
    fn vertical_coverage_property() {
        // The union of selected vertical columns must cover >= γ of the
        // vertical mass (Alg. 5's selection invariant).
        let (bs, seq) = (BLOCK_SIZE, 3 * BLOCK_SIZE);
        let mut g = Gen::from_seed(11);
        let q0 = seq - bs;
        let mut m = vec![0f32; bs * seq];
        for r in 0..bs {
            for k in 0..=q0 + r {
                m[r * seq + k] = g.f32_in(0.0, 1.0);
            }
        }
        let gamma = 0.8f32;
        let mut vert = vec![0f32; seq];
        for r in 0..bs {
            for k in 0..=q0 + r {
                vert[k] += m[r * seq + k];
            }
        }
        let sel = cumulative_select(&vert, gamma);
        let total: f32 = vert.iter().sum();
        let covered: f32 = sel.iter().map(|&c| vert[c]).sum();
        assert!(covered >= gamma * total - 1e-3);
    }
}
