//! Algorithm 5 — **Search Vertical Slash Pattern** (also the MInference
//! baseline's dynamic index, and SharePrefill's conservative fallback).
//!
//! Input: the softmaxed last-row-block attention map Â `[BS, S]` from the
//! vslash probe.  Vertical scores sum Â per key column; slash scores sum Â
//! per diagonal offset (qpos − kpos).  Each is normalized; the minimal
//! cumulative-γ prefix of each sorted list is selected; the union of the
//! chosen vertical columns and slash diagonals, mapped to block
//! granularity, forms the mask.
//!
//! Mechanical-sympathy notes: the accumulation is one pass of two
//! sequential streams per probe row (no strided second write), and the
//! mask is built in closed form — a selected slash offset `d = db·B + r`
//! lights block `i − db` at every row-block `i ≥ db`, plus `i − db − 1`
//! when the stripe straddles a block boundary (`r > 0`), so the whole
//! slash family collapses to a small set of block-diagonal offsets OR'd
//! into each row as shifted bitset words.  `search_vslash_threshold` is
//! the FlashPrefill-style variant that swaps the cumulative-γ selection
//! for direct thresholding.

use crate::exec::WorkerPool;
use crate::util::math::{cumulative_select, threshold_select};
use crate::BLOCK_SIZE;

use super::BlockMask;

/// Search a vertical-slash pattern from the probe map.
///
/// * `amap` — `[bs, seq]` row-softmaxed last-block attention.
/// * `seq` — sequence length; `nb = seq / BLOCK_SIZE`.
/// * `gamma` — cumulative attention threshold.
pub fn search_vslash(amap: &[f32], bs: usize, seq: usize, gamma: f32)
                     -> BlockMask {
    let nb = seq / BLOCK_SIZE;
    debug_assert_eq!(amap.len(), bs * seq);
    let (vert, slash) = accumulate_vslash(amap, bs, seq);
    let sel_v = cumulative_select(&vert, gamma);
    let sel_s = cumulative_select(&slash, gamma);
    build_mask(nb, &sel_v, &sel_s)
}

/// FlashPrefill-style discovery (arxiv 2603.06199): the same probe
/// accumulation, but vertical columns and slash offsets are selected by
/// the calibrated threshold `θ(γ) = (1-γ)·mass/positions` instead of the
/// sorted cumulative-γ prefix — no sort, no cumulative scan, the same
/// ≥ γ coverage guarantee, a slightly denser selection on flat maps
/// (see [`threshold_select`]).
pub fn search_vslash_threshold(amap: &[f32], bs: usize, seq: usize,
                               gamma: f32) -> BlockMask {
    let nb = seq / BLOCK_SIZE;
    debug_assert_eq!(amap.len(), bs * seq);
    let (vert, slash) = accumulate_vslash(amap, bs, seq);
    let sel_v = threshold_select(&vert, gamma);
    let sel_s = threshold_select(&slash, gamma);
    build_mask(nb, &sel_v, &sel_s)
}

/// One cache-blocked pass over the probe map: vertical totals are a
/// streaming vector add, slash totals add each causal row prefix
/// reversed — two sequential (auto-vectorizable) streams per row instead
/// of one loop with a strided second write.  Each `(row, cell)` pair
/// contributes exactly once and rows accumulate in the same order as
/// the fused loop this replaces, so the totals are bit-identical.
fn accumulate_vslash(amap: &[f32], bs: usize, seq: usize)
                     -> (Vec<f32>, Vec<f32>) {
    let q0 = seq - bs; // qpos of probe row 0
    let mut vert = vec![0f32; seq];
    let mut slash = vec![0f32; seq];
    for r in 0..bs {
        let qpos = q0 + r;
        let row = &amap[r * seq..r * seq + qpos + 1];
        for (kpos, &a) in row.iter().enumerate() {
            vert[kpos] += a;
        }
        // reversed, index == diagonal offset d = qpos - kpos
        for (d, &a) in row.iter().rev().enumerate() {
            slash[d] += a;
        }
    }
    (vert, slash)
}

/// Closed-form mask construction from selected vertical token columns
/// and slash offsets.
///
/// Verticals collapse to a word-set of block columns, AND'ed with each
/// row's causal prefix.  A slash offset `d` (`d = db·B + r`) touches, at
/// row-block `i ≥ db`, block `i − db`, plus `i − db − 1` when `r > 0` —
/// so the selected offsets collapse to a set `S` of block-diagonal
/// lags, held bit-reversed so one word-level right shift per row lands
/// every lag `s ∈ S` on column `i − s`.
fn build_mask(nb: usize, sel_v: &[usize], sel_s: &[usize]) -> BlockMask {
    let wpr = BlockMask::words_per_row(nb);
    let wbits = wpr * 64;
    let mut vcols = vec![0u64; wpr];
    for &col in sel_v {
        let jb = col / BLOCK_SIZE;
        vcols[jb >> 6] |= 1u64 << (jb & 63);
    }
    // block-diagonal lag set, bit-reversed: lag s sits at bit wbits-1-s,
    // so `srev >> (wbits-1-i)` puts it at bit i-s (dropped when s > i)
    let mut srev = vec![0u64; wpr];
    for &d in sel_s {
        let db = d / BLOCK_SIZE;
        let p = wbits - 1 - db;
        srev[p >> 6] |= 1u64 << (p & 63);
        if d % BLOCK_SIZE > 0 && db + 1 < nb {
            let p = wbits - 2 - db;
            srev[p >> 6] |= 1u64 << (p & 63);
        }
    }
    let mut mask = BlockMask::empty(nb);
    let mut rowbuf = vec![0u64; wpr];
    for i in 0..nb {
        rowbuf.copy_from_slice(&vcols);
        shr_or(&srev, wbits - 1 - i, &mut rowbuf);
        // the diagonal block is always computed (self-attention keeps
        // softmax well-defined for every query)
        rowbuf[i >> 6] |= 1u64 << (i & 63);
        mask.or_row_words(i, &rowbuf);
    }
    mask
}

/// `dst |= src >> shift` over little-endian u64 words (word 0 holds
/// bits 0–63); both slices are the same length.
fn shr_or(src: &[u64], shift: usize, dst: &mut [u64]) {
    let n = src.len();
    let ws = shift >> 6;
    let bs = shift & 63;
    if bs == 0 {
        for w in 0..n - ws {
            dst[w] |= src[w + ws];
        }
    } else {
        for w in 0..n - ws {
            let lo = src[w + ws] >> bs;
            let hi = if w + ws + 1 < n {
                src[w + ws + 1] << (64 - bs)
            } else {
                0
            };
            dst[w] |= lo | hi;
        }
    }
}

/// Head-sliced entry point: one [`search_vslash`] per `(head, γ)` job,
/// fanned out across the pool with head-indexed result slots (result
/// `k` is always job `k`'s mask, so the worker count cannot reorder or
/// change anything).
///
/// * `amap` — the full `[H, bs, seq]` vslash probe, flattened.
/// * `jobs` — `(head index, gamma)` per head that needs a search.
pub fn search_vslash_heads(pool: &WorkerPool, amap: &[f32],
                           jobs: &[(usize, f32)], bs: usize, seq: usize)
                           -> Vec<BlockMask> {
    let per_head = bs * seq;
    pool.fan_out(jobs.len(), |k| {
        let (h, gamma) = jobs[k];
        let head_map = &amap[h * per_head..(h + 1) * per_head];
        search_vslash(head_map, bs, seq, gamma)
    })
}

/// Head-sliced [`search_vslash_threshold`]: same head-indexed fan-out
/// contract as [`search_vslash_heads`], thresholded selection.
pub fn search_vslash_threshold_heads(pool: &WorkerPool, amap: &[f32],
                                     jobs: &[(usize, f32)], bs: usize,
                                     seq: usize) -> Vec<BlockMask> {
    let per_head = bs * seq;
    pool.fan_out(jobs.len(), |k| {
        let (h, gamma) = jobs[k];
        let head_map = &amap[h * per_head..(h + 1) * per_head];
        search_vslash_threshold(head_map, bs, seq, gamma)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    /// Â with all mass on key column `col`.
    fn column_map(bs: usize, seq: usize, col: usize) -> Vec<f32> {
        let mut m = vec![0f32; bs * seq];
        for r in 0..bs {
            m[r * seq + col] = 1.0;
        }
        m
    }

    #[test]
    fn pure_vertical_selects_column_block() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        let m = column_map(bs, seq, 10); // block 0
        let mask = search_vslash(&m, bs, seq, 0.9);
        let nb = seq / BLOCK_SIZE;
        for i in 0..nb {
            assert!(mask.contains(i, 0), "vertical col missing at row {i}");
        }
    }

    #[test]
    fn pure_slash_selects_diagonal_stripe() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        // all mass on the self-position (offset 0 diagonal)
        let mut m = vec![0f32; bs * seq];
        let q0 = seq - bs;
        for r in 0..bs {
            m[r * seq + q0 + r] = 1.0;
        }
        let mask = search_vslash(&m, bs, seq, 0.9);
        let nb = seq / BLOCK_SIZE;
        for i in 0..nb {
            assert!(mask.contains(i, i), "diag missing at row {i}");
        }
        // offset-0 slash shouldn't light distant off-diagonal blocks
        assert!(!mask.contains(nb - 1, 1) || nb <= 2);
    }

    #[test]
    fn gamma_monotone_in_mask_size() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        let mut g = Gen::from_seed(9);
        let mut m = vec![0f32; bs * seq];
        let q0 = seq - bs;
        for r in 0..bs {
            for k in 0..=q0 + r {
                m[r * seq + k] = g.f32_in(0.0, 1.0);
            }
        }
        let small = search_vslash(&m, bs, seq, 0.5).count();
        let large = search_vslash(&m, bs, seq, 0.95).count();
        assert!(small <= large, "γ=0.5 -> {small}, γ=0.95 -> {large}");
    }

    #[test]
    fn prop_mask_causal_and_diagonal() {
        property("vslash causal+diag", 40, |g: &mut Gen| {
            let nbs = [2usize, 3, 4];
            let nb = nbs[g.usize_in(0..3)];
            let seq = nb * BLOCK_SIZE;
            let bs = BLOCK_SIZE;
            let q0 = seq - bs;
            let mut m = vec![0f32; bs * seq];
            for r in 0..bs {
                for k in 0..=q0 + r {
                    m[r * seq + k] = g.f32_in(0.0, 1.0);
                }
            }
            let gamma = g.f32_in(0.3, 0.99);
            for mask in [search_vslash(&m, bs, seq, gamma),
                         search_vslash_threshold(&m, bs, seq, gamma)] {
                for i in 0..nb {
                    assert!(mask.contains(i, i));
                    for j in mask.row(i) {
                        assert!((j as usize) <= i);
                    }
                }
            }
        });
    }

    #[test]
    fn head_fanout_matches_serial_per_head_searches() {
        use crate::util::proptest::Gen;
        let (bs, seq, heads) = (BLOCK_SIZE, 4 * BLOCK_SIZE, 5);
        let q0 = seq - bs;
        let mut g = Gen::from_seed(13);
        let mut amap = vec![0f32; heads * bs * seq];
        for h in 0..heads {
            for r in 0..bs {
                for k in 0..=q0 + r {
                    amap[h * bs * seq + r * seq + k] = g.f32_in(0.0, 1.0);
                }
            }
        }
        let jobs: Vec<(usize, f32)> =
            (0..heads).map(|h| (h, 0.5 + 0.1 * h as f32)).collect();
        let serial: Vec<BlockMask> = jobs.iter()
            .map(|&(h, gamma)| {
                search_vslash(&amap[h * bs * seq..(h + 1) * bs * seq],
                              bs, seq, gamma)
            })
            .collect();
        let serial_thr: Vec<BlockMask> = jobs.iter()
            .map(|&(h, gamma)| {
                search_vslash_threshold(
                    &amap[h * bs * seq..(h + 1) * bs * seq], bs, seq,
                    gamma)
            })
            .collect();
        for workers in [1usize, 2, 4] {
            let pool = crate::exec::WorkerPool::new(workers);
            let got = search_vslash_heads(&pool, &amap, &jobs, bs, seq);
            assert_eq!(got, serial,
                       "fan-out at {workers} workers changed a mask");
            let got = search_vslash_threshold_heads(&pool, &amap, &jobs,
                                                    bs, seq);
            assert_eq!(got, serial_thr,
                       "threshold fan-out at {workers} workers changed \
                        a mask");
        }
    }

    #[test]
    fn vertical_coverage_property() {
        // The union of selected vertical columns must cover >= γ of the
        // vertical mass (Alg. 5's selection invariant).
        let (bs, seq) = (BLOCK_SIZE, 3 * BLOCK_SIZE);
        let mut g = Gen::from_seed(11);
        let q0 = seq - bs;
        let mut m = vec![0f32; bs * seq];
        for r in 0..bs {
            for k in 0..=q0 + r {
                m[r * seq + k] = g.f32_in(0.0, 1.0);
            }
        }
        let gamma = 0.8f32;
        let mut vert = vec![0f32; seq];
        for r in 0..bs {
            for k in 0..=q0 + r {
                vert[k] += m[r * seq + k];
            }
        }
        let sel = cumulative_select(&vert, gamma);
        let total: f32 = vert.iter().sum();
        let covered: f32 = sel.iter().map(|&c| vert[c]).sum();
        assert!(covered >= gamma * total - 1e-3);
    }

    // ------------------------------------------------------------------
    // Equivalence against the pre-rewrite search
    // ------------------------------------------------------------------

    /// Verbatim copy of the pre-rewrite `search_vslash`: fused strided
    /// accumulation + per-offset × per-row-block stripe insertion.  The
    /// bit-identity oracle for the closed-form rewrite.
    fn search_vslash_reference(amap: &[f32], bs: usize, seq: usize,
                               gamma: f32) -> BlockMask {
        let nb = seq / BLOCK_SIZE;
        let q0 = seq - bs;
        let mut vert = vec![0f32; seq];
        let mut slash = vec![0f32; seq];
        for r in 0..bs {
            let qpos = q0 + r;
            let row = &amap[r * seq..(r + 1) * seq];
            for (kpos, &a) in row.iter().enumerate().take(qpos + 1) {
                vert[kpos] += a;
                slash[qpos - kpos] += a;
            }
        }
        let sel_v = cumulative_select(&vert, gamma);
        let sel_s = cumulative_select(&slash, gamma);
        let mut mask = BlockMask::empty(nb);
        for &col in &sel_v {
            let jb = col / BLOCK_SIZE;
            for i in jb..nb {
                mask.insert(i, jb);
            }
        }
        for &d in &sel_s {
            for i in 0..nb {
                let row_lo = i * BLOCK_SIZE;
                let row_hi = row_lo + BLOCK_SIZE - 1;
                if row_hi < d {
                    continue;
                }
                let k_hi = row_hi - d;
                let jb_hi = k_hi / BLOCK_SIZE;
                mask.insert(i, jb_hi.min(i));
                if row_lo >= d {
                    let jb_lo = (row_lo - d) / BLOCK_SIZE;
                    mask.insert(i, jb_lo.min(i));
                }
            }
        }
        mask.ensure_diagonal();
        mask
    }

    fn random_causal_map(g: &mut Gen, bs: usize, seq: usize) -> Vec<f32> {
        let q0 = seq - bs;
        let mut m = vec![0f32; bs * seq];
        for r in 0..bs {
            for k in 0..=q0 + r {
                // sparse holes keep the selection lists interesting
                m[r * seq + k] = if g.usize_in(0..4) == 0 {
                    0.0
                } else {
                    g.f32_in(0.0, 1.0)
                };
            }
        }
        m
    }

    #[test]
    fn prop_closed_form_bit_identical_to_reference() {
        property("closed-form vslash == reference", 20, |g: &mut Gen| {
            let nbs = [2usize, 3, 4, 7];
            let nb = nbs[g.usize_in(0..4)];
            let seq = nb * BLOCK_SIZE;
            let bs = BLOCK_SIZE;
            let m = random_causal_map(g, bs, seq);
            for gamma in [0.3, 0.65, 0.9, 1.0] {
                let got = search_vslash(&m, bs, seq, gamma);
                let want = search_vslash_reference(&m, bs, seq, gamma);
                assert_eq!(got, want, "nb={nb} gamma={gamma}");
            }
        });
    }

    /// Same oracle across the 64-block word boundary (multi-word rows:
    /// the shifted-lag construction must carry bits between words).
    #[test]
    fn closed_form_matches_reference_past_word_boundary() {
        let nb = 66;
        let seq = nb * BLOCK_SIZE;
        let bs = BLOCK_SIZE;
        let mut g = Gen::from_seed(29);
        let m = random_causal_map(&mut g, bs, seq);
        for gamma in [0.65, 0.9] {
            let got = search_vslash(&m, bs, seq, gamma);
            let want = search_vslash_reference(&m, bs, seq, gamma);
            assert_eq!(got, want, "gamma={gamma}");
        }
    }

    /// Thresholded discovery keeps the cumulative mask: its selections
    /// are supersets of the cumulative-γ prefixes, and the mask builder
    /// is monotone in its selection lists.
    #[test]
    fn threshold_mask_covers_cumulative_mask() {
        let (bs, seq) = (BLOCK_SIZE, 4 * BLOCK_SIZE);
        let nb = seq / BLOCK_SIZE;
        let mut g = Gen::from_seed(17);
        let m = random_causal_map(&mut g, bs, seq);
        for gamma in [0.5, 0.8, 0.9] {
            let cum = search_vslash(&m, bs, seq, gamma);
            let thr = search_vslash_threshold(&m, bs, seq, gamma);
            for i in 0..nb {
                for j in cum.row(i) {
                    assert!(thr.contains(i, j as usize),
                            "gamma={gamma}: cumulative block ({i},{j}) \
                             missing from thresholded mask");
                }
            }
        }
    }
}
