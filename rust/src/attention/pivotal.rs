//! Algorithm 2 — **Construct Pivotal Pattern** — and the evolving pivotal
//! pattern dictionary (Algorithm 4's storage).
//!
//! When a head runs with the *dense* pattern, its block-averaged QK map Ã
//! is complete.  We then: row-softmax Ã into block-averaged attention
//! scores, keep the last row as the pivotal representative ã (used for the
//! JS similarity check of Alg. 3), take the minimal flattened prefix whose
//! cumulative mass ≥ γ (the selection normalizes by the total internally,
//! so Alg. 2's explicit normalize pass is fused away), and store the
//! resulting block mask keyed by the head's cluster.

use std::collections::HashMap;

use crate::exec::WorkerPool;
use crate::util::math::{cumulative_select, softmax_inplace, NEG_INF};

use super::BlockMask;

/// Dictionary entry: the pivotal representative ã (last-row block-averaged
/// attention distribution) and the constructed mask M.
#[derive(Debug, Clone)]
pub struct PivotalEntry {
    pub ahat_last: Vec<f32>,
    pub mask: BlockMask,
    /// (layer, head) that produced this pivot — observability only.
    pub source: (usize, usize),
}

/// cluster id → pivotal entry.  Reset per request: patterns are
/// input-dependent (the paper's dictionary evolves during one prefill).
pub type PivotalDict = HashMap<usize, PivotalEntry>;

/// Construct a pivotal pattern from a *full* block-averaged QK map
/// (`abar[i*nb + j]`, `-inf` above the diagonal), per Algorithm 2.
///
/// Returns the entry; the caller stores it under the head's cluster id.
pub fn construct_pivotal(abar: &[f32], nb: usize, gamma: f32,
                         source: (usize, usize)) -> PivotalEntry {
    construct_pivotal_scratch(abar, nb, gamma, source, &mut Vec::new())
}

/// [`construct_pivotal`] with a caller-owned scratch buffer: the
/// softmaxed score map is built in `scratch` (cleared and refilled, no
/// per-call allocation), so the publish fan-out path constructing pivots
/// for many heads reuses one buffer across calls.
///
/// Algorithm 2's explicit flatten + normalize pass is fused away:
/// `cumulative_select` already normalizes by the map's total mass inside
/// its γ-stop (`acc >= γ·Σ`), so pre-dividing every score by the same
/// positive total selects the same prefix — the softmaxed scores feed
/// the selection directly and the nb² division pass disappears.
pub fn construct_pivotal_scratch(abar: &[f32], nb: usize, gamma: f32,
                                 source: (usize, usize),
                                 scratch: &mut Vec<f32>) -> PivotalEntry {
    debug_assert_eq!(abar.len(), nb * nb);
    // Row-softmax: Ã = softmax(block-averaged QK) per query row-block —
    // attention semantics at block granularity.
    scratch.clear();
    scratch.extend_from_slice(abar);
    let scores = &mut scratch[..];
    for i in 0..nb {
        softmax_inplace(&mut scores[i * nb..(i + 1) * nb]);
    }
    // Pivotal representative: last row.
    let ahat_last = scores[(nb - 1) * nb..].to_vec();
    // Minimal cumulative-γ selection over the flattened map.
    let selected = cumulative_select(scores, gamma);
    let mut mask = BlockMask::empty(nb);
    for flat in selected {
        mask.insert(flat / nb, flat % nb);
    }
    // Self-attention blocks must always be computed for well-defined rows.
    mask.ensure_diagonal();
    PivotalEntry { ahat_last, mask, source }
}

/// Assemble a full `[nb, nb]` abar map from a budgeted kernel output:
/// `abar_slots[i*budget + s]` corresponds to `idx[i*budget + s]`.
/// Unvisited blocks are `-inf`. Used when a head ran dense (budget == nb,
/// causal idx) or to scatter any sparse result for inspection.
pub fn scatter_abar(abar_slots: &[f32], idx: &[i32], valid: &[f32],
                    nb: usize, budget: usize) -> Vec<f32> {
    let mut full = vec![NEG_INF; nb * nb];
    for i in 0..nb {
        for s in 0..budget {
            let off = i * budget + s;
            if valid[off] > 0.0 && abar_slots[off].is_finite() {
                let j = idx[off] as usize;
                full[i * nb + j] = abar_slots[off];
            }
        }
    }
    full
}

/// Head-sliced entry point: one [`scatter_abar`] per publishing head,
/// fanned out with head-indexed result slots.  Each job is the head's
/// `(abar_slots, idx, valid, budget)` straight off the budgeted kernel
/// output; result `k` is always job `k`'s full `[nb, nb]` map.
pub fn scatter_abar_heads(pool: &WorkerPool, nb: usize,
                          jobs: &[(&[f32], &[i32], &[f32], usize)])
                          -> Vec<Vec<f32>> {
    pool.fan_out(jobs.len(), |k| {
        let (slots, idx, valid, budget) = jobs[k];
        scatter_abar(slots, idx, valid, nb, budget)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    fn uniform_map(nb: usize) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = 0.0;
            }
        }
        m
    }

    #[test]
    fn gamma_one_selects_everything_causal() {
        let nb = 4;
        let e = construct_pivotal(&uniform_map(nb), nb, 1.0, (0, 0));
        assert_eq!(e.mask.count(), nb * (nb + 1) / 2);
    }

    #[test]
    fn low_gamma_selects_few() {
        let nb = 4;
        let mut m = uniform_map(nb);
        // one dominant block per row
        for i in 0..nb {
            m[i * nb] = 10.0;
        }
        let e = construct_pivotal(&m, nb, 0.5, (1, 2));
        assert!(e.mask.density() < 1.0);
        // the dominant sink column dominates the selection: at least half
        // of the rows keep their sink block at γ=0.5
        let sinks = (1..nb).filter(|&i| e.mask.contains(i, 0)).count();
        assert!(sinks >= nb / 2 - 1, "only {sinks} sink blocks selected");
        assert_eq!(e.source, (1, 2));
    }

    #[test]
    fn ahat_last_is_distribution() {
        let nb = 5;
        let e = construct_pivotal(&uniform_map(nb), nb, 0.9, (0, 0));
        let s: f32 = e.ahat_last.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert_eq!(e.ahat_last.len(), nb);
    }

    #[test]
    fn diagonal_always_present() {
        let nb = 4;
        let mut m = uniform_map(nb);
        m[nb + 0] = 100.0; // row 1 mass entirely on block 0
        let e = construct_pivotal(&m, nb, 0.1, (0, 0));
        for i in 0..nb {
            assert!(e.mask.contains(i, i), "diag missing at {i}");
        }
    }

    #[test]
    fn scatter_roundtrip() {
        let nb = 3;
        let budget = 2;
        let idx = vec![0, 0, /*row0*/ 0, 1, /*row1*/ 1, 2 /*row2*/];
        let valid = vec![1., 0., 1., 1., 1., 1.];
        let slots = vec![0.5, 9.9, 0.1, 0.2, 0.3, 0.4];
        let full = scatter_abar(&slots, &idx, &valid, nb, budget);
        assert_eq!(full[0], 0.5);
        assert_eq!(full[nb], 0.1);
        assert_eq!(full[nb + 1], 0.2);
        assert_eq!(full[2 * nb + 1], 0.3);
        assert_eq!(full[2 * nb + 2], 0.4);
        assert_eq!(full[1], NEG_INF); // masked slot not scattered
    }

    /// One scratch buffer driven across many heads must reproduce the
    /// allocate-per-call wrapper exactly (masks, representative, source).
    #[test]
    fn scratch_reuse_matches_fresh_allocation() {
        let mut g = Gen::from_seed(23);
        let mut scratch = Vec::new();
        for head in 0..6 {
            let nb = g.usize_in(2..9);
            let mut m = vec![NEG_INF; nb * nb];
            for i in 0..nb {
                for j in 0..=i {
                    m[i * nb + j] = g.f32_in(-3.0, 3.0);
                }
            }
            let gamma = g.f32_in(0.3, 0.99);
            let fresh = construct_pivotal(&m, nb, gamma, (0, head));
            let reused = construct_pivotal_scratch(&m, nb, gamma,
                                                   (0, head),
                                                   &mut scratch);
            assert_eq!(fresh.mask, reused.mask);
            assert_eq!(fresh.ahat_last, reused.ahat_last);
            assert_eq!(fresh.source, reused.source);
        }
    }

    #[test]
    fn prop_selection_covers_gamma() {
        property("pivotal covers gamma", 60, |g: &mut Gen| {
            let nb = g.usize_in(2..9);
            let mut m = vec![NEG_INF; nb * nb];
            for i in 0..nb {
                for j in 0..=i {
                    m[i * nb + j] = g.f32_in(-3.0, 3.0);
                }
            }
            let gamma = g.f32_in(0.3, 0.99);
            let e = construct_pivotal(&m, nb, gamma, (0, 0));
            // recompute normalized score mass covered by the mask
            let mut scores = m.clone();
            for i in 0..nb {
                crate::util::math::softmax_inplace(
                    &mut scores[i * nb..(i + 1) * nb]);
            }
            let total: f32 = scores.iter().sum();
            let covered: f32 = (0..nb)
                .flat_map(|i| (0..=i).map(move |j| (i, j)))
                .filter(|&(i, j)| e.mask.contains(i, j))
                .map(|(i, j)| scores[i * nb + j])
                .sum();
            assert!(covered / total >= gamma - 1e-4,
                    "covered {} < gamma {}", covered / total, gamma);
        });
    }
}
