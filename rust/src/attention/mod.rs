//! The pattern engine: block masks, budget packing, and the paper's
//! Algorithms 2 (pivotal pattern construction), 3 (pattern decision),
//! 4 (sharing) and 5 (vertical-slash search).

pub mod blockmask;
pub mod decide;
pub mod pivotal;
pub mod vslash;

pub use blockmask::BlockMask;
pub use decide::{decide_pattern, Decision};
pub use pivotal::{construct_pivotal, PivotalDict, PivotalEntry};
pub use vslash::search_vslash;
