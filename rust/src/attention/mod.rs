//! The pattern engine: block masks, budget packing, and the paper's
//! Algorithms 2 (pivotal pattern construction), 3 (pattern decision),
//! 4 (sharing) and 5 (vertical-slash search).

pub mod blockmask;
pub mod decide;
pub mod pivotal;
pub mod vslash;

pub use blockmask::{pack_heads, BlockMask};
pub use decide::{decide_pattern, Decision};
pub use pivotal::{construct_pivotal, construct_pivotal_scratch,
                  scatter_abar, scatter_abar_heads, PivotalDict,
                  PivotalEntry};
pub use vslash::{search_vslash, search_vslash_heads,
                 search_vslash_threshold, search_vslash_threshold_heads};
