//! TOML-subset parser for the config system: `[section]` + `[section.sub]`
//! headers, `key = value` lines with string / number / bool / inline array
//! values, `#` comments.  Flattened into `section.key` → value, which is
//! what the typed config layer (`config::`) consumes.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Flattened key → value map.
#[derive(Debug, Default, Clone)]
pub struct Toml {
    pub entries: BTreeMap<String, Value>,
}

impl Toml {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str().ok().map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }
}

pub fn parse(src: &str) -> Result<Toml> {
    let mut out = Toml::default();
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let Some(name) = inner.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected 'key = value'", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.entries.insert(full, val);
    }
    Ok(out)
}

/// Serialize back to the flat subset this parser accepts: one dotted
/// `key = value` line per entry (a top-level `a.b = v` line flattens
/// to the same key as `[a]` + `b = v`), so `parse(&emit(t))` is
/// entry-identical to `t` for every document `parse` accepts — string
/// values out of `parse` can never contain `"` or newlines, and
/// arrays are always flat, which is exactly what the emitter handles.
pub fn emit(t: &Toml) -> String {
    let mut out = String::new();
    for (k, v) in &t.entries {
        out.push_str(k);
        out.push_str(" = ");
        emit_value(v, &mut out);
        out.push('\n');
    }
    out
}

fn emit_value(v: &Value, out: &mut String) {
    match v {
        Value::Str(s) => {
            out.push('"');
            out.push_str(s);
            out.push('"');
        }
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                emit_value(x, out);
            }
            out.push(']');
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(body) = inner.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let body = body.trim();
        if body.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        return Ok(Value::Arr(
            body.split(',')
                .map(|e| parse_value(e.trim()))
                .collect::<Result<Vec<_>>>()?,
        ));
    }
    match s.parse::<f64>() {
        Ok(n) => Ok(Value::Num(n)),
        Err(_) => bail!("cannot parse value '{s}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "demo"
count = 3

[method]
kind = "shareprefill"  # inline comment
tau = 0.2
delta = 0.3
share = true
buckets = [1, 2, 4]

[method.nested]
x = 1
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(SAMPLE).unwrap();
        assert_eq!(t.get("name").unwrap().as_str().unwrap(), "demo");
        assert_eq!(t.get("count").unwrap().as_usize().unwrap(), 3);
        assert_eq!(t.get("method.kind").unwrap().as_str().unwrap(),
                   "shareprefill");
        assert!((t.get("method.tau").unwrap().as_f64().unwrap() - 0.2).abs()
                < 1e-12);
        assert!(t.get("method.share").unwrap().as_bool().unwrap());
        assert_eq!(t.get("method.nested.x").unwrap().as_usize().unwrap(), 1);
        match t.get("method.buckets").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn defaults() {
        let t = parse("").unwrap();
        assert_eq!(t.str_or("x", "d"), "d");
        assert_eq!(t.usize_or("y", 7), 7);
        assert!((t.f64_or("z", 0.5) - 0.5).abs() < 1e-12);
        assert!(t.bool_or("b", true));
    }

    #[test]
    fn comment_inside_string_kept() {
        let t = parse("s = \"a#b\"").unwrap();
        assert_eq!(t.get("s").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn emit_roundtrips_parsed_documents() {
        let t1 = parse(SAMPLE).unwrap();
        let text = emit(&t1);
        let t2 = parse(&text).unwrap();
        assert_eq!(t1.entries, t2.entries);
        // flat dotted keys, sorted: stable output for diffs
        assert!(text.contains("method.nested.x = 1\n"));
        assert!(text.contains("name = \"demo\"\n"));
    }

    #[test]
    fn emit_value_forms() {
        let t = parse(
            "f = 0.25\ni = 3\nb = false\ns = \"a#b\"\na = [1, 2]\n")
            .unwrap();
        let t2 = parse(&emit(&t)).unwrap();
        assert_eq!(t.entries, t2.entries);
        assert!(emit(&t).contains("f = 0.25\n"));
        assert!(emit(&t).contains("i = 3\n"));
        assert!(emit(&t).contains("a = [1, 2]\n"));
    }

    #[test]
    fn errors() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @").is_err());
    }
}
