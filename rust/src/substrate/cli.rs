//! Hand-rolled CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`,
//! with typed accessors and a generated usage string.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I,
                                                 flag_names: &[&str])
                                                 -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        anyhow!("option --{name} expects a value")
                    })?;
                    out.options.insert(name.to_string(), v);
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow!("option --{name}: '{v}' is not an integer")
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow!("option --{name}: '{v}' is not a number")
            }),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.opt(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn subcommand(&self) -> Result<&str> {
        match &self.subcommand {
            Some(s) => Ok(s),
            None => bail!("missing subcommand"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string), &["verbose"])
            .unwrap()
    }

    #[test]
    fn subcommand_options_positionals() {
        let a = parse("serve --model sim-llama --port 8080 extra1 extra2");
        assert_eq!(a.subcommand().unwrap(), "serve");
        assert_eq!(a.opt("model").unwrap(), "sim-llama");
        assert_eq!(a.usize_or("port", 0).unwrap(), 8080);
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn flags_and_eq_syntax() {
        let a = parse("eval --verbose --tau=0.25");
        assert!(a.flag("verbose"));
        assert!((a.f64_or("tau", 0.0).unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn list_option() {
        let a = parse("eval --methods ours,flash");
        assert_eq!(a.list_or("methods", &[]), vec!["ours", "flash"]);
        assert_eq!(a.list_or("tasks", &["all"]), vec!["all"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["x".into(), "--model".into()], &[]).is_err());
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.require("missing").is_err());
    }
}
