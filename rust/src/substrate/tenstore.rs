//! Reader for the `tenstore` weight archive written by
//! `python/compile/tenstore.py` (format documented there): magic
//! `TENSTOR1`, u64-LE header length, JSON header, raw f32-LE payload.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use super::json;

/// One stored tensor: row-major f32 data + shape.
#[derive(Debug, Clone)]
pub struct StoredTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl StoredTensor {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The archive: name → tensor.
#[derive(Debug, Default)]
pub struct TenStore {
    pub tensors: BTreeMap<String, StoredTensor>,
}

impl TenStore {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let raw = std::fs::read(path.as_ref()).with_context(|| {
            format!("reading tenstore {:?}", path.as_ref())
        })?;
        Self::from_bytes(&raw)
    }

    pub fn from_bytes(raw: &[u8]) -> Result<Self> {
        if raw.len() < 16 || &raw[..8] != b"TENSTOR1" {
            bail!("bad tenstore magic");
        }
        let hlen = u64::from_le_bytes(raw[8..16].try_into().unwrap()) as usize;
        if 16 + hlen > raw.len() {
            bail!("truncated tenstore header");
        }
        let header = json::parse(std::str::from_utf8(&raw[16..16 + hlen])?)?;
        let base = 16 + hlen;
        let mut tensors = BTreeMap::new();
        for (name, meta) in header.req("tensors")?.as_obj()? {
            let dtype = meta.req("dtype")?.as_str()?;
            if dtype != "f32" {
                bail!("tensor '{name}': unsupported dtype {dtype}");
            }
            let shape = meta.req("shape")?.usize_list()?;
            let offset = meta.req("offset")?.as_usize()?;
            let nbytes = meta.req("nbytes")?.as_usize()?;
            let count = nbytes / 4;
            if shape.iter().product::<usize>() != count {
                bail!("tensor '{name}': shape/nbytes mismatch");
            }
            let end = base + offset + nbytes;
            if end > raw.len() {
                bail!("tensor '{name}': payload out of bounds");
            }
            let bytes = &raw[base + offset..end];
            let mut data = vec![0f32; count];
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(ch.try_into().unwrap());
            }
            tensors.insert(name.clone(), StoredTensor { shape, data });
        }
        Ok(TenStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&StoredTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("tenstore: missing tensor '{name}'"))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    /// Writer (used by tests and by `shareprefill cluster` to persist
    /// calibration features).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut payload: Vec<u8> = Vec::new();
        let mut entries = BTreeMap::new();
        for (name, t) in &self.tensors {
            let offset = payload.len();
            for v in &t.data {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            entries.insert(
                name.clone(),
                json::Json::obj(vec![
                    ("dtype", json::Json::str("f32")),
                    ("shape",
                     json::Json::Arr(t.shape.iter()
                         .map(|&s| json::Json::num(s as f64)).collect())),
                    ("offset", json::Json::num(offset as f64)),
                    ("nbytes", json::Json::num((t.data.len() * 4) as f64)),
                ]),
            );
        }
        let header = json::Json::obj(vec![(
            "tensors",
            json::Json::Obj(entries),
        )])
        .to_string();
        let mut out = Vec::with_capacity(16 + header.len() + payload.len());
        out.extend_from_slice(b"TENSTOR1");
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&payload);
        std::fs::write(path, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TenStore {
        let mut t = TenStore::default();
        t.tensors.insert(
            "a".into(),
            StoredTensor { shape: vec![2, 3], data: vec![0., 1., 2., 3., 4., 5.] },
        );
        t.tensors.insert(
            "b.c".into(),
            StoredTensor { shape: vec![4], data: vec![9.; 4] },
        );
        t
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("tenstore_rt.bin");
        sample().save(&dir).unwrap();
        let back = TenStore::load(&dir).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(back.get("a").unwrap().data, vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(back.get("b.c").unwrap().data, vec![9.; 4]);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TenStore::from_bytes(b"NOTMAGICxxxxxxxxxxx").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("tenstore_trunc.bin");
        sample().save(&dir).unwrap();
        let mut raw = std::fs::read(&dir).unwrap();
        raw.truncate(raw.len() - 4);
        assert!(TenStore::from_bytes(&raw).is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn missing_tensor_error() {
        assert!(sample().get("nope").is_err());
    }
}
