//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the
//! artifact manifest, cluster files and metrics dumps: no surrogate-pair
//! unescaping beyond \uXXXX BMP, numbers as f64).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn usize_list(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

// ---- parser -------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence starting at c.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()
            .map_err(|_| anyhow!("bad number '{s}' at byte {start}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").unwrap().as_arr().unwrap()[2]
                .req("b").unwrap().as_str().unwrap(),
            "c");
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"o":{"b":false}}"#;
        let j = parse(src).unwrap();
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn string_escaping_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&s.to_string()).unwrap(), s);
    }
}
