//! Self-contained substrates the coordinator depends on.  The offline
//! vendor set provides only `xla`, `anyhow`, `once_cell`, so serialization
//! and CLI parsing are implemented here (and tested as first-class
//! modules — see DESIGN.md "Substitutions").

pub mod cli;
pub mod json;
pub mod tenstore;
pub mod tomlmini;
