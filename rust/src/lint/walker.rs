//! Source discovery for `pallas-lint`: every `.rs` file under a root,
//! as (relative path, contents) pairs in sorted order — sorted so
//! diagnostics, the baseline file and `--write-baseline` output are
//! deterministic across filesystems.

use anyhow::{Context, Result};
use std::path::Path;

/// All `.rs` files under `root`, as (relative path with `/`
/// separators, contents), sorted by relative path.
pub fn rust_sources(root: &Path) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    out.sort();
    Ok(out)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<(String, String)>)
        -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path.strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let src = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            out.push((rel, src));
        }
    }
    Ok(())
}
