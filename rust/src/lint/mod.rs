//! `pallas-lint`: the tree's architecture & invariant checker.
//!
//! The compiler cannot see the contracts the serving stack rests on —
//! PR 5's determinism contract (order-bearing state never crosses a
//! thread), the layering discipline (only `exec` spawns threads, the
//! pattern engine never reaches into `serving`), the panic policy on
//! the hot path, and the rule that every `serve.*` knob is reachable
//! from the CLI and documented — both in DESIGN.md's serve-knob table
//! and in the operator's handbook (`docs/OPERATIONS.md`).  This module
//! enforces them as a blocking CI gate (see DESIGN.md "Invariants &
//! enforcement").
//!
//! Zero dependencies beyond the vendored `anyhow`: a space-blanking
//! scrubber ([`scan`]), a sorted source walker ([`walker`]), the four
//! rules ([`rules`]), and the panic-hygiene ratchet file
//! ([`baseline`]).  The binary front-end is
//! `rust/src/bin/pallas_lint.rs` (`cargo run --bin pallas-lint`).

pub mod baseline;
pub mod rules;
pub mod scan;
pub mod walker;

use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

use baseline::Baseline;

/// One finding, rendered as `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}",
               self.file, self.line, self.rule, self.message)
    }
}

/// Result of a full tree check.
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    /// Files scanned.
    pub files: usize,
    /// Observed panic-site counts in the hot path (what
    /// `--write-baseline` freezes), including zero-site files omitted.
    pub panic_counts: BTreeMap<String, usize>,
}

/// Check every `.rs` file under `root`.
///
/// * `base` — the panic-hygiene ratchet; `None` skips the comparison
///   (used by `--write-baseline`, which freezes `Report::panic_counts`
///   instead).
/// * `design` — DESIGN.md contents for the knob-documentation half of
///   rule 4; `None` skips that half (the flag half still runs when
///   the tree has a `cli_main.rs`).
/// * `ops` — docs/OPERATIONS.md contents for the operator-handbook
///   half of rule 4 (every knob needs a row in the operator's knob
///   table); `None` skips it.
pub fn check_tree(root: &Path, base: Option<&Baseline>,
                  design: Option<&str>, ops: Option<&str>)
                  -> Result<Report> {
    let files = walker::rust_sources(root)?;
    let mut diagnostics = Vec::new();
    let mut panic_counts = BTreeMap::new();
    let mut panic_found: BTreeMap<String, Vec<(usize, &'static str)>> =
        BTreeMap::new();
    // key -> (file, offset) of its first appearance in config/
    let mut knob_keys: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut cli_text: Option<&String> = None;

    for (rel, src) in &files {
        let sc = scan::scrub(src);
        let bytes = src.as_bytes();
        for (off, message) in rules::layering(rel, &sc) {
            diagnostics.push(Diagnostic {
                file: rel.clone(),
                line: scan::line_of(bytes, off),
                rule: rules::RULE_LAYERING,
                message,
            });
        }
        for (off, message) in rules::determinism(&sc) {
            diagnostics.push(Diagnostic {
                file: rel.clone(),
                line: scan::line_of(bytes, off),
                rule: rules::RULE_DETERMINISM,
                message,
            });
        }
        if rules::panic_scope(rel) {
            let sites = rules::panic_sites(&sc);
            if !sites.is_empty() {
                panic_counts.insert(rel.clone(), sites.len());
                panic_found.insert(rel.clone(), sites);
            }
        }
        if rel.starts_with("config/") {
            for (off, key) in rules::serve_keys(&sc) {
                knob_keys.entry(key).or_insert((rel.clone(), off));
            }
        }
        if rel == "cli_main.rs" {
            cli_text = Some(src);
        }
    }

    // Rule 3, cross-file half: the ratchet.  Over baseline -> every
    // site in the file is listed (the author knows which are new);
    // under baseline -> the shrink must be recorded.
    if let Some(base) = base {
        for (rel, sites) in &panic_found {
            let allowed = base.allowed(rel);
            let n = sites.len();
            if n > allowed {
                for (off, kind) in sites {
                    let src = files.iter()
                        .find(|(r, _)| r == rel)
                        .map(|(_, s)| s.as_bytes())
                        .unwrap_or_default();
                    diagnostics.push(Diagnostic {
                        file: rel.clone(),
                        line: scan::line_of(src, *off),
                        rule: rules::RULE_PANIC,
                        message: format!(
                            "`{kind}` in the serving hot path ({n} \
                             site(s), baseline allows {allowed}) — \
                             return a typed error or use \
                             expect(\"invariant: ...\")"),
                    });
                }
            } else if n < allowed {
                diagnostics.push(stale_baseline(rel, allowed, n));
            }
        }
        for (rel, &allowed) in &base.counts {
            if allowed > 0 && !panic_found.contains_key(rel) {
                diagnostics.push(stale_baseline(rel, allowed, 0));
            }
        }
    }

    // Rule 4, cross-file half: flag + doc lookup per collected key.
    for (key, (file, off)) in &knob_keys {
        let line = files.iter()
            .find(|(r, _)| r == file)
            .map(|(_, s)| scan::line_of(s.as_bytes(), *off))
            .unwrap_or(1);
        let flag = rules::flag_for(key);
        if let Some(cli) = cli_text {
            if !cli.contains(&format!("--{flag}")) {
                diagnostics.push(Diagnostic {
                    file: file.clone(),
                    line,
                    rule: rules::RULE_KNOBS,
                    message: format!(
                        "`{key}` is parsed here but `cli_main.rs` has \
                         no `--{flag}` flag — every serve knob must be \
                         reachable from the CLI"),
                });
            }
        }
        if let Some(doc) = design {
            if !doc.contains(key.as_str()) {
                diagnostics.push(Diagnostic {
                    file: file.clone(),
                    line,
                    rule: rules::RULE_KNOBS,
                    message: format!(
                        "`{key}` is not mentioned in DESIGN.md — \
                         document the knob in the serve-knob table"),
                });
            }
        }
        if let Some(handbook) = ops {
            if !handbook.contains(key.as_str()) {
                diagnostics.push(Diagnostic {
                    file: file.clone(),
                    line,
                    rule: rules::RULE_KNOBS,
                    message: format!(
                        "`{key}` has no row in docs/OPERATIONS.md — \
                         every serve knob needs an entry in the \
                         operator's knob table (name, flag, default, \
                         when to turn it)"),
                });
            }
        }
    }

    Ok(Report { diagnostics, files: files.len(), panic_counts })
}

fn stale_baseline(rel: &str, allowed: usize, found: usize) -> Diagnostic {
    Diagnostic {
        file: rel.to_string(),
        line: 1,
        rule: rules::RULE_PANIC,
        message: format!(
            "stale baseline: {allowed} site(s) recorded, {found} found \
             — shrink lint_baseline.toml (regenerate with \
             `pallas-lint --check rust/src --write-baseline` or \
             tools/lint_baseline_gen.py) so the burn-down is recorded"),
    }
}
