//! Lightweight Rust source scanner for `pallas-lint`: comment/literal
//! scrubbing, `#[cfg(test)]` span detection, and the byte-level
//! matching helpers the rules build on.
//!
//! Deliberately *not* a real lexer — the rules only need token-shaped
//! substring matching on comment-free text with stable line numbers.
//! The scrubber blanks comments and literal bodies with spaces
//! (newlines preserved, so every offset keeps its original line
//! number) and records ordinary string-literal bodies by the offset of
//! their opening quote, for the one rule that inspects literal
//! content (panic hygiene's `expect("invariant: …")` allowance).
//!
//! `tools/lint_baseline_gen.py` is a line-for-line replica of these
//! semantics so the panic-hygiene baseline can be regenerated without
//! a Rust toolchain; any change here must be mirrored there.

use std::collections::BTreeMap;

/// Scrubbed source: comments and literal bodies blanked to spaces,
/// plus the bodies of ordinary (non-raw) string literals keyed by the
/// offset of their opening quote.
pub struct Scrubbed {
    pub text: Vec<u8>,
    pub literals: BTreeMap<usize, String>,
}

/// Is `b` a Rust identifier byte?  (ASCII only: the tree's identifiers
/// are ASCII, and every token the rules search for is too.)
pub fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// First occurrence of `needle` in `hay` at or after `from`.
pub fn find(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len())
        .find(|&i| &hay[i..i + needle.len()] == needle)
}

/// 1-based line number of byte offset `off` in `src`.
pub fn line_of(src: &[u8], off: usize) -> usize {
    src[..off.min(src.len())].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Skip ASCII whitespace starting at `i`.
pub fn skip_ws(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && matches!(s[i], b' ' | b'\t' | b'\r' | b'\n') {
        i += 1;
    }
    i
}

/// Offset one past the `)` matching the `(` at `open` (or `s.len()`
/// when unbalanced).  Call on scrubbed text only — literal parens are
/// already blanked, so plain depth counting is exact.
pub fn match_paren(s: &[u8], open: usize) -> usize {
    let mut depth = 0i64;
    let mut j = open;
    while j < s.len() {
        match s[j] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    s.len()
}

/// Offsets of `needle` in `s[from..to]` with non-identifier bytes on
/// both sides (word-boundary occurrences).
pub fn word_hits(s: &[u8], needle: &[u8], from: usize, to: usize)
                 -> Vec<usize> {
    let mut hits = Vec::new();
    let mut pos = from;
    let to = to.min(s.len());
    while let Some(i) = find(&s[..to], needle, pos) {
        let left_ok = i == 0 || !is_ident(s[i - 1]);
        let after = i + needle.len();
        let right_ok = after >= s.len() || !is_ident(s[after]);
        if left_ok && right_ok {
            hits.push(i);
        }
        pos = i + 1;
    }
    hits
}

/// Blank comments and string/char literal contents with spaces
/// (newlines preserved), recording string-literal bodies by offset.
///
/// Handles: line comments, nested block comments, raw strings
/// (`r"…"` / `r#"…"#` with any number of hashes), ordinary strings
/// with escapes, and char literals (including `'\x'` escapes),
/// distinguishing the latter from lifetimes (`'a`) by the position of
/// the closing quote.
pub fn scrub(src: &str) -> Scrubbed {
    let s = src.as_bytes();
    let n = s.len();
    let mut out = s.to_vec();
    let mut literals = BTreeMap::new();
    let mut i = 0usize;
    while i < n {
        let c = s[i];
        let nxt = if i + 1 < n { s[i + 1] } else { 0 };
        if c == b'/' && nxt == b'/' {
            while i < n && s[i] != b'\n' {
                out[i] = b' ';
                i += 1;
            }
        } else if c == b'/' && nxt == b'*' {
            let mut depth = 0i64;
            while i < n {
                if s[i] == b'/' && i + 1 < n && s[i + 1] == b'*' {
                    depth += 1;
                    out[i] = b' ';
                    out[i + 1] = b' ';
                    i += 2;
                } else if s[i] == b'*' && i + 1 < n && s[i + 1] == b'/' {
                    depth -= 1;
                    out[i] = b' ';
                    out[i + 1] = b' ';
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if s[i] != b'\n' {
                        out[i] = b' ';
                    }
                    i += 1;
                }
            }
        } else if c == b'r' && (nxt == b'"' || nxt == b'#') {
            // raw string r"…" / r#"…"# (possibly more hashes)
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && s[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && s[j] == b'"' {
                let mut close = vec![b'#'; hashes];
                close.insert(0, b'"');
                let end = match find(s, &close, j + 1) {
                    Some(k) => k + close.len(),
                    None => n,
                };
                for p in i..end {
                    if s[p] != b'\n' {
                        out[p] = b' ';
                    }
                }
                i = end;
            } else {
                i += 1;
            }
        } else if c == b'"' {
            let start = i;
            let mut j = i + 1;
            let mut body = Vec::new();
            while j < n {
                if s[j] == b'\\' && j + 1 < n {
                    body.push(s[j]);
                    body.push(s[j + 1]);
                    j += 2;
                } else if s[j] == b'"' {
                    break;
                } else {
                    body.push(s[j]);
                    j += 1;
                }
            }
            let end = if j < n { j + 1 } else { n };
            for p in i..end {
                if s[p] != b'\n' {
                    out[p] = b' ';
                }
            }
            literals.insert(start,
                            String::from_utf8_lossy(&body).into_owned());
            i = end;
        } else if c == b'\'' {
            // char literal vs lifetime: 'x' / '\x' is a literal;
            // 'ident (no closing quote right after) is a lifetime
            if nxt == b'\\' {
                let mut j = i + 2;
                while j < n && s[j] != b'\'' {
                    j += 1;
                }
                let end = if j < n { j + 1 } else { n };
                for p in i..end {
                    if s[p] != b'\n' {
                        out[p] = b' ';
                    }
                }
                i = end;
            } else if i + 2 < n && s[i + 2] == b'\'' {
                out[i] = b' ';
                out[i + 1] = b' ';
                out[i + 2] = b' ';
                i += 3;
            } else {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    Scrubbed { text: out, literals }
}

/// Byte spans of `#[cfg(test)] mod … { … }` blocks in scrubbed text.
/// Rules skip matches inside these spans: test code may unwrap, sleep
/// on threads, and parse ad-hoc TOML without tripping the audit.
pub fn test_spans(scrubbed: &[u8]) -> Vec<(usize, usize)> {
    let attr: &[u8] = b"#[cfg(test)]";
    let mut spans = Vec::new();
    let mut pos = 0usize;
    while let Some(a) = find(scrubbed, attr, pos) {
        let Some(open) = find(scrubbed, b"{", a + attr.len()) else {
            break;
        };
        if find(&scrubbed[..open], b"mod", a + attr.len()).is_none() {
            pos = a + attr.len();
            continue;
        }
        let mut depth = 0i64;
        let mut j = open;
        let mut end = scrubbed.len();
        while j < scrubbed.len() {
            match scrubbed[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = j + 1;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        spans.push((a, end));
        pos = end;
    }
    spans
}

/// Is `off` inside any of `spans`?
pub fn in_spans(spans: &[(usize, usize)], off: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= off && off < b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrubbed_str(src: &str) -> String {
        String::from_utf8(scrub(src).text).unwrap()
    }

    #[test]
    fn scrub_line_and_block_comments() {
        let s = scrubbed_str("a // unwrap()\nb /* panic! */ c");
        assert!(!s.contains("unwrap"));
        assert!(!s.contains("panic"));
        assert!(s.contains('a') && s.contains('b') && s.contains('c'));
        assert_eq!(s.matches('\n').count(), 1);
    }

    #[test]
    fn scrub_nested_block_comment() {
        let s = scrubbed_str("x /* outer /* inner */ still */ y");
        assert!(!s.contains("inner") && !s.contains("still"));
        assert!(s.contains('x') && s.contains('y'));
    }

    #[test]
    fn scrub_string_literals_recorded() {
        let sc = scrub("call(\"invariant: queue non-empty\")");
        assert!(!String::from_utf8(sc.text.clone()).unwrap()
            .contains("invariant"));
        assert_eq!(sc.literals.get(&5).map(String::as_str),
                   Some("invariant: queue non-empty"));
    }

    #[test]
    fn scrub_escaped_quote_in_string() {
        let sc = scrub(r#"f("a\"b") + g"#);
        let s = String::from_utf8(sc.text).unwrap();
        assert!(s.contains("+ g"), "scan must resume after the literal");
    }

    #[test]
    fn scrub_raw_string() {
        let s = scrubbed_str("let x = r#\"panic! \"quoted\" here\"#; y");
        assert!(!s.contains("panic"));
        assert!(s.contains("; y"));
    }

    #[test]
    fn scrub_char_literal_vs_lifetime() {
        let s = scrubbed_str("let c = '\"'; fn f<'a>(x: &'a str) {}");
        assert!(!s.contains('"'), "char literal quote must be blanked");
        assert!(s.contains("'a"), "lifetimes survive scrubbing");
    }

    #[test]
    fn newlines_and_offsets_preserved() {
        let src = "a\n\"two\nline\"\nb.unwrap()";
        let sc = scrub(src);
        assert_eq!(sc.text.iter().filter(|&&b| b == b'\n').count(), 3);
        let i = find(&sc.text, b".unwrap", 0).unwrap();
        assert_eq!(line_of(src.as_bytes(), i), 4);
    }

    #[test]
    fn test_spans_cover_mod_tests() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n fn b() { x.unwrap() } }\nfn c() {}";
        let sc = scrub(src);
        let spans = test_spans(&sc.text);
        assert_eq!(spans.len(), 1);
        let u = find(&sc.text, b".unwrap", 0).unwrap();
        assert!(in_spans(&spans, u));
        let c = find(&sc.text, b"fn c", 0).unwrap();
        assert!(!in_spans(&spans, c));
    }

    #[test]
    fn cfg_test_without_mod_is_not_a_span() {
        let src = "#[cfg(test)]\nfn helper() { x.unwrap() }";
        let sc = scrub(src);
        // attribute on a bare fn: the brace-matched "mod" heuristic
        // must not claim the whole rest of the file
        assert!(test_spans(&sc.text).is_empty());
    }

    #[test]
    fn word_hits_respect_boundaries() {
        let s = b"Rc::new(x); Rcx; my_Rc; a.borrow_mut()";
        assert_eq!(word_hits(s, b"Rc", 0, s.len()), vec![0]);
        assert_eq!(word_hits(s, b"borrow_mut", 0, s.len()).len(), 1);
    }

    #[test]
    fn match_paren_nested() {
        let s = b"f(a(b), c(d(e))) tail";
        assert_eq!(match_paren(s, 1), 16);
    }
}
