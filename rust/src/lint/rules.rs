//! The four pallas-lint rules.  Each returns raw (offset, message)
//! findings over one scrubbed file; `lint::check_tree` attaches file
//! names and line numbers and applies the cross-file parts (the
//! panic-hygiene baseline ratchet, the knob-hygiene flag/doc lookup).
//!
//! Rule ids (stable — they appear in diagnostics and CI logs):
//!   layering        module-dependency allowlist
//!   determinism     no order-bearing state inside fan_out closures
//!   panic-hygiene   no unwrap/expect/panic! in the serving hot path
//!   knob-hygiene    every serve.* key has a CLI flag + a DESIGN.md
//!                   entry + a row in the docs/OPERATIONS.md knob table

use super::scan::{self, Scrubbed};

pub const RULE_LAYERING: &str = "layering";
pub const RULE_DETERMINISM: &str = "determinism";
pub const RULE_PANIC: &str = "panic-hygiene";
pub const RULE_KNOBS: &str = "knob-hygiene";

/// Modules that may never name `std::thread` — everyone but `exec`
/// (the worker pool and the sanctioned `spawn_worker` entry point).
const THREAD_OWNER: &str = "exec";

/// Pattern-engine modules that must stay below the serving layer.
const BELOW_SERVING: [&str; 4] =
    ["attention", "clustering", "linalg", "methods"];

/// Paths the serving layer may not reach up into.
const ABOVE_SERVING: [&str; 2] = ["crate::eval", "crate::bench"];

/// Tokens that carry or mutate order-bearing state and therefore must
/// never appear inside a `fan_out(..)` closure: the strategy's
/// pattern-decision entry points (their call order is part of the
/// determinism contract), PJRT dispatch (`execute`/`run_buffers` —
/// engine-thread only), and single-thread shared-state machinery.
const FAN_OUT_FORBIDDEN: [&str; 7] = [
    "decide_pattern", "publish_abar", "execute", "run_buffers",
    "Rc", "RefCell", "borrow_mut",
];

/// The serving hot path governed by the panic-hygiene baseline.
pub fn panic_scope(rel: &str) -> bool {
    rel.starts_with("serving/") || rel.starts_with("exec/")
        || rel == "methods/pattern_cache.rs"
        || rel == "methods/flash_threshold.rs"
}

/// Top-level module of a file path relative to the source root.
pub fn module_of(rel: &str) -> &str {
    match rel.find('/') {
        Some(i) => &rel[..i],
        None => rel.strip_suffix(".rs").unwrap_or(rel),
    }
}

/// Rule 1: layering.  `use`/path tokens only — scrubbed text, so
/// comments and strings never trip it; `#[cfg(test)]` mod blocks are
/// exempt (tests may sleep on threads and reach across layers).
pub fn layering(rel: &str, sc: &Scrubbed) -> Vec<(usize, String)> {
    let s = &sc.text[..];
    let spans = scan::test_spans(s);
    let module = module_of(rel);
    let mut out = Vec::new();
    if module != THREAD_OWNER {
        for off in scan::word_hits(s, b"std::thread", 0, s.len()) {
            if !scan::in_spans(&spans, off) {
                out.push((off, format!(
                    "`std::thread` outside `exec` (module `{module}`) — \
                     spawn through exec::spawn_worker / exec::WorkerPool \
                     so threads stay visible to the determinism audit")));
            }
        }
    }
    // the raw-thread entry point itself is reserved for the two
    // sanctioned engine-owner loops: the single-engine server and the
    // fleet's shard actors.  Everyone else goes through WorkerPool.
    let spawn_owner = module == THREAD_OWNER
        || rel == "serving/server.rs"
        || rel.starts_with("serving/fleet");
    if !spawn_owner {
        for off in scan::word_hits(s, b"exec::spawn_worker", 0, s.len()) {
            if !scan::in_spans(&spans, off) {
                out.push((off, format!(
                    "`exec::spawn_worker` outside its owners (module \
                     `{module}`) — only `serving/server.rs` and \
                     `serving/fleet` may own engine threads; use \
                     exec::WorkerPool for data-parallel work")));
            }
        }
    }
    if BELOW_SERVING.contains(&module) {
        for off in scan::word_hits(s, b"crate::serving", 0, s.len()) {
            if !scan::in_spans(&spans, off) {
                out.push((off, format!(
                    "`{module}` may not import `serving` — the pattern \
                     engine sits below the serving layer")));
            }
        }
    }
    if module == "serving" {
        for target in ABOVE_SERVING {
            for off in scan::word_hits(s, target.as_bytes(), 0, s.len()) {
                if !scan::in_spans(&spans, off) {
                    out.push((off, format!(
                        "`serving` may not import `{}` — harnesses \
                         depend on the server, never the reverse",
                        &target["crate::".len()..])));
                }
            }
        }
    }
    out.sort();
    out
}

/// Rule 2: determinism.  Brace/paren-matched span scanning: every
/// `.fan_out(` call's argument span (which contains the per-head
/// closure) is searched for order-bearing tokens.
pub fn determinism(sc: &Scrubbed) -> Vec<(usize, String)> {
    let s = &sc.text[..];
    let spans = scan::test_spans(s);
    let mut out = Vec::new();
    let pat: &[u8] = b".fan_out";
    let mut pos = 0usize;
    while let Some(i) = scan::find(s, pat, pos) {
        pos = i + 1;
        let after = i + pat.len();
        if after < s.len() && scan::is_ident(s[after]) {
            continue;
        }
        if scan::in_spans(&spans, i) {
            continue;
        }
        let open = scan::skip_ws(s, after);
        if open >= s.len() || s[open] != b'(' {
            continue;
        }
        let end = scan::match_paren(s, open);
        for tok in FAN_OUT_FORBIDDEN {
            for off in scan::word_hits(s, tok.as_bytes(), open, end) {
                out.push((off, format!(
                    "`{tok}` inside a fan_out(..) closure — fan-out \
                     closures must be pure per-head; order-bearing \
                     state stays on the engine thread (PR 5 \
                     determinism contract)")));
            }
        }
    }
    out.sort();
    out
}

/// Rule 3 (per-file half): panic sites in scrubbed source — `.unwrap()`,
/// `.expect(..)` without an `"invariant: …"` literal message, and the
/// panic-family macros, outside `#[cfg(test)]` mod blocks.  The
/// cross-file baseline comparison lives in `lint::check_tree`.
pub fn panic_sites(sc: &Scrubbed) -> Vec<(usize, &'static str)> {
    let s = &sc.text[..];
    let spans = scan::test_spans(s);
    let mut sites: Vec<(usize, &'static str)> = Vec::new();

    let pat: &[u8] = b".unwrap";
    let mut pos = 0usize;
    while let Some(i) = scan::find(s, pat, pos) {
        pos = i + 1;
        let after = i + pat.len();
        let j = scan::skip_ws(s, after);
        if j < s.len() && s[j] == b'(' {
            let k = scan::skip_ws(s, j + 1);
            if k < s.len() && s[k] == b')'
                && (after >= s.len() || !scan::is_ident(s[after]))
                && !scan::in_spans(&spans, i)
            {
                sites.push((i, "unwrap()"));
            }
        }
    }

    let pat: &[u8] = b".expect";
    let mut pos = 0usize;
    while let Some(i) = scan::find(s, pat, pos) {
        pos = i + 1;
        let after = i + pat.len();
        if after < s.len() && scan::is_ident(s[after]) {
            continue; // .expect_err and friends
        }
        let j = scan::skip_ws(s, after);
        if j < s.len() && s[j] == b'(' {
            // a string-literal argument is blanked to spaces in the
            // scrubbed text, so skip_ws runs past it: the literal (if
            // any) is the first one recorded in (j, k]
            let k = scan::skip_ws(s, j + 1);
            let ok = sc.literals.range(j + 1..=k).next()
                .is_some_and(|(_, l)| l.starts_with("invariant:"));
            if !ok && !scan::in_spans(&spans, i) {
                sites.push((i, "expect(..)"));
            }
        }
    }

    for mac in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
        let mut pos = 0usize;
        while let Some(i) = scan::find(s, mac.as_bytes(), pos) {
            pos = i + 1;
            let before_ok = i == 0 || !scan::is_ident(s[i - 1]);
            if before_ok && !scan::in_spans(&spans, i) {
                sites.push((i, match mac {
                    "panic!" => "panic!",
                    "unreachable!" => "unreachable!",
                    "todo!" => "todo!",
                    _ => "unimplemented!",
                }));
            }
        }
    }
    sites.sort();
    sites
}

/// Rule 4 (collection half): `serve.*` keys named in string literals
/// of a `config/` source file, outside test mod blocks.  The flag and
/// DESIGN.md lookups live in `lint::check_tree`.
pub fn serve_keys(sc: &Scrubbed) -> Vec<(usize, String)> {
    let spans = scan::test_spans(&sc.text);
    sc.literals.iter()
        .filter(|(off, body)| {
            body.starts_with("serve.") && !scan::in_spans(&spans, **off)
        })
        .map(|(off, body)| (*off, body.clone()))
        .collect()
}

/// CLI flag a `serve.*` key must be reachable through: strip the
/// `serve.` prefix and map separators to `-`.  Two irregular mappings:
/// the cache master switches are the booleans `--pattern-cache` and
/// `--prefix-cache`.
pub fn flag_for(key: &str) -> String {
    if key == "serve.pattern_cache.enabled" {
        return "pattern-cache".to_string();
    }
    if key == "serve.prefix_cache.enabled" {
        return "prefix-cache".to_string();
    }
    key.trim_start_matches("serve.").replace(['.', '_'], "-")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scrub;

    #[test]
    fn layering_flags_thread_outside_exec() {
        let sc = scrub("fn f() { std::thread::spawn(|| {}); }");
        let hits = layering("serving/server.rs", &sc);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].1.contains("std::thread"));
        assert!(layering("exec/pool.rs", &sc).is_empty());
    }

    #[test]
    fn layering_ignores_comments_and_tests() {
        let sc = scrub(
            "// std::thread is discussed here only\n\
             #[cfg(test)]\nmod tests { fn t() { \
             std::thread::sleep(d); } }");
        assert!(layering("util/timer.rs", &sc).is_empty());
    }

    #[test]
    fn layering_reserves_spawn_worker_for_engine_owners() {
        let sc = scrub("crate::exec::spawn_worker(\"w\", move || {});\n");
        assert!(layering("serving/server.rs", &sc).is_empty());
        assert!(layering("serving/fleet/mod.rs", &sc).is_empty());
        assert!(layering("exec/pool.rs", &sc).is_empty());
        let hits = layering("serving/scheduler.rs", &sc);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].1.contains("exec::spawn_worker"));
        assert_eq!(layering("eval/latency.rs", &sc).len(), 1);
    }

    #[test]
    fn layering_flags_upward_imports() {
        let sc = scrub("use crate::serving::Engine;\n");
        assert_eq!(layering("attention/vslash.rs", &sc).len(), 1);
        assert!(layering("eval/latency.rs", &sc).is_empty());
        let sc = scrub("use crate::eval::open_registry;\n");
        assert_eq!(layering("serving/server.rs", &sc).len(), 1);
        assert!(layering("cli_main.rs", &sc).is_empty());
    }

    #[test]
    fn determinism_flags_order_bearing_tokens() {
        let sc = scrub(
            "let r = pool.fan_out(n, |h| {\n\
                 cache.borrow_mut().push(h);\n\
                 strategy.decide_pattern(h)\n\
             });");
        let hits = determinism(&sc);
        assert_eq!(hits.len(), 2);
        assert!(hits[0].1.contains("borrow_mut"));
        assert!(hits[1].1.contains("decide_pattern"));
    }

    #[test]
    fn determinism_allows_pure_closures() {
        let sc = scrub(
            "let r = pool.fan_out(jobs.len(), |k| {\n\
                 search_vslash(maps, bs, seq, gamma)\n\
             });\n\
             cache.borrow_mut().insert(k, r);");
        assert!(determinism(&sc).is_empty(),
                "tokens outside the call span must not fire");
    }

    #[test]
    fn panic_sites_counting() {
        let sc = scrub(
            "fn f() {\n\
                 a.unwrap();\n\
                 b.unwrap_or(0);\n\
                 c.expect(\"queue non-empty\");\n\
                 d.expect(\"invariant: handed out by us\");\n\
                 panic!(\"boom\");\n\
             }\n\
             #[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }");
        let kinds: Vec<&str> =
            panic_sites(&sc).iter().map(|s| s.1).collect();
        assert_eq!(kinds, vec!["unwrap()", "expect(..)", "panic!"]);
    }

    #[test]
    fn panic_scope_is_the_hot_path() {
        assert!(panic_scope("serving/scheduler.rs"));
        assert!(panic_scope("exec/pool.rs"));
        assert!(panic_scope("methods/pattern_cache.rs"));
        assert!(panic_scope("methods/flash_threshold.rs"));
        assert!(!panic_scope("methods/shareprefill.rs"));
        assert!(!panic_scope("eval/latency.rs"));
    }

    #[test]
    fn serve_keys_and_flags() {
        let sc = scrub(
            "t.usize_or(\"serve.kv_blocks\", d);\n\
             t.bool_or(\"serve.pattern_cache.enabled\", e);\n\
             s.push(\"other.key\");\n\
             #[cfg(test)]\nmod tests { fn t() { \
             p(\"serve.fake_test_key\"); } }");
        let keys: Vec<String> =
            serve_keys(&sc).iter().map(|k| k.1.clone()).collect();
        assert_eq!(keys,
                   vec!["serve.kv_blocks".to_string(),
                        "serve.pattern_cache.enabled".to_string()]);
        assert_eq!(flag_for("serve.kv_blocks"), "kv-blocks");
        assert_eq!(flag_for("serve.pattern_cache.enabled"),
                   "pattern-cache");
        assert_eq!(flag_for("serve.pattern_cache.max_age"),
                   "pattern-cache-max-age");
        assert_eq!(flag_for("serve.prefix_cache.enabled"),
                   "prefix-cache");
        assert_eq!(flag_for("serve.prefix_cache.capacity"),
                   "prefix-cache-capacity");
    }

    #[test]
    fn module_of_paths() {
        assert_eq!(module_of("serving/engine.rs"), "serving");
        assert_eq!(module_of("cli_main.rs"), "cli_main");
        assert_eq!(module_of("bin/pallas_lint.rs"), "bin");
    }
}
