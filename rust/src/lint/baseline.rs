//! The panic-hygiene ratchet file (`lint_baseline.toml`): frozen
//! per-file counts of `unwrap()`/`expect()`/panic-family sites in the
//! serving hot path.  The file may only shrink — `pallas-lint` fails
//! when a file exceeds its recorded count (a new panic site) *and*
//! when it falls below it (a stale baseline: the burn-down must be
//! recorded in the same change).
//!
//! The format is a self-contained `"path" = count` line list (parsed
//! here rather than by `substrate::tomlmini`, whose section
//! flattening would mangle quoted path keys).  `render` reproduces
//! `tools/lint_baseline_gen.py`'s output byte for byte so either tool
//! can regenerate the file.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// Frozen per-file panic-site counts; files absent are at zero.
#[derive(Debug, Default)]
pub struct Baseline {
    pub counts: BTreeMap<String, usize>,
}

impl Baseline {
    pub fn allowed(&self, rel: &str) -> usize {
        self.counts.get(rel).copied().unwrap_or(0)
    }
}

pub fn load(path: &Path) -> Result<Baseline> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading baseline {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse(text: &str) -> Result<Baseline> {
    let mut counts = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected '\"path\" = count'", lineno + 1);
        };
        let key = line[..eq].trim().trim_matches('"');
        if key.is_empty() {
            bail!("line {}: empty path", lineno + 1);
        }
        let n: usize = line[eq + 1..].trim().parse().with_context(
            || format!("line {}: bad count", lineno + 1))?;
        counts.insert(key.to_string(), n);
    }
    Ok(Baseline { counts })
}

/// Serialize counts in the committed baseline format.  Must stay byte-
/// identical to `tools/lint_baseline_gen.py`'s output.
pub fn render(counts: &BTreeMap<String, usize>) -> String {
    let mut out = String::from(
        "# pallas-lint panic-hygiene baseline — frozen counts of\n\
         # unwrap()/expect()/panic-family sites in the serving hot path\n\
         # (serving/, exec/, methods/pattern_cache.rs,\n\
         # methods/flash_threshold.rs; test modules\n\
         # excluded).  This file may only shrink: pallas-lint fails if a\n\
         # file exceeds its count here (new panic site) OR falls below it\n\
         # (stale baseline — regenerate with `pallas-lint --check\n\
         # rust/src --write-baseline` or tools/lint_baseline_gen.py so\n\
         # the burn-down is recorded).  Files absent from this list are\n\
         # at zero.\n");
    for (k, v) in counts {
        let _ = writeln!(out, "\"{k}\" = {v}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_skips_comments_and_blanks() {
        let b = parse("# header\n\n\"serving/a.rs\" = 3\n\"exec/b.rs\" = 1\n")
            .unwrap();
        assert_eq!(b.allowed("serving/a.rs"), 3);
        assert_eq!(b.allowed("exec/b.rs"), 1);
        assert_eq!(b.allowed("serving/unlisted.rs"), 0);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse("serving/a.rs").is_err());
        assert!(parse("\"a.rs\" = many").is_err());
        assert!(parse("\"\" = 1").is_err());
    }

    #[test]
    fn render_roundtrip() {
        let mut counts = BTreeMap::new();
        counts.insert("serving/batcher.rs".to_string(), 1);
        counts.insert("serving/kvcache.rs".to_string(), 1);
        let text = render(&counts);
        let back = parse(&text).unwrap();
        assert_eq!(back.counts, counts);
        assert!(text.starts_with('#'), "header comment present");
    }
}
