//! Host-side tensor: the coordinator's in-memory representation, converted
//! to/from `xla::Literal` at the execute boundary.

use anyhow::{bail, Result};
use xla::Literal;

/// Row-major host tensor, f32 or i32.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs data {}", data.len());
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    /// Slice the leading axis: `self[index]` for a `[N, ...]` tensor.
    pub fn index_axis0(&self, index: usize) -> Result<Tensor> {
        let shape = self.shape();
        if shape.is_empty() {
            bail!("cannot index a scalar");
        }
        let inner: usize = shape[1..].iter().product();
        let inner_shape = shape[1..].to_vec();
        match self {
            Tensor::F32 { data, .. } => Ok(Tensor::f32(
                inner_shape,
                data[index * inner..(index + 1) * inner].to_vec())),
            Tensor::I32 { data, .. } => Ok(Tensor::i32(
                inner_shape,
                data[index * inner..(index + 1) * inner].to_vec())),
        }
    }

    /// Convert to an `xla::Literal` (copies).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Tensor::F32 { data, .. } => {
                if dims.is_empty() {
                    Literal::scalar(data[0])
                } else {
                    Literal::vec1(data).reshape(&dims)?
                }
            }
            Tensor::I32 { data, .. } => {
                if dims.is_empty() {
                    Literal::scalar(data[0])
                } else {
                    Literal::vec1(data).reshape(&dims)?
                }
            }
        })
    }

    /// Convert from an `xla::Literal` (copies).
    pub fn from_literal(lit: &Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize)
            .collect();
        match shape.primitive_type() {
            xla::PrimitiveType::F32 => {
                Ok(Tensor::f32(dims, lit.to_vec::<f32>()?))
            }
            xla::PrimitiveType::S32 => {
                Ok(Tensor::i32(dims, lit.to_vec::<i32>()?))
            }
            other => bail!("unsupported literal type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_consistency() {
        let t = Tensor::f32(vec![2, 3], vec![0.; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), "f32");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![0.; 3]);
    }

    #[test]
    fn index_axis0() {
        let t = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.index_axis0(1).unwrap();
        assert_eq!(r.shape(), &[3]);
        assert_eq!(r.as_f32().unwrap(), &[4., 5., 6.]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 2]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![3], vec![7, 8, 9]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7, 8, 9]);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_i32(5);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[5]);
        assert!(back.shape().is_empty());
    }
}
