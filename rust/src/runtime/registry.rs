//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), exposes shape metadata, and lazily compiles
//! HLO artifacts on first use, caching the executables.
//!
//! Lazy compilation matters on the single-core testbed: an eval that only
//! touches the 1024-token bucket never pays for the 4096-token artifacts.

use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::rc::Rc;

use crate::substrate::json::{self, Json};

use super::{Executable, Runtime, Tensor};

/// Parameter or output descriptor from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub model: String,
    pub stage: String,
    pub seq: usize,
    pub budget: Option<usize>,
    pub params: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model shape metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub prefix: String,
    pub num_layers: usize,
    pub num_heads: usize,
    pub num_kv_heads: usize,
    pub head_dim: usize,
    pub hidden: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub seq_buckets: Vec<usize>,
    /// seq bucket → available attention budget buckets (ascending).
    pub budgets: BTreeMap<usize, Vec<usize>>,
    pub weights_file: String,
}

impl ModelSpec {
    pub fn group(&self) -> usize {
        self.num_heads / self.num_kv_heads
    }

    pub fn num_blocks(&self, seq: usize) -> usize {
        seq / crate::BLOCK_SIZE
    }

    /// Smallest seq bucket that fits `len` tokens.
    pub fn seq_bucket_for(&self, len: usize) -> Result<usize> {
        self.seq_buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .ok_or_else(|| anyhow!(
                "prompt of {len} tokens exceeds max bucket {}",
                self.max_seq))
    }

    /// Smallest budget bucket (for `seq`) with capacity >= `blocks`.
    pub fn budget_bucket_for(&self, seq: usize, blocks: usize) -> usize {
        let buckets = &self.budgets[&seq];
        buckets
            .iter()
            .copied()
            .find(|&b| b >= blocks)
            .unwrap_or(*buckets.last().unwrap())
    }
}

/// The registry.
pub struct Registry {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, Artifact>,
    runtime: Rc<Runtime>,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Compile count (observability for tests + `inspect`).
    compiles: RefCell<usize>,
}

impl Registry {
    pub fn load(dir: impl Into<PathBuf>, runtime: Rc<Runtime>)
                -> Result<Registry> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run \
                                      `make artifacts` first"))?;
        let j = json::parse(&text)?;
        let block = j.req("block_size")?.as_usize()?;
        if block != crate::BLOCK_SIZE {
            bail!("manifest block_size {block} != crate BLOCK_SIZE {}",
                  crate::BLOCK_SIZE);
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj()? {
            let mut budgets = BTreeMap::new();
            for (seq, arr) in m.req("budgets")?.as_obj()? {
                budgets.insert(seq.parse::<usize>()?, arr.usize_list()?);
            }
            models.insert(name.clone(), ModelSpec {
                name: name.clone(),
                prefix: m.req("prefix")?.as_str()?.to_string(),
                num_layers: m.req("num_layers")?.as_usize()?,
                num_heads: m.req("num_heads")?.as_usize()?,
                num_kv_heads: m.req("num_kv_heads")?.as_usize()?,
                head_dim: m.req("head_dim")?.as_usize()?,
                hidden: m.req("hidden")?.as_usize()?,
                ffn: m.req("ffn")?.as_usize()?,
                vocab: m.req("vocab")?.as_usize()?,
                max_seq: m.req("max_seq")?.as_usize()?,
                seq_buckets: m.req("seq_buckets")?.usize_list()?,
                budgets,
                weights_file: m.req("weights_file")?.as_str()?.to_string(),
            });
        }
        let mut artifacts = BTreeMap::new();
        for a in j.req("artifacts")?.as_arr()? {
            let art = Artifact {
                name: a.req("name")?.as_str()?.to_string(),
                file: a.req("file")?.as_str()?.to_string(),
                model: a.req("model")?.as_str()?.to_string(),
                stage: a.req("stage")?.as_str()?.to_string(),
                seq: a.req("seq")?.as_usize()?,
                budget: a.get("budget").map(|b| b.as_usize()).transpose()?,
                params: parse_specs(a.req("params")?)?,
                outputs: parse_specs(a.req("outputs")?)?,
            };
            artifacts.insert(art.name.clone(), art);
        }
        Ok(Registry {
            dir,
            models,
            artifacts,
            runtime,
            cache: RefCell::new(HashMap::new()),
            compiles: RefCell::new(0),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let art = self.artifact(name)?;
        let path = self.dir.join(&art.file);
        let exe = Rc::new(self.runtime.compile_hlo_file(&path)?);
        *self.compiles.borrow_mut() += 1;
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compile_count(&self) -> usize {
        *self.compiles.borrow()
    }

    /// Execute an artifact by name, validating input shapes against the
    /// manifest (cheap; catches wiring bugs early with a useful message).
    pub fn execute(&self, name: &str, inputs: &[Tensor])
                   -> Result<Vec<Tensor>> {
        let art = self.artifact(name)?;
        if inputs.len() != art.params.len() {
            bail!("artifact {name}: {} inputs given, {} expected",
                  inputs.len(), art.params.len());
        }
        for (t, spec) in inputs.iter().zip(&art.params) {
            if t.shape() != spec.shape.as_slice() || t.dtype() != spec.dtype {
                bail!("artifact {name}: param '{}' expects {} {:?}, got {} \
                       {:?}", spec.name, spec.dtype, spec.shape, t.dtype(),
                      t.shape());
            }
        }
        self.executable(name)?.run(inputs)
    }

    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.runtime
    }
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()?
        .iter()
        .map(|p| {
            Ok(TensorSpec {
                name: p.get("name")
                    .map(|n| n.as_str().map(str::to_string))
                    .transpose()?
                    .unwrap_or_default(),
                dtype: p.req("dtype")?.as_str()?.to_string(),
                shape: p.req("shape")?.usize_list()?,
            })
        })
        .collect()
}
