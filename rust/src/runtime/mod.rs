//! L3 ↔ L2 bridge: PJRT CPU client, artifact registry (manifest-driven,
//! lazily compiled), and the typed host [`Tensor`].
//!
//! Flow (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! Every artifact was lowered with `return_tuple=True`, so outputs are
//! always unpacked from a tuple literal.

pub mod registry;
pub mod tensor;

pub use registry::{Artifact, Registry};
pub use tensor::Tensor;

use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// Shared setup: PJRT runtime + artifact registry.  Lives here rather
/// than in `eval` (which re-exports it) so the serving layer can open
/// a registry without crossing the layering boundary pallas-lint
/// enforces — `serving` must never import `eval`.
pub fn open_registry(cfg: &crate::config::Config) -> Result<Rc<Registry>> {
    let rt = Rc::new(Runtime::cpu()?);
    Ok(Rc::new(Registry::load(cfg.paths.artifacts.clone(), rt)?))
}

/// Wrapper around the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns the unpacked output tuple.
    ///
    /// NOTE: this stages inputs as rust-owned `PjRtBuffer`s and calls
    /// `execute_b` rather than `execute` — the crate's `execute` leaks
    /// every input buffer (`BufferFromHostLiteral(..).release()` with no
    /// matching free in xla_rs.cc), ~100 MB per prefill at ctx 512.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<InputRef<'_>> =
            inputs.iter().map(InputRef::Host).collect();
        self.run_buffers(&refs)
    }

    /// Execute with pre-staged device buffers mixed with host tensors.
    /// `staged` entries override the input at their position — used on the
    /// hot path to avoid re-transferring layer weights every call.
    pub fn run_buffers(&self, inputs: &[InputRef<'_>]) -> Result<Vec<Tensor>> {
        // The xla crate's execute_b takes a homogeneous buffer slice, so we
        // first stage any host tensors, then assemble a reference list that
        // mixes the freshly-staged buffers with the caller's staged ones.
        let client = self.exe.client();
        let device = &client.addressable_devices()[0];
        let mut owned: Vec<Option<xla::PjRtBuffer>> =
            Vec::with_capacity(inputs.len());
        // Host→device transfers are asynchronous: the literals must stay
        // alive until the execution's outputs are materialized below.
        let mut live_literals: Vec<xla::Literal> = Vec::new();
        for inp in inputs {
            match inp {
                InputRef::Host(t) => {
                    let lit = t.to_literal()?;
                    owned.push(Some(
                        client.buffer_from_host_literal(Some(device), &lit)?));
                    live_literals.push(lit);
                }
                InputRef::Staged(_) => owned.push(None),
            }
        }
        let borrowed: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .zip(&owned)
            .map(|(inp, o)| match inp {
                InputRef::Host(_) => o.as_ref().unwrap(),
                InputRef::Staged(b) => *b,
            })
            .collect();
        let result = self.exe.execute_b(&borrowed)?;
        let out = result[0][0].to_literal_sync()?;
        drop(live_literals); // outputs materialized -> transfers done
        let parts = out.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// Input to [`Executable::run_buffers`].
pub enum InputRef<'a> {
    Host(&'a Tensor),
    Staged(&'a xla::PjRtBuffer),
}

/// Stage a tensor onto the device once (weights on the hot path).
pub fn stage(rt: &Runtime, t: &Tensor) -> Result<xla::PjRtBuffer> {
    let device = &rt.client.addressable_devices()[0];
    let lit = t.to_literal()?;
    Ok(rt.client.buffer_from_host_literal(Some(device), &lit)?)
}
