//! Execution substrate: the head-parallel worker pool.
//!
//! The paper's economy leaves per-head work inside one layer
//! embarrassingly parallel: once a layer's plans are decided, each
//! head's vertical-slash search, mask packing, abar scatter and
//! cache-validation probe touches only that head's slice of the probe
//! tensors.  [`WorkerPool`] shards exactly that work across OS threads
//! with *deterministic head-indexed result slots*, so `workers = N` is
//! bit-identical to `workers = 1` — the contract the strategy-, engine-
//! and fuzz-level tests assert (see DESIGN.md "Execution model").
//!
//! What stays on the engine thread: everything touching the PJRT
//! runtime (`Rc<Registry>` handles are deliberately not `Send`), the
//! strategy's pivotal dictionary (its insertion order is part of the
//! determinism contract), and the scheduler.  The pool only ever runs
//! pure per-item closures over borrowed host slices.

pub mod pool;

pub use pool::{env_workers, PoolStats, WorkerPool};

/// Spawn a named long-lived OS thread.
///
/// The one sanctioned thread-spawn entry point outside the pool:
/// pallas-lint's layering rule keeps `std::thread` out of every
/// module but `exec`, so actors that need a thread of their own (the
/// serving engine thread in `serving::server`) take it here, where
/// the determinism audit can see every spawn site and the thread gets
/// a name that shows up in panics and sanitizer reports.
pub fn spawn_worker<F>(name: &str, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .expect("invariant: OS thread spawn fails only on resource \
                 exhaustion")
}
