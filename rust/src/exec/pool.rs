//! The worker pool: per-layer scoped fan-out over `std::thread`.
//!
//! No queues, no long-lived workers, no new dependencies: each
//! [`WorkerPool::fan_out`] call spawns at most `workers` scoped threads
//! (`std::thread::scope`), hands each a contiguous shard of the item
//! range, and merges the per-shard results back in index order.  The
//! closure receives the *item index* and must be pure per item — under
//! that contract the returned `Vec` is byte-identical for every worker
//! count, which is what makes the parallel prefill path safe to enable
//! in production without revalidating outputs.
//!
//! Scoped threads (rather than a persistent pool) keep the borrow
//! story simple — closures borrow the caller's probe slices directly,
//! with no `'static` bound, no `Arc`, and no channel plumbing — at the
//! cost of one thread spawn per shard per layer, which is noise next
//! to the per-head attention work being sharded.

use std::cell::RefCell;

/// Cumulative fan-out accounting (observability; never part of the
/// determinism contract).  `span_items` sums the busiest shard's item
/// count per round — the round's critical path in items — so
/// `items / (span_items * workers)` is the count-based worker
/// occupancy, and its shortfall from 1.0 is the shard imbalance (idle
/// worker slots while the busiest shard finishes).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Fan-out rounds executed (serial rounds included).
    pub rounds: u64,
    /// Items processed across all rounds.
    pub items: u64,
    /// Sum over rounds of the busiest shard's item count.
    pub span_items: u64,
}

impl PoolStats {
    /// Count-based worker occupancy in `[0, 1]` for a pool of `workers`
    /// threads; 1.0 when every round filled every worker slot evenly.
    pub fn occupancy(&self, workers: usize) -> f64 {
        let denom = self.span_items.saturating_mul(workers.max(1) as u64);
        if denom == 0 {
            return 1.0;
        }
        self.items as f64 / denom as f64
    }
}

/// Worker count override consumed by the test harness (and the CI
/// matrix): `SHAREPREFILL_WORKERS=<n>`.  Serving defaults stay at the
/// config value — this is for exercising the parallel path on every
/// test run, not for configuring servers.
pub fn env_workers() -> Option<usize> {
    std::env::var("SHAREPREFILL_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
}

/// A fixed-width pool of scoped fan-out workers.  `workers = 1` is the
/// serial path (no threads are ever spawned); any `workers = N` is
/// bit-identical to it by construction.
#[derive(Debug)]
pub struct WorkerPool {
    workers: usize,
    stats: RefCell<PoolStats>,
}

impl WorkerPool {
    /// A pool of `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool {
            workers: workers.max(1),
            stats: RefCell::new(PoolStats::default()),
        }
    }

    /// The always-serial pool (the default everywhere a pool is
    /// optional).
    pub fn serial() -> WorkerPool {
        WorkerPool::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Snapshot of the cumulative fan-out accounting.
    pub fn stats(&self) -> PoolStats {
        *self.stats.borrow()
    }

    /// Compute `f(0), f(1), …, f(items - 1)` and return the results in
    /// index order.  Shards the index range contiguously across up to
    /// `workers` scoped threads; result slot `i` always holds `f(i)`,
    /// so for pure `f` the output is independent of the worker count.
    pub fn fan_out<T, F>(&self, items: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if items == 0 {
            return Vec::new();
        }
        let shards = self.workers.min(items);
        let base = items / shards;
        let extra = items % shards;
        let busiest = base + usize::from(extra > 0);
        {
            let mut s = self.stats.borrow_mut();
            s.rounds += 1;
            s.items += items as u64;
            s.span_items += busiest as u64;
        }
        if shards <= 1 {
            return (0..items).map(f).collect();
        }
        let mut shard_results: Vec<Vec<T>> = Vec::with_capacity(shards);
        std::thread::scope(|scope| {
            let f = &f;
            let mut handles = Vec::with_capacity(shards);
            let mut start = 0usize;
            for s in 0..shards {
                let len = base + usize::from(s < extra);
                let range = start..start + len;
                start += len;
                handles.push(scope.spawn(move || {
                    range.map(f).collect::<Vec<T>>()
                }));
            }
            debug_assert_eq!(start, items);
            for h in handles {
                // a worker panic is a caller bug (the closure must be
                // pure); surface it on the calling thread unchanged
                match h.join() {
                    Ok(r) => shard_results.push(r),
                    Err(e) => std::panic::resume_unwind(e),
                }
            }
        });
        shard_results.into_iter().flatten().collect()
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_land_in_index_order() {
        for workers in [1usize, 2, 3, 4, 9] {
            let pool = WorkerPool::new(workers);
            for items in [0usize, 1, 2, 5, 16, 33] {
                let got = pool.fan_out(items, |i| i * i);
                let want: Vec<usize> = (0..items).map(|i| i * i).collect();
                assert_eq!(got, want,
                           "workers {workers}, items {items}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        // f32 work: the exact bytes must match, not just the values
        let serial = WorkerPool::serial();
        let par = WorkerPool::new(4);
        let f = |i: usize| {
            let mut acc = 0f32;
            for k in 1..=(i + 7) {
                acc += 1.0 / k as f32;
            }
            acc
        };
        let a = serial.fan_out(40, f);
        let b = par.fan_out(40, f);
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb, "parallel fan-out changed f32 bits");
    }

    #[test]
    fn workers_clamp_to_at_least_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        assert_eq!(pool.fan_out(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let pool = WorkerPool::new(16);
        assert_eq!(pool.fan_out(2, |i| i + 1), vec![1, 2]);
    }

    #[test]
    fn fallible_fan_out_selects_lowest_indexed_error() {
        // fallible callers fan out Results and collect: the first
        // error *in index order* wins, deterministic regardless of
        // which shard hit one first
        let pool = WorkerPool::new(4);
        let r: Result<Vec<usize>, String> = pool
            .fan_out(16, |i| {
                if i == 11 || i == 3 {
                    Err(format!("item {i} failed"))
                } else {
                    Ok(i)
                }
            })
            .into_iter()
            .collect();
        assert_eq!(r.unwrap_err(), "item 3 failed",
                   "error selection must be deterministic");
    }

    #[test]
    fn stats_track_rounds_items_and_span() {
        let pool = WorkerPool::new(4);
        // 6 items over 4 workers: shards (2, 2, 1, 1), busiest 2
        pool.fan_out(6, |i| i);
        let s = pool.stats();
        assert_eq!(s.rounds, 1);
        assert_eq!(s.items, 6);
        assert_eq!(s.span_items, 2);
        assert!((s.occupancy(4) - 0.75).abs() < 1e-12);
        // serial pool: occupancy is always 1.0
        let serial = WorkerPool::serial();
        serial.fan_out(6, |i| i);
        assert!((serial.stats().occupancy(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_fan_out_records_nothing() {
        let pool = WorkerPool::new(4);
        let got: Vec<usize> = pool.fan_out(0, |i| i);
        assert!(got.is_empty());
        assert_eq!(pool.stats().rounds, 0);
        assert!((pool.stats().occupancy(4) - 1.0).abs() < 1e-12);
    }
}
