//! Criterion-style micro/end-to-end bench harness (the offline vendor set
//! has no `criterion`; `benches/*.rs` use this with `harness = false`).

pub mod harness;

pub use harness::{Bench, BenchResult};
