//! Minimal benchmarking harness: warmup + timed iterations + summary
//! statistics, markdown report, optional JSON dump for regression diffs.

use std::time::Instant;

use crate::substrate::json::Json;
use crate::util::ascii::markdown_table;
use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub std_ms: f64,
    /// Optional derived metric (e.g. tokens/s) set by the caller.
    pub throughput: Option<(f64, &'static str)>,
}

/// A named group of benchmark cases.
pub struct Bench {
    pub group: String,
    warmup: usize,
    iters: usize,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // BENCH_FAST=1 trims iterations (CI smoke mode).
        let fast = std::env::var("BENCH_FAST").is_ok();
        Bench {
            group: group.to_string(),
            warmup: if fast { 1 } else { 2 },
            iters: if fast { 2 } else { 5 },
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Bench {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Time `f`; it may return a unit count for throughput reporting.
    pub fn case<F>(&mut self, name: &str, mut f: F)
    where
        F: FnMut() -> usize,
    {
        let mut units = 0usize;
        for _ in 0..self.warmup {
            units = f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t = Instant::now();
            units = f();
            s.add(t.elapsed().as_secs_f64() * 1e3);
        }
        let mean_ms = s.mean();
        let throughput = if units > 0 && mean_ms > 0.0 {
            Some((units as f64 / (mean_ms / 1e3), "units/s"))
        } else {
            None
        };
        let r = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ms,
            p50_ms: s.p50(),
            p99_ms: s.percentile(99.0),
            std_ms: s.std(),
            throughput,
        };
        println!("  {:40} {:>10.2} ms ±{:>6.2}", r.name, r.mean_ms, r.std_ms);
        self.results.push(r);
    }

    pub fn report(&self) -> String {
        let rows: Vec<Vec<String>> = self.results.iter().map(|r| {
            vec![
                r.name.clone(),
                format!("{:.2}", r.mean_ms),
                format!("{:.2}", r.p50_ms),
                format!("{:.2}", r.p99_ms),
                format!("{:.2}", r.std_ms),
                r.throughput.map(|(v, u)| format!("{v:.0} {u}"))
                    .unwrap_or_default(),
            ]
        }).collect();
        format!("## {}\n\n{}", self.group, markdown_table(
            &["case", "mean ms", "p50 ms", "p99 ms", "std", "throughput"],
            &rows))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("group", Json::str(self.group.clone())),
            ("results", Json::Arr(self.results.iter().map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("mean_ms", Json::num(r.mean_ms)),
                    ("p99_ms", Json::num(r.p99_ms)),
                ])
            }).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_cases_and_reports() {
        let mut b = Bench::new("g").with_iters(1, 3);
        let mut n = 0;
        b.case("busy", || {
            n += 1;
            std::hint::black_box((0..1000).sum::<usize>());
            1000
        });
        assert_eq!(n, 4); // 1 warmup + 3 timed
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].throughput.is_some());
        let rep = b.report();
        assert!(rep.contains("busy"));
        let j = b.to_json().to_string();
        assert!(j.contains("mean_ms"));
    }
}
