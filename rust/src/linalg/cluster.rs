//! Agglomerative (hierarchical) clustering with average linkage and a
//! distance threshold — the rust equivalent of the paper's
//! `scipy.cluster.hierarchy.fcluster(..., criterion="distance")` step,
//! including the "clusters with fewer than `min_size` members become
//! noise" rule (Appendix A.4).

/// Result of clustering: `assignment[i]` is the cluster id of sample i;
/// id `NOISE` marks noise samples (members of dissolved small clusters).
#[derive(Debug, Clone)]
pub struct Clustering {
    pub assignment: Vec<usize>,
    pub num_clusters: usize,
}

/// Cluster id used for noise samples.
pub const NOISE: usize = usize::MAX;

/// Average-linkage agglomerative clustering.
///
/// * `dist` — condensed pairwise distance accessor (symmetric).
/// * `n` — number of samples.
/// * `threshold` — stop merging when the closest pair of clusters is
///   farther apart than this.
/// * `min_size` — clusters smaller than this are relabeled as `NOISE`.
pub fn agglomerative(n: usize, threshold: f64, min_size: usize,
                     dist: impl Fn(usize, usize) -> f64) -> Clustering {
    if n == 0 {
        return Clustering { assignment: Vec::new(), num_clusters: 0 };
    }
    // active clusters: member lists
    let mut members: Vec<Option<Vec<usize>>> =
        (0..n).map(|i| Some(vec![i])).collect();
    // pairwise average-linkage distances, O(n^2) memory (n = heads, small)
    let mut d = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let v = dist(i, j);
            d[i * n + j] = v;
            d[j * n + i] = v;
        }
    }
    loop {
        // find closest active pair
        let mut best = (f64::INFINITY, 0, 0);
        for i in 0..n {
            if members[i].is_none() {
                continue;
            }
            for j in (i + 1)..n {
                if members[j].is_none() {
                    continue;
                }
                if d[i * n + j] < best.0 {
                    best = (d[i * n + j], i, j);
                }
            }
        }
        let (bd, bi, bj) = best;
        if !bd.is_finite() || bd > threshold {
            break;
        }
        // merge j into i; update average-linkage distances
        let mj = members[bj].take().unwrap();
        let ni = members[bi].as_ref().unwrap().len() as f64;
        let nj = mj.len() as f64;
        members[bi].as_mut().unwrap().extend(mj);
        for k in 0..n {
            if k == bi || members[k].is_none() {
                continue;
            }
            let dik = d[bi * n + k];
            let djk = d[bj * n + k];
            let v = (ni * dik + nj * djk) / (ni + nj);
            d[bi * n + k] = v;
            d[k * n + bi] = v;
        }
    }
    // assign ids; small clusters -> NOISE
    let mut assignment = vec![NOISE; n];
    let mut next_id = 0;
    for m in members.iter().flatten() {
        if m.len() >= min_size {
            for &s in m {
                assignment[s] = next_id;
            }
            next_id += 1;
        }
    }
    Clustering { assignment, num_clusters: next_id }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::euclidean;

    fn points_dist(pts: &[Vec<f64>]) -> impl Fn(usize, usize) -> f64 + '_ {
        move |i, j| euclidean(&pts[i], &pts[j])
    }

    #[test]
    fn two_blobs() {
        let mut pts = Vec::new();
        for i in 0..5 {
            pts.push(vec![0.0 + i as f64 * 0.01, 0.0]);
        }
        for i in 0..5 {
            pts.push(vec![10.0 + i as f64 * 0.01, 0.0]);
        }
        let c = agglomerative(10, 1.0, 2, points_dist(&pts));
        assert_eq!(c.num_clusters, 2);
        let a0 = c.assignment[0];
        assert!(c.assignment[..5].iter().all(|&a| a == a0));
        let a5 = c.assignment[5];
        assert_ne!(a0, a5);
        assert!(c.assignment[5..].iter().all(|&a| a == a5));
    }

    #[test]
    fn threshold_monotone() {
        // larger threshold merges more -> fewer (or equal) clusters
        let pts: Vec<Vec<f64>> =
            (0..12).map(|i| vec![i as f64, 0.0]).collect();
        let mut prev = usize::MAX;
        for th in [0.5, 1.5, 3.0, 20.0] {
            let c = agglomerative(12, th, 1, points_dist(&pts));
            assert!(c.num_clusters <= prev);
            prev = c.num_clusters;
        }
    }

    #[test]
    fn small_clusters_become_noise() {
        let pts = vec![
            vec![0.0], vec![0.1], vec![0.2],  // blob of 3
            vec![50.0],                        // singleton -> noise
        ];
        let c = agglomerative(4, 1.0, 2, points_dist(&pts));
        assert_eq!(c.num_clusters, 1);
        assert_eq!(c.assignment[3], NOISE);
        assert!(c.assignment[..3].iter().all(|&a| a == 0));
    }

    #[test]
    fn partition_property() {
        let pts: Vec<Vec<f64>> =
            (0..8).map(|i| vec![(i % 4) as f64 * 5.0, (i / 4) as f64]).collect();
        let c = agglomerative(8, 2.0, 1, points_dist(&pts));
        // every sample assigned (min_size 1 -> no noise)
        assert!(c.assignment.iter().all(|&a| a != NOISE));
        // ids are compact
        assert!(c.assignment.iter().all(|&a| a < c.num_clusters));
    }

    #[test]
    fn empty_input() {
        let c = agglomerative(0, 1.0, 1, |_, _| 0.0);
        assert_eq!(c.num_clusters, 0);
    }
}
