//! Tiny dense linear algebra: row-major matrices, matmul, Jacobi
//! eigensolver and PCA.  Sized for the offline clustering pipeline
//! (hundreds of heads × ≤256 features), not for the model hot path —
//! model compute runs in the compiled HLO artifacts.

pub mod cluster;
pub mod pca;

/// Row-major f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|row| row.len() == c));
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Column means.
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                m[j] += self[(i, j)];
            }
        }
        m.iter_mut().for_each(|x| *x /= self.rows.max(1) as f64);
        m
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Euclidean distance between two equal-length slices.
pub fn euclidean(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Cosine similarity (0 on zero vectors).
pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::identity(2);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn distances() {
        assert!((euclidean(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert_eq!(cosine(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn col_means() {
        let a = Mat::from_rows(vec![vec![1.0, 10.0], vec![3.0, 20.0]]);
        assert_eq!(a.col_means(), vec![2.0, 15.0]);
    }
}
