//! PCA via cyclic Jacobi eigendecomposition of the covariance matrix.
//!
//! This is the rust stand-in for the paper's offline conv-autoencoder
//! (Appendix C): both compress each head's attention-score map to a
//! low-dimensional representation before hierarchical clustering; PCA is
//! the optimal *linear* autoencoder, and the cluster structure it feeds is
//! what matters downstream (DESIGN.md "Substitutions").

use super::Mat;

/// Eigendecomposition of a symmetric matrix by the cyclic Jacobi method.
/// Returns (eigenvalues, eigenvectors as columns), sorted descending.
pub fn symmetric_eig(a: &Mat, sweeps: usize) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::identity(n);
    for _ in 0..sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[(p, q)] * m[(p, q)];
            }
        }
        if off < 1e-20 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum()
                    / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(j, j)].partial_cmp(&m[(i, i)]).unwrap());
    let vals: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let mut vecs = Mat::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..n {
            vecs[(r, newc)] = v[(r, oldc)];
        }
    }
    (vals, vecs)
}

/// PCA projection: rows of `x` (samples × features) → samples × k scores.
/// Also returns the explained-variance ratio per component.
pub fn pca(x: &Mat, k: usize) -> (Mat, Vec<f64>) {
    let n = x.rows;
    let d = x.cols;
    let k = k.min(d);
    let means = x.col_means();
    let mut centered = x.clone();
    for i in 0..n {
        for j in 0..d {
            centered[(i, j)] -= means[j];
        }
    }
    // covariance d×d
    let mut cov = Mat::zeros(d, d);
    for i in 0..n {
        let row = centered.row(i);
        for a in 0..d {
            let ra = row[a];
            if ra == 0.0 {
                continue;
            }
            for b in a..d {
                cov[(a, b)] += ra * row[b];
            }
        }
    }
    for a in 0..d {
        for b in a..d {
            let v = cov[(a, b)] / (n.max(2) - 1) as f64;
            cov[(a, b)] = v;
            cov[(b, a)] = v;
        }
    }
    let (vals, vecs) = symmetric_eig(&cov, 30);
    let total: f64 = vals.iter().map(|v| v.max(0.0)).sum::<f64>().max(1e-30);
    let ratios: Vec<f64> =
        vals.iter().take(k).map(|v| v.max(0.0) / total).collect();
    // scores = centered · vecs[:, :k]
    let mut proj = Mat::zeros(d, k);
    for r in 0..d {
        for c in 0..k {
            proj[(r, c)] = vecs[(r, c)];
        }
    }
    (centered.matmul(&proj), ratios)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eig_of_diagonal() {
        let a = Mat::from_rows(vec![vec![3.0, 0.0], vec![0.0, 1.0]]);
        let (vals, _) = symmetric_eig(&a, 10);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = symmetric_eig(&a, 20);
        assert!((vals[0] - 3.0).abs() < 1e-9);
        assert!((vals[1] - 1.0).abs() < 1e-9);
        // eigenvector for 3 is (1,1)/sqrt(2)
        let ratio = vecs[(0, 0)] / vecs[(1, 0)];
        assert!((ratio - 1.0).abs() < 1e-6);
    }

    #[test]
    fn eig_reconstructs() {
        let a = Mat::from_rows(vec![
            vec![4.0, 1.0, 0.5],
            vec![1.0, 3.0, 0.2],
            vec![0.5, 0.2, 1.0],
        ]);
        let (vals, vecs) = symmetric_eig(&a, 30);
        // A·v = λ·v for each column
        for c in 0..3 {
            for r in 0..3 {
                let av: f64 = (0..3).map(|k| a[(r, k)] * vecs[(k, c)]).sum();
                assert!((av - vals[c] * vecs[(r, c)]).abs() < 1e-8,
                        "col {c} row {r}");
            }
        }
    }

    #[test]
    fn pca_finds_dominant_direction() {
        // points along (1, 1) with small noise in (1, -1)
        let mut rows = Vec::new();
        for i in 0..50 {
            let t = i as f64 / 10.0;
            let noise = if i % 2 == 0 { 0.01 } else { -0.01 };
            rows.push(vec![t + noise, t - noise]);
        }
        let x = Mat::from_rows(rows);
        let (scores, ratios) = pca(&x, 2);
        assert!(ratios[0] > 0.99, "ratios {ratios:?}");
        assert_eq!(scores.rows, 50);
        assert_eq!(scores.cols, 2);
    }

    #[test]
    fn pca_k_larger_than_dims_clamped() {
        let x = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 4.0]]);
        let (scores, _) = pca(&x, 10);
        assert_eq!(scores.cols, 2);
    }
}
