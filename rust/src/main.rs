//! `shareprefill` — CLI entry point.
//!
//! Subcommands (see `--help`):
//!   serve      run the serving engine on a synthetic request stream
//!   eval       InfiniteBench-sim task suite (Table 1)
//!   ablate     ablation variants (Table 2)
//!   ppl        PG19-sim perplexity sweep (Figure 4)
//!   latency    prefill latency sweep (Figure 5)
//!   patterns   attention-pattern / similarity / distribution dumps
//!              (Figures 2 & 6)
//!   cluster    offline head clustering -> artifacts/head_clusters-*.json
//!   inspect    artifact registry / manifest info

fn main() {
    if let Err(e) = shareprefill::run_cli() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
