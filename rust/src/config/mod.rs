//! Config system: method hyper-parameters (the paper's τ, δ, γ), serving
//! parameters, and path wiring.  Loaded from a TOML file (`--config`) with
//! CLI overrides; every field has the paper's default.

use crate::substrate::{cli::Args, tomlmini};
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Which sparse-attention method drives prefill.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MethodKind {
    /// Dense FlashAttention-2 baseline.
    Flash,
    /// FlashPrefill-style thresholded discovery: vertical-slash patterns
    /// selected by thresholding the probe map directly (no sort, no
    /// cumulative scan); γ calibrates the threshold.
    FlashPrefill,
    /// MInference: per-head dynamic vertical-slash (default config of the
    /// paper's comparison).
    MInference,
    /// FlexPrefill: pooled query-aware block patterns + vslash fallback.
    FlexPrefill,
    /// The paper's contribution.
    SharePrefill,
}

impl MethodKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "flash" | "flashattn" | "dense" => MethodKind::Flash,
            "flashprefill" | "threshold" => MethodKind::FlashPrefill,
            "minference" => MethodKind::MInference,
            "flexprefill" | "flex" => MethodKind::FlexPrefill,
            "shareprefill" | "ours" | "share" => MethodKind::SharePrefill,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::Flash => "FlashAttn",
            MethodKind::FlashPrefill => "FlashPrefill",
            MethodKind::MInference => "MInference",
            MethodKind::FlexPrefill => "FlexPrefill",
            MethodKind::SharePrefill => "SharePrefill",
        }
    }

    pub fn all() -> [MethodKind; 5] {
        [MethodKind::Flash, MethodKind::FlashPrefill,
         MethodKind::MInference, MethodKind::FlexPrefill,
         MethodKind::SharePrefill]
    }
}

/// Hyper-parameters of the pattern engine (paper Section 6.1 defaults).
#[derive(Debug, Clone)]
pub struct MethodConfig {
    pub kind: MethodKind,
    /// Similarity threshold τ (JS distance below which patterns are shared).
    pub tau: f64,
    /// Sparsity threshold δ (JS distance to uniform above which a head is
    /// "highly sparse" and excluded from sharing).
    pub delta: f64,
    /// Cumulative attention threshold γ for pattern construction.
    /// Paper default is 0.9 on 128K-context 8B models; on this testbed's
    /// tiny models / short buckets the attention distributions are flatter,
    /// so γ=0.65 reproduces the paper's *kept-density regime* (~10–40%
    /// of blocks).  Pass --gamma 0.9 for the literal paper value.
    pub gamma: f32,
    /// FlexPrefill's pattern-decision threshold (its own τ).
    pub flex_tau: f64,
    /// Path to the offline cluster file (SharePrefill only).
    pub clusters_file: Option<PathBuf>,
}

impl Default for MethodConfig {
    fn default() -> Self {
        MethodConfig {
            kind: MethodKind::SharePrefill,
            tau: 0.2,
            delta: 0.3,
            gamma: 0.65,
            flex_tau: 0.1,
            clusters_file: None,
        }
    }
}

/// Cross-request pattern cache knobs (`serve.pattern_cache` in TOML).
///
/// The cache reuses pivotal patterns observed on earlier requests
/// (length-bucketed) so warm requests skip the dense pivotal bootstrap
/// for heads whose cached pattern passes a cheap probe-recall
/// validation.  Off by default: with `enabled = false` the serving
/// stack is bit-identical to a cache-less build.
#[derive(Debug, Clone)]
pub struct PatternCacheConfig {
    /// Master switch; false = never consult or populate the cache.
    pub enabled: bool,
    /// Max cached patterns across all length buckets (LRU eviction).
    pub capacity: usize,
    /// Probe-recall threshold a cached pattern must pass per head: the
    /// fraction of the request's observed last-row attention mass the
    /// cached mask covers.  Below it the head falls back to the exact
    /// (dense bootstrap) path — a stale pattern is never used silently.
    pub validation: f64,
    /// Publishes an entry may survive without being refreshed before it
    /// is treated as stale and dropped on lookup.
    pub max_age: u64,
}

impl Default for PatternCacheConfig {
    fn default() -> Self {
        PatternCacheConfig {
            enabled: false,
            capacity: 256,
            validation: 0.75,
            max_age: 64,
        }
    }
}

/// Prefix-sharing KV cache knobs (`serve.prefix_cache` in TOML).
///
/// The cache reuses *KV blocks* across requests: completed prefills
/// publish their full prompt chunks under a chained content hash, warm
/// requests retain the longest matched prefix and start prefill at the
/// first divergent chunk (copy-on-write on the allocator keeps shared
/// blocks immutable).  Off by default: with `enabled = false` the
/// serving stack is bit-identical to a build without the index.
#[derive(Debug, Clone)]
pub struct PrefixCacheConfig {
    /// Master switch; false = never consult or populate the index.
    pub enabled: bool,
    /// Max cached chunk entries in the prefix index (LRU eviction;
    /// each entry pins one KV block per layer until evicted).
    pub capacity: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig {
            enabled: false,
            capacity: 512,
        }
    }
}

/// SLO-aware admission control + overload degradation knobs
/// (`serve.admission` in TOML).
///
/// Off by default: with `enabled = false` submit-time admission, the
/// per-class priority, queue deadlines, and the degradation ladder are
/// all inert and the serving stack is bit-identical to a build without
/// them.  Each sub-knob additionally treats `0` as "off" so the
/// features can be engaged independently.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Master switch; false = FIFO admission exactly as before.
    pub enabled: bool,
    /// Early back-pressure: reject at submit with `QueueDepth` once the
    /// queue holds this many sessions (0 = only the hard
    /// `queue_capacity` wall rejects).  Interactive-class requests are
    /// exempt and may use the full queue capacity.
    pub max_queue_depth: usize,
    /// KV headroom ceiling as a fraction of allocator capacity: reject
    /// at submit with `KvHeadroom` when held + queued demand + this
    /// request's whole-lifetime blocks exceeds `kv_overcommit ×
    /// kv_blocks` (0.0 = off).  Values > 1.0 deliberately overcommit,
    /// betting on queued sessions completing before admission.
    pub kv_overcommit: f64,
    /// Deadline proxy in scheduler rounds: a queued session that has
    /// waited more than this many rounds is shed with
    /// `DeadlineExceeded` instead of served uselessly late (0 = wait
    /// forever).  Rounds, not wall time, so virtual-time (SimEngine)
    /// runs are deterministic.
    pub max_queue_rounds: usize,
    /// Request-class boundary: prompts of at most this many tokens are
    /// "interactive" — admitted ahead of batch requests, exempt from
    /// `max_queue_depth`, and tracked in the per-class TTFT histograms
    /// (0 = single-class traffic, no reordering).
    pub interactive_max_tokens: usize,
    /// Degradation ladder trigger: queue depth at which the scheduler
    /// enters degraded mode (0 = never degrade).
    pub degrade_queue_depth: usize,
    /// Degraded mode: round budget shrinks to this percentage of
    /// `max_batch_tokens` (100 = unchanged), trading per-round
    /// throughput for faster round turnaround (admission, deadlines,
    /// and decode latency are all per-round).
    pub degraded_budget_pct: usize,
    /// Degraded mode: cap on concurrent prefills (0 = unchanged);
    /// fewer interleaved prefills means less KV held half-finished
    /// under pressure.
    pub degraded_max_prefills: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            enabled: false,
            max_queue_depth: 0,
            kv_overcommit: 0.0,
            max_queue_rounds: 0,
            interactive_max_tokens: 0,
            degrade_queue_depth: 0,
            degraded_budget_pct: 100,
            degraded_max_prefills: 0,
        }
    }
}

/// Serving engine parameters.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Token budget per prefill batch admitted by the dynamic batcher.
    pub max_batch_tokens: usize,
    /// Max requests admitted per scheduling round.
    pub max_batch_requests: usize,
    /// Queue capacity before admission rejects.
    pub queue_capacity: usize,
    /// Decode steps per request after prefill.
    pub decode_tokens: usize,
    /// KV cache capacity in blocks (paged allocator).
    pub kv_blocks: usize,
    /// Layers advanced per prefill chunk (1 = finest interleaving of
    /// decode steps between chunks; `num_layers` = monolithic prefill).
    pub chunk_layers: usize,
    /// Prefills the scheduler interleaves concurrently (pattern state is
    /// per-request, so any value is sound).  1 = the old strictly-serial
    /// prefill pipeline; the default 2 lets a short prompt overtake a
    /// long prefill (shortest-remaining-work-first fairness).
    pub max_concurrent_prefills: usize,
    /// Rounds a KV-starved request waits at the head of the queue before
    /// it is rejected (bounded re-queueing; clients never hang).
    pub admit_retries: usize,
    /// Head-parallel prefill workers: per-head host work inside each
    /// layer (vslash searches, mask packing, abar scatter, cache
    /// validation probes) fans out across this many threads with
    /// head-indexed result slots.  1 (the default) is the serial path;
    /// any `N` is bit-identical to it — only faster.
    pub workers: usize,
    /// Engine shards behind the fleet front door (`serving::fleet`):
    /// each shard is an actor-style worker owning its own scheduler, KV
    /// cache and worker pool, fed by a per-shard mailbox and placed by
    /// the load-aware session-affine router.  1 (the default) is the
    /// single-engine path, bit-identical to a fleet-less build.
    pub shards: usize,
    /// Cross-request pivotal-pattern cache (SharePrefill only).
    pub pattern_cache: PatternCacheConfig,
    /// Content-addressed prefix-sharing KV cache (method-agnostic).
    pub prefix_cache: PrefixCacheConfig,
    /// SLO-aware admission control + overload degradation.
    pub admission: AdmissionConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch_tokens: 8192,
            max_batch_requests: 8,
            queue_capacity: 256,
            decode_tokens: 8,
            kv_blocks: 1024,
            chunk_layers: 1,
            max_concurrent_prefills: 2,
            admit_retries: 4,
            workers: 1,
            shards: 1,
            pattern_cache: PatternCacheConfig::default(),
            prefix_cache: PrefixCacheConfig::default(),
            admission: AdmissionConfig::default(),
        }
    }
}

/// Paths to build outputs.
#[derive(Debug, Clone)]
pub struct Paths {
    pub artifacts: PathBuf,
}

impl Default for Paths {
    fn default() -> Self {
        Paths { artifacts: PathBuf::from("artifacts") }
    }
}

/// Full runtime configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub method: MethodConfig,
    pub serve: ServeConfig,
    pub paths: Paths,
}

impl Config {
    /// Load from optional TOML file, then apply CLI overrides.
    pub fn load(args: &Args) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(path) = args.opt("config") {
            let text = std::fs::read_to_string(path)?;
            cfg.apply_toml(&tomlmini::parse(&text)?)?;
        }
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, t: &tomlmini::Toml) -> Result<()> {
        if let Some(v) = t.get("method.kind") {
            self.method.kind = MethodKind::parse(v.as_str()?)?;
        }
        self.method.tau = t.f64_or("method.tau", self.method.tau);
        self.method.delta = t.f64_or("method.delta", self.method.delta);
        self.method.gamma = t.f64_or("method.gamma",
                                     self.method.gamma as f64) as f32;
        self.method.flex_tau = t.f64_or("method.flex_tau",
                                        self.method.flex_tau);
        if let Some(v) = t.get("method.clusters_file") {
            self.method.clusters_file = Some(PathBuf::from(v.as_str()?));
        }
        self.serve.max_batch_tokens =
            t.usize_or("serve.max_batch_tokens", self.serve.max_batch_tokens);
        self.serve.max_batch_requests = t.usize_or(
            "serve.max_batch_requests", self.serve.max_batch_requests);
        self.serve.queue_capacity =
            t.usize_or("serve.queue_capacity", self.serve.queue_capacity);
        self.serve.decode_tokens =
            t.usize_or("serve.decode_tokens", self.serve.decode_tokens);
        self.serve.kv_blocks =
            t.usize_or("serve.kv_blocks", self.serve.kv_blocks);
        self.serve.chunk_layers =
            t.usize_or("serve.chunk_layers", self.serve.chunk_layers);
        self.serve.max_concurrent_prefills =
            t.usize_or("serve.max_concurrent_prefills",
                       self.serve.max_concurrent_prefills);
        self.serve.admit_retries =
            t.usize_or("serve.admit_retries", self.serve.admit_retries);
        self.serve.workers =
            t.usize_or("serve.workers", self.serve.workers).max(1);
        self.serve.shards =
            t.usize_or("serve.shards", self.serve.shards).max(1);
        let pc = &mut self.serve.pattern_cache;
        pc.enabled = t.bool_or("serve.pattern_cache.enabled", pc.enabled);
        pc.capacity =
            t.usize_or("serve.pattern_cache.capacity", pc.capacity);
        pc.validation =
            t.f64_or("serve.pattern_cache.validation", pc.validation);
        pc.max_age =
            t.usize_or("serve.pattern_cache.max_age", pc.max_age as usize)
                as u64;
        let px = &mut self.serve.prefix_cache;
        px.enabled = t.bool_or("serve.prefix_cache.enabled", px.enabled);
        px.capacity =
            t.usize_or("serve.prefix_cache.capacity", px.capacity);
        let ad = &mut self.serve.admission;
        ad.enabled = t.bool_or("serve.admission.enabled", ad.enabled);
        ad.max_queue_depth = t.usize_or("serve.admission.max_queue_depth",
                                        ad.max_queue_depth);
        ad.kv_overcommit = t.f64_or("serve.admission.kv_overcommit",
                                    ad.kv_overcommit);
        ad.max_queue_rounds =
            t.usize_or("serve.admission.max_queue_rounds",
                       ad.max_queue_rounds);
        ad.interactive_max_tokens =
            t.usize_or("serve.admission.interactive_max_tokens",
                       ad.interactive_max_tokens);
        ad.degrade_queue_depth =
            t.usize_or("serve.admission.degrade_queue_depth",
                       ad.degrade_queue_depth);
        ad.degraded_budget_pct =
            t.usize_or("serve.admission.degraded_budget_pct",
                       ad.degraded_budget_pct);
        ad.degraded_max_prefills =
            t.usize_or("serve.admission.degraded_max_prefills",
                       ad.degraded_max_prefills);
        if let Some(v) = t.get("paths.artifacts") {
            self.paths.artifacts = PathBuf::from(v.as_str()?);
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(m) = args.opt("method") {
            self.method.kind = MethodKind::parse(m)?;
        }
        self.method.tau = args.f64_or("tau", self.method.tau)?;
        self.method.delta = args.f64_or("delta", self.method.delta)?;
        self.method.gamma = args.f64_or("gamma",
                                        self.method.gamma as f64)? as f32;
        if let Some(p) = args.opt("clusters") {
            self.method.clusters_file = Some(PathBuf::from(p));
        }
        if let Some(p) = args.opt("artifacts") {
            self.paths.artifacts = PathBuf::from(p);
        }
        self.serve.decode_tokens =
            args.usize_or("decode-tokens", self.serve.decode_tokens)?;
        self.serve.max_batch_tokens =
            args.usize_or("max-batch-tokens", self.serve.max_batch_tokens)?;
        self.serve.max_batch_requests =
            args.usize_or("max-batch-requests",
                          self.serve.max_batch_requests)?;
        self.serve.queue_capacity =
            args.usize_or("queue-capacity", self.serve.queue_capacity)?;
        self.serve.kv_blocks =
            args.usize_or("kv-blocks", self.serve.kv_blocks)?;
        self.serve.chunk_layers =
            args.usize_or("chunk-layers", self.serve.chunk_layers)?;
        self.serve.max_concurrent_prefills =
            args.usize_or("max-concurrent-prefills",
                          self.serve.max_concurrent_prefills)?;
        self.serve.admit_retries =
            args.usize_or("admit-retries", self.serve.admit_retries)?;
        self.serve.workers =
            args.usize_or("workers", self.serve.workers)?.max(1);
        self.serve.shards =
            args.usize_or("shards", self.serve.shards)?.max(1);
        if args.flag("pattern-cache") {
            self.serve.pattern_cache.enabled = true;
        }
        let pc = &mut self.serve.pattern_cache;
        pc.capacity = args.usize_or("pattern-cache-capacity", pc.capacity)?;
        pc.validation =
            args.f64_or("pattern-cache-validation", pc.validation)?;
        pc.max_age =
            args.usize_or("pattern-cache-max-age", pc.max_age as usize)?
                as u64;
        if args.flag("prefix-cache") {
            self.serve.prefix_cache.enabled = true;
        }
        let px = &mut self.serve.prefix_cache;
        px.capacity = args.usize_or("prefix-cache-capacity", px.capacity)?;
        if args.flag("admission-enabled") {
            self.serve.admission.enabled = true;
        }
        let ad = &mut self.serve.admission;
        ad.max_queue_depth =
            args.usize_or("admission-max-queue-depth",
                          ad.max_queue_depth)?;
        ad.kv_overcommit =
            args.f64_or("admission-kv-overcommit", ad.kv_overcommit)?;
        ad.max_queue_rounds =
            args.usize_or("admission-max-queue-rounds",
                          ad.max_queue_rounds)?;
        ad.interactive_max_tokens =
            args.usize_or("admission-interactive-max-tokens",
                          ad.interactive_max_tokens)?;
        ad.degrade_queue_depth =
            args.usize_or("admission-degrade-queue-depth",
                          ad.degrade_queue_depth)?;
        ad.degraded_budget_pct =
            args.usize_or("admission-degraded-budget-pct",
                          ad.degraded_budget_pct)?;
        ad.degraded_max_prefills =
            args.usize_or("admission-degraded-max-prefills",
                          ad.degraded_max_prefills)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.method.kind, MethodKind::SharePrefill);
        assert!((c.method.tau - 0.2).abs() < 1e-12);
        assert!((c.method.delta - 0.3).abs() < 1e-12);
        assert!((c.method.gamma - 0.65).abs() < 1e-6);
        assert_eq!(c.serve.chunk_layers, 1);
        assert_eq!(c.serve.max_concurrent_prefills, 2);
        assert_eq!(c.serve.admit_retries, 4);
        assert_eq!(c.serve.workers, 1, "serial prefill is the default");
        assert_eq!(c.serve.shards, 1, "single engine is the default");
    }

    #[test]
    fn shards_knob_toml_and_cli() {
        let t = tomlmini::parse("[serve]\nshards = 4\n").unwrap();
        let mut c = Config::default();
        c.apply_toml(&t).unwrap();
        assert_eq!(c.serve.shards, 4);
        let args = Args::parse(
            ["x", "--shards", "2"].map(String::from), &[]).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.serve.shards, 2);
        // 0 clamps to the single-engine path
        let zero = Args::parse(
            ["x", "--shards", "0"].map(String::from), &[]).unwrap();
        c.apply_args(&zero).unwrap();
        assert_eq!(c.serve.shards, 1);
    }

    #[test]
    fn workers_knob_toml_and_cli() {
        let t = tomlmini::parse("[serve]\nworkers = 4\n").unwrap();
        let mut c = Config::default();
        c.apply_toml(&t).unwrap();
        assert_eq!(c.serve.workers, 4);
        let args = Args::parse(
            ["x", "--workers", "2"].map(String::from), &[]).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.serve.workers, 2);
        // 0 clamps to the serial path instead of misconfiguring the pool
        let zero = Args::parse(
            ["x", "--workers", "0"].map(String::from), &[]).unwrap();
        c.apply_args(&zero).unwrap();
        assert_eq!(c.serve.workers, 1);
    }

    #[test]
    fn toml_overrides() {
        let t = tomlmini::parse(
            "[method]\nkind = \"flexprefill\"\ntau = 0.5\n\
             [serve]\ndecode_tokens = 3\nchunk_layers = 2\n\
             max_concurrent_prefills = 4\n").unwrap();
        let mut c = Config::default();
        c.apply_toml(&t).unwrap();
        assert_eq!(c.method.kind, MethodKind::FlexPrefill);
        assert!((c.method.tau - 0.5).abs() < 1e-12);
        assert_eq!(c.serve.decode_tokens, 3);
        assert_eq!(c.serve.chunk_layers, 2);
        assert_eq!(c.serve.max_concurrent_prefills, 4);
    }

    #[test]
    fn pattern_cache_defaults_off() {
        let c = Config::default();
        assert!(!c.serve.pattern_cache.enabled);
        assert_eq!(c.serve.pattern_cache.capacity, 256);
        assert!((c.serve.pattern_cache.validation - 0.75).abs() < 1e-12);
        assert_eq!(c.serve.pattern_cache.max_age, 64);
    }

    #[test]
    fn pattern_cache_toml_overrides() {
        let t = tomlmini::parse(
            "[serve.pattern_cache]\nenabled = true\ncapacity = 8\n\
             validation = 0.9\nmax_age = 3\n").unwrap();
        let mut c = Config::default();
        c.apply_toml(&t).unwrap();
        assert!(c.serve.pattern_cache.enabled);
        assert_eq!(c.serve.pattern_cache.capacity, 8);
        assert!((c.serve.pattern_cache.validation - 0.9).abs() < 1e-12);
        assert_eq!(c.serve.pattern_cache.max_age, 3);
    }

    #[test]
    fn pattern_cache_cli_overrides() {
        let args = Args::parse(
            ["x", "--pattern-cache", "--pattern-cache-capacity", "16",
             "--pattern-cache-validation", "0.5"]
                .map(String::from), &["pattern-cache"]).unwrap();
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        assert!(c.serve.pattern_cache.enabled);
        assert_eq!(c.serve.pattern_cache.capacity, 16);
        assert!((c.serve.pattern_cache.validation - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prefix_cache_defaults_off() {
        // bit-identity contract: the index must be inert out of the box
        let c = Config::default();
        assert!(!c.serve.prefix_cache.enabled);
        assert_eq!(c.serve.prefix_cache.capacity, 512);
    }

    #[test]
    fn prefix_cache_toml_overrides() {
        let t = tomlmini::parse(
            "[serve.prefix_cache]\nenabled = true\ncapacity = 12\n")
            .unwrap();
        let mut c = Config::default();
        c.apply_toml(&t).unwrap();
        assert!(c.serve.prefix_cache.enabled);
        assert_eq!(c.serve.prefix_cache.capacity, 12);
    }

    #[test]
    fn prefix_cache_cli_overrides() {
        let args = Args::parse(
            ["x", "--prefix-cache", "--prefix-cache-capacity", "33"]
                .map(String::from), &["prefix-cache"]).unwrap();
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        assert!(c.serve.prefix_cache.enabled);
        assert_eq!(c.serve.prefix_cache.capacity, 33);
    }

    #[test]
    fn cli_max_concurrent_prefills() {
        let args = Args::parse(
            ["x", "--max-concurrent-prefills", "1"]
                .map(String::from), &[]).unwrap();
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.serve.max_concurrent_prefills, 1);
    }

    #[test]
    fn cli_capacity_knobs() {
        let args = Args::parse(
            ["x", "--kv-blocks", "64", "--queue-capacity", "9",
             "--max-batch-requests", "2", "--max-batch-tokens", "512"]
                .map(String::from), &[]).unwrap();
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.serve.kv_blocks, 64);
        assert_eq!(c.serve.queue_capacity, 9);
        assert_eq!(c.serve.max_batch_requests, 2);
        assert_eq!(c.serve.max_batch_tokens, 512);
    }

    // Every serve.* knob must survive tomlmini parse -> emit -> parse
    // (the knob-hygiene rule's sibling guarantee: what the config
    // layer reads, a tool can re-emit without loss).
    #[test]
    fn serve_knobs_survive_toml_roundtrip() {
        let doc = "\
[serve]
max_batch_tokens = 4096
max_batch_requests = 5
queue_capacity = 99
decode_tokens = 7
kv_blocks = 333
chunk_layers = 2
max_concurrent_prefills = 3
admit_retries = 6
workers = 4
shards = 3

[serve.pattern_cache]
enabled = true
capacity = 17
validation = 0.6
max_age = 9

[serve.prefix_cache]
enabled = true
capacity = 41

[serve.admission]
enabled = true
max_queue_depth = 11
kv_overcommit = 1.5
max_queue_rounds = 21
interactive_max_tokens = 257
degrade_queue_depth = 13
degraded_budget_pct = 55
degraded_max_prefills = 2
";
        let t1 = tomlmini::parse(doc).unwrap();
        let t2 = tomlmini::parse(&tomlmini::emit(&t1)).unwrap();
        assert_eq!(t1.entries, t2.entries);
        let mut c = Config::default();
        c.apply_toml(&t2).unwrap();
        // every value deliberately differs from the default, so a
        // knob silently dropped by emit would fail its assert
        assert_eq!(c.serve.max_batch_tokens, 4096);
        assert_eq!(c.serve.max_batch_requests, 5);
        assert_eq!(c.serve.queue_capacity, 99);
        assert_eq!(c.serve.decode_tokens, 7);
        assert_eq!(c.serve.kv_blocks, 333);
        assert_eq!(c.serve.chunk_layers, 2);
        assert_eq!(c.serve.max_concurrent_prefills, 3);
        assert_eq!(c.serve.admit_retries, 6);
        assert_eq!(c.serve.workers, 4);
        assert_eq!(c.serve.shards, 3);
        assert!(c.serve.pattern_cache.enabled);
        assert_eq!(c.serve.pattern_cache.capacity, 17);
        assert!((c.serve.pattern_cache.validation - 0.6).abs() < 1e-12);
        assert_eq!(c.serve.pattern_cache.max_age, 9);
        assert!(c.serve.prefix_cache.enabled);
        assert_eq!(c.serve.prefix_cache.capacity, 41);
        assert!(c.serve.admission.enabled);
        assert_eq!(c.serve.admission.max_queue_depth, 11);
        assert!((c.serve.admission.kv_overcommit - 1.5).abs() < 1e-12);
        assert_eq!(c.serve.admission.max_queue_rounds, 21);
        assert_eq!(c.serve.admission.interactive_max_tokens, 257);
        assert_eq!(c.serve.admission.degrade_queue_depth, 13);
        assert_eq!(c.serve.admission.degraded_budget_pct, 55);
        assert_eq!(c.serve.admission.degraded_max_prefills, 2);
    }

    #[test]
    fn admission_defaults_off() {
        // bit-identity contract: every admission knob defaults to the
        // value that makes the new machinery inert
        let a = Config::default().serve.admission;
        assert!(!a.enabled);
        assert_eq!(a.max_queue_depth, 0);
        assert_eq!(a.kv_overcommit, 0.0);
        assert_eq!(a.max_queue_rounds, 0);
        assert_eq!(a.interactive_max_tokens, 0);
        assert_eq!(a.degrade_queue_depth, 0);
        assert_eq!(a.degraded_budget_pct, 100);
        assert_eq!(a.degraded_max_prefills, 0);
    }

    #[test]
    fn admission_cli_overrides() {
        let args = Args::parse(
            ["x", "--admission-enabled",
             "--admission-max-queue-depth", "6",
             "--admission-kv-overcommit", "2.0",
             "--admission-max-queue-rounds", "40",
             "--admission-interactive-max-tokens", "128",
             "--admission-degrade-queue-depth", "4",
             "--admission-degraded-budget-pct", "50",
             "--admission-degraded-max-prefills", "1"]
                .map(String::from), &["admission-enabled"]).unwrap();
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        let a = &c.serve.admission;
        assert!(a.enabled);
        assert_eq!(a.max_queue_depth, 6);
        assert!((a.kv_overcommit - 2.0).abs() < 1e-12);
        assert_eq!(a.max_queue_rounds, 40);
        assert_eq!(a.interactive_max_tokens, 128);
        assert_eq!(a.degrade_queue_depth, 4);
        assert_eq!(a.degraded_budget_pct, 50);
        assert_eq!(a.degraded_max_prefills, 1);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["x", "--method", "flash", "--gamma", "0.8"]
                .map(String::from), &[]).unwrap();
        let mut c = Config::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.method.kind, MethodKind::Flash);
        assert!((c.method.gamma - 0.8).abs() < 1e-6);
    }

    #[test]
    fn method_parse_aliases() {
        assert_eq!(MethodKind::parse("ours").unwrap(),
                   MethodKind::SharePrefill);
        assert_eq!(MethodKind::parse("dense").unwrap(), MethodKind::Flash);
        assert_eq!(MethodKind::parse("flashprefill").unwrap(),
                   MethodKind::FlashPrefill);
        assert_eq!(MethodKind::parse("threshold").unwrap(),
                   MethodKind::FlashPrefill);
        assert!(MethodKind::parse("bogus").is_err());
        // every kind's canonical name round-trips through parse
        for k in MethodKind::all() {
            assert_eq!(MethodKind::parse(k.name()).unwrap(), k);
        }
    }
}
