//! CLI dispatcher for the `shareprefill` binary.

use anyhow::{bail, Result};
use std::cell::RefCell;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::eval::{ablation, build_engine, infinitebench, latency,
                  open_registry, perplexity};
use crate::methods::{HeadPlan, NoState, PatternState, PatternStrategy,
                     Probes};
use crate::serving::{Engine, ServerBuilder};
use crate::substrate::cli::Args;
use crate::util::ascii::{heatmap, mask_map};
use crate::workloads::corpus::detokenize;
use crate::workloads::tasks::{self, Task, TASK_NAMES};

const USAGE: &str = "\
shareprefill — SharePrefill serving stack (paper reproduction)

USAGE: shareprefill <subcommand> [options]

SUBCOMMANDS
  serve     run the serving engine on a synthetic request stream
            (chunked prefill + continuous batching; per-request TTFT)
            [--model M] [--method ours|flash|flashprefill|minference|flexprefill]
            [--requests N] [--ctx L] [--decode-tokens N]
            [--chunk-layers N] [--max-concurrent-prefills N]
            [--workers N] [--shards N] [--admit-retries N] [--kv-blocks N]
            [--max-batch-tokens N] [--max-batch-requests N]
            [--queue-capacity N] [--pattern-cache]
            [--pattern-cache-capacity N] [--pattern-cache-validation T]
            [--pattern-cache-max-age N]
            [--prefix-cache] [--prefix-cache-capacity N]
            [--admission-enabled] [--admission-max-queue-depth N]
            [--admission-kv-overcommit F] [--admission-max-queue-rounds N]
            [--admission-interactive-max-tokens N]
            [--admission-degrade-queue-depth N]
            [--admission-degraded-budget-pct P]
            [--admission-degraded-max-prefills N]
  eval      Table 1: InfiniteBench-sim suite
            [--model M] [--methods a,b,..] [--samples N] [--ctx L]
  ablate    Table 2: ablations [--model M] [--samples N] [--ctx L]
  ppl       Figure 4: perplexity sweep [--model M] [--ctxs 256,512,..]
  latency   Figure 5: latency sweep [--model M] [--ctxs ...] [--repeats N]
  patterns  Figures 2 & 6: pattern maps [--similarity] [--distribution]
            [--model M] [--ctx L] [--task Retr.KV]
  cluster   offline head clustering -> artifacts/head_clusters-{model}.json
            [--model M] [--ctx L] [--threshold T] [--min-size N]
  inspect   artifact registry info
  golden    golden-vector integration check [--model M]

COMMON  --artifacts DIR   (default: artifacts)
        --config FILE     TOML config
        --tau/--delta/--gamma overrides";

pub fn run_cli() -> Result<()> {
    let args = Args::from_env(&["help", "verbose", "similarity",
                                "distribution", "pattern-cache",
                                "prefix-cache", "admission-enabled"])?;
    if args.flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    let cfg = Config::load(&args)?;
    match args.subcommand()? {
        "serve" => cmd_serve(&args, &cfg),
        "eval" => cmd_eval(&args, &cfg),
        "ablate" => cmd_ablate(&args, &cfg),
        "ppl" => cmd_ppl(&args, &cfg),
        "latency" => cmd_latency(&args, &cfg),
        "patterns" => cmd_patterns(&args, &cfg),
        "cluster" => cmd_cluster(&args, &cfg),
        "inspect" => cmd_inspect(&cfg),
        "golden" => cmd_golden(&args, &cfg),
        other => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
    }
}

fn parse_methods(args: &Args) -> Result<Vec<MethodKind>> {
    args.list_or("methods", &["flash", "minference", "flexprefill", "ours"])
        .iter()
        .map(|s| MethodKind::parse(s))
        .collect()
}

fn parse_tasks(args: &Args) -> Vec<Task> {
    match args.opt("tasks") {
        None => TASK_NAMES.iter().map(|(t, _)| *t).collect(),
        Some(list) => list.split(',')
            .filter_map(|n| Task::by_name(n.trim()))
            .collect(),
    }
}

fn cmd_serve(args: &Args, cfg: &Config) -> Result<()> {
    let model = args.str_or("model", "sim-llama");
    let n = args.usize_or("requests", 8)?;
    let ctx = args.usize_or("ctx", 1024)?;
    let mut handle = ServerBuilder::new()
        .config(cfg.clone())
        .model(&model)
        .spawn_fleet();
    println!("serving {n} requests @ ctx {ctx}, model {model}, method {} \
              ({} layer(s)/prefill chunk, {} concurrent prefill(s), \
              {} worker(s), {} shard(s), pattern cache {}, prefix \
              cache {})",
             cfg.method.kind.name(), cfg.serve.chunk_layers,
             cfg.serve.max_concurrent_prefills, cfg.serve.workers,
             handle.shard_count(),
             if cfg.serve.pattern_cache.enabled { "on" } else { "off" },
             if cfg.serve.prefix_cache.enabled { "on" } else { "off" });
    let sessions: Vec<_> = (0..n)
        .map(|_| handle.submit(tasks::latency_prompt(ctx),
                               cfg.serve.decode_tokens))
        .collect();
    for s in sessions {
        let id = s.id;
        match s.wait() {
            Ok(r) => println!(
                "req {:3}: ttft {:7.1} ms, prefill {:7.1} ms, decode \
                 {:6.1} ms, density {:.2}, gen {:?}",
                r.id, r.ttft_us as f64 / 1e3, r.prefill_us as f64 / 1e3,
                r.decode_us as f64 / 1e3, r.density,
                detokenize(&r.generated)),
            Err(e) => println!("req {id:3}: {e:#}"),
        }
    }
    println!("\n{}", handle.shutdown());
    Ok(())
}

fn cmd_eval(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let methods = parse_methods(args)?;
    let tasks_v = parse_tasks(args);
    let samples = args.usize_or("samples", 3)?;
    let ctx = args.usize_or("ctx", 1024)?;
    let t1 = infinitebench::run_table1(&registry, cfg, &model, &methods,
                                       &tasks_v, samples, ctx)?;
    println!("{}", t1.render());
    Ok(())
}

fn cmd_ablate(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let samples = args.usize_or("samples", 2)?;
    let ctx = args.usize_or("ctx", 1024)?;
    let spec = registry.model(&model)?.clone();
    let latency_ctx = args.usize_or("latency-ctx", spec.max_seq)?;
    let tasks_v = parse_tasks(args);
    let rows = ablation::run_ablation(&registry, cfg, &model, &tasks_v,
                                      samples, ctx, latency_ctx)?;
    println!("{}", ablation::render(&rows, ctx, latency_ctx));
    Ok(())
}

fn cmd_ppl(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let methods = parse_methods(args)?;
    let ctxs: Vec<usize> = args.list_or("ctxs", &["256", "512", "1024"])
        .iter().map(|s| s.parse().unwrap_or(512)).collect();
    let samples = args.usize_or("samples", 2)?;
    let curves = perplexity::run_ppl(&registry, cfg, &model, &methods,
                                     &ctxs, samples)?;
    println!("{}", curves.render());
    Ok(())
}

fn cmd_latency(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let methods = parse_methods(args)?;
    let ctxs: Vec<usize> = args
        .list_or("ctxs", &["512", "1024", "2048"])
        .iter().map(|s| s.parse().unwrap_or(512)).collect();
    let repeats = args.usize_or("repeats", 2)?;
    let curves = latency::run_latency(&registry, cfg, &model, &methods,
                                      &ctxs, repeats)?;
    println!("{}", curves.render());
    println!("speedups vs FlashAttn @ {} tok:",
             curves.ctx_lens.last().unwrap());
    for (m, s) in curves.speedups() {
        println!("  {:14} {s:.2}x", m.name());
    }
    Ok(())
}

/// Strategy that runs every head dense and collects the full abar maps —
/// the calibration path for `cluster` and `patterns`.  Collection is an
/// engine-wide side channel, deliberately *not* per-request pattern
/// state: calibration runs one prompt at a time through the serial
/// `Engine::prefill` path (`collect_head_maps` owns the buffer
/// lifecycle), and maps from concurrent prefills would interleave —
/// never drive a `DenseCollector` engine through the multi-prefill
/// scheduler.
pub struct DenseCollector {
    pub maps: Rc<RefCell<Vec<Vec<f32>>>>,
}

impl PatternStrategy for DenseCollector {
    fn kind(&self) -> MethodKind {
        MethodKind::Flash
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        Box::new(NoState)
    }

    fn plan_layer(&self, _state: &mut dyn PatternState, _l: usize,
                  _s: usize, h: usize, _p: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        Ok((0..h).map(|_| HeadPlan::dense(true)).collect())
    }

    fn publish_abar(&self, _state: &mut dyn PatternState, _layer: usize,
                    _head: usize, _nb: usize, abar: &[f32]) {
        self.maps.borrow_mut().push(abar.to_vec());
    }
}

/// Collect each head's dense block-average map on one prompt (serial
/// prefill; owns the collector's buffer lifecycle).
pub fn collect_head_maps(registry: &Rc<crate::runtime::Registry>,
                         model: &str, prompt: &[i32])
                         -> Result<(Vec<Vec<f32>>, usize)> {
    let maps = Rc::new(RefCell::new(Vec::new()));
    let strategy = Box::new(DenseCollector { maps: maps.clone() });
    let mut engine = Engine::new(registry.clone(), model, strategy)?;
    let pre = engine.prefill(prompt)?;
    let nb = pre.seq / crate::BLOCK_SIZE;
    let out = maps.borrow().clone();
    Ok((out, nb))
}

fn cmd_patterns(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let ctx = args.usize_or("ctx", 1024)?;
    let task = Task::by_name(&args.str_or("task", "Retr.KV"))
        .unwrap_or(Task::RetrKV);
    let spec = registry.model(&model)?.clone();
    let s = tasks::sample(task, 1, ctx);
    let gamma = cfg.method.gamma;

    if args.flag("distribution") {
        // Figure 6: pattern distribution under SharePrefill per task
        println!("### Figure 6 — pattern distribution, {model} @ ctx {ctx}\n");
        println!("| task | dense | shared | vslash |");
        println!("|---|---:|---:|---:|");
        for (t, name) in TASK_NAMES {
            let mut e = build_engine(&registry, cfg, &model,
                                     MethodKind::SharePrefill)?;
            let sm = tasks::sample(t, 3, ctx);
            let pre = e.prefill(&sm.prompt)?;
            println!("| {} | {} | {} | {} |", name, pre.stats.dense,
                     pre.stats.shared, pre.stats.vslash);
        }
        return Ok(());
    }

    let (maps, nb) = collect_head_maps(&registry, &model, &s.prompt)?;
    let patterns: Vec<_> = maps.iter()
        .map(|m| crate::clustering::pattern_of_map(m, nb, gamma))
        .collect();

    if args.flag("similarity") {
        // Figure 2b: head × head Jaccard matrix
        let m = crate::clustering::jaccard_matrix(&patterns);
        let n = patterns.len();
        println!("### Figure 2b — Jaccard similarity, {n} heads, task {}\n",
                 task.name());
        let f32m: Vec<f32> = m.iter().map(|&x| x as f32).collect();
        println!("{}", heatmap(&f32m, n, n));
        let off: Vec<f64> = (0..n)
            .flat_map(|i| (0..n).filter(move |&j| j != i)
                .map(move |j| (i, j)))
            .map(|(i, j)| m[i * n + j])
            .collect();
        let above = off.iter().filter(|&&x| x > 0.5).count();
        println!("off-diagonal pairs with similarity > 0.5: {:.2}",
                 above as f64 / off.len().max(1) as f64);
    } else {
        // Figure 2a: a few heads' patterns
        println!("### Figure 2a — block patterns (γ={gamma}), task {}, \
                  {} heads × {} layers\n",
                 task.name(), spec.num_heads, spec.num_layers);
        for (i, p) in patterns.iter().enumerate().take(6) {
            let (l, h) = (i / spec.num_heads, i % spec.num_heads);
            println!("(L{l}, H{h}) density {:.2}", p.density());
            println!("{}", mask_map(&p.to_grid(), nb));
        }
    }
    Ok(())
}

fn cmd_cluster(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let ctx = args.usize_or("ctx", 1024)?;
    let threshold = args.f64_or("threshold", 0.6)?;
    let min_size = args.usize_or("min-size", 5)?;
    let spec = registry.model(&model)?.clone();
    // calibration sample: Retr.KV, as in the paper (Section 5.2)
    let s = tasks::sample(Task::RetrKV, 7, ctx);
    let (maps, nb) = collect_head_maps(&registry, &model, &s.prompt)?;
    let hc = crate::clustering::cluster_heads(
        &model, spec.num_layers, spec.num_heads, &maps, nb, 16, 64,
        threshold, min_size);
    let path = cfg.paths.artifacts
        .join(format!("head_clusters-{model}.json"));
    crate::clustering::save_clusters(&hc, &path)?;
    println!("clustered {} heads -> {} clusters (noise: {}) @ {:?}",
             maps.len(), hc.num_clusters,
             hc.assignment.iter().filter(|a| a.is_none()).count(), path);
    for (i, sz) in hc.sizes().iter().enumerate() {
        println!("  cluster {i}: {sz} heads");
    }
    Ok(())
}

fn cmd_inspect(cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    println!("artifacts dir: {:?}", cfg.paths.artifacts);
    for (name, m) in &registry.models {
        println!("model {name}: {}L x {}H (kv {}), d{} hidden {}, vocab {}, \
                  buckets {:?}",
                 m.num_layers, m.num_heads, m.num_kv_heads, m.head_dim,
                 m.hidden, m.vocab, m.seq_buckets);
    }
    println!("{} artifacts", registry.artifacts.len());
    let mut by_stage: std::collections::BTreeMap<&str, usize> =
        Default::default();
    for a in registry.artifacts.values() {
        *by_stage.entry(a.stage.as_str()).or_default() += 1;
    }
    for (s, n) in by_stage {
        println!("  {s}: {n}");
    }
    Ok(())
}

fn cmd_golden(args: &Args, cfg: &Config) -> Result<()> {
    let registry = open_registry(cfg)?;
    let model = args.str_or("model", "sim-llama");
    let report = crate::eval::golden::run_golden(&registry, &model)?;
    println!("{report}");
    Ok(())
}
