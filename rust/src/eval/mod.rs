//! Evaluation harnesses regenerating the paper's tables and figures
//! (experiment index in DESIGN.md).

pub mod ablation;
pub mod infinitebench;
pub mod latency;
pub mod perplexity;

use anyhow::Result;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::methods::build_strategy;
use crate::runtime::{Registry, Runtime};
use crate::serving::Engine;

/// Shared setup: runtime + registry.
pub fn open_registry(cfg: &Config) -> Result<Rc<Registry>> {
    let rt = Rc::new(Runtime::cpu()?);
    Ok(Rc::new(Registry::load(cfg.paths.artifacts.clone(), rt)?))
}

/// Build an engine for (model, method), loading the cluster table when one
/// exists (SharePrefill falls back to per-index clusters otherwise).
pub fn build_engine(registry: &Rc<Registry>, cfg: &Config, model: &str,
                    kind: MethodKind) -> Result<Engine> {
    let spec = registry.model(model)?.clone();
    let mut mcfg = cfg.method.clone();
    mcfg.kind = kind;
    let clusters = if kind == MethodKind::SharePrefill {
        let path = match &mcfg.clusters_file {
            Some(p) => p.clone(),
            None => cfg.paths.artifacts
                .join(format!("head_clusters-{model}.json")),
        };
        match crate::clustering::load_clusters(&path) {
            Ok(hc) => Some(hc.assignment),
            Err(_) => None, // fall back to positional clusters
        }
    } else {
        None
    };
    let strategy = build_strategy(&mcfg, spec.num_layers, spec.num_heads,
                                  clusters);
    Engine::new(registry.clone(), model, strategy)
}
pub mod golden;
