//! Evaluation harnesses regenerating the paper's tables and figures
//! (experiment index in DESIGN.md).

pub mod ablation;
pub mod infinitebench;
pub mod latency;
pub mod perplexity;

use anyhow::Result;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::runtime::Registry;
use crate::serving::{Engine, EngineBuilder};

/// Shared setup: runtime + registry.  Implemented in [`crate::runtime`]
/// (so `serving` can use it without importing `eval` — the layering
/// rule pallas-lint enforces); re-exported here for the existing
/// eval/bench/example call sites.
pub use crate::runtime::open_registry;

/// Build an engine for (model, method) — a thin shim over
/// [`EngineBuilder`], which owns the cluster-table lookup (SharePrefill
/// falls back to per-index clusters when no table exists).
pub fn build_engine(registry: &Rc<Registry>, cfg: &Config, model: &str,
                    kind: MethodKind) -> Result<Engine> {
    EngineBuilder::new(registry.clone(), model)
        .method_config(cfg.method.clone())
        .method(kind)
        .workers(cfg.serve.workers)
        .build()
}
pub mod golden;
