//! Golden-vector integration check: replay the oracle vectors emitted by
//! aot.py through the *compiled artifacts* and compare — proves the whole
//! AOT chain (Pallas kernel → HLO text → PJRT compile → rust execute)
//! preserves numerics.

use anyhow::{bail, Result};
use std::rc::Rc;

use crate::runtime::{Registry, Tensor};
use crate::substrate::tenstore::TenStore;

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b)
        .map(|(x, y)| {
            // -1e30 encodes -inf in the golden file
            if *y <= -1e29 && !x.is_finite() { 0.0 } else { (x - y).abs() }
        })
        .fold(0f32, f32::max)
}

pub fn run_golden(registry: &Rc<Registry>, model: &str) -> Result<String> {
    let spec = registry.model(model)?.clone();
    let path = registry.dir.join(format!("golden-{model}.bin"));
    let g = TenStore::load(&path)?;
    let seq = g.get("seq")?.data[0] as usize;
    let nb = seq / crate::BLOCK_SIZE;
    let t = |n: &str| -> Result<Tensor> {
        let s = g.get(n)?;
        Ok(Tensor::f32(s.shape.clone(), s.data.clone()))
    };
    let ti = |n: &str, shape: Vec<usize>| -> Result<Tensor> {
        let s = g.get(n)?;
        Ok(Tensor::i32(shape, s.data.iter().map(|&x| x as i32).collect()))
    };
    let mut report = String::new();
    fn check(report: &mut String, name: &str, got: &[f32], want: &[f32],
             atol: f32) -> Result<()> {
        let e = max_err(got, want);
        report.push_str(&format!("{name}: max err {e:.2e}\n"));
        if e > atol {
            bail!("golden check '{name}' failed: {e} > {atol}");
        }
        Ok(())
    }

    // dense attention (budget = nb)
    let art = format!("{}_attn_s{}_b{}", spec.prefix, seq, nb);
    let out = registry.execute(&art, &[
        t("q")?, t("k")?, t("v")?,
        ti("dense_idx", vec![nb, nb])?, t("dense_valid")?,
    ])?;
    check(&mut report, "dense o", out[0].as_f32()?, g.get("dense_o")?.data.as_slice(),
          5e-4)?;
    check(&mut report, "dense abar", out[1].as_f32()?,
          g.get("dense_abar")?.data.as_slice(), 5e-4)?;

    // sparse attention at the golden budget
    let b = g.get("sparse_idx")?.shape[1];
    let art = format!("{}_attn_s{}_b{}", spec.prefix, seq, b);
    if registry.artifacts.contains_key(&art) {
        let out = registry.execute(&art, &[
            t("q")?, t("k")?, t("v")?,
            ti("sparse_idx", vec![nb, b])?, t("sparse_valid")?,
        ])?;
        check(&mut report, "sparse o", out[0].as_f32()?,
              g.get("sparse_o")?.data.as_slice(), 5e-4)?;
        check(&mut report, "sparse abar", out[1].as_f32()?,
              g.get("sparse_abar")?.data.as_slice(), 5e-4)?;
    } else {
        report.push_str(&format!("sparse: no artifact {art}, skipped\n"));
    }

    // pattern probe
    let art = format!("{}_patternprobe_s{}", spec.prefix, seq);
    let out = registry.execute(&art, &[t("probe_qh")?, t("probe_k")?])?;
    check(&mut report, "pattern probe", out[0].as_f32()?,
          g.get("probe_ahat")?.data.as_slice(), 5e-5)?;

    // flex probe
    let art = format!("{}_flexprobe_s{}", spec.prefix, seq);
    let out = registry.execute(&art, &[t("flex_q")?, t("probe_k")?])?;
    check(&mut report, "flex probe", out[0].as_f32()?,
          g.get("flex_map")?.data.as_slice(), 5e-5)?;

    report.push_str("golden OK\n");
    Ok(report)
}
