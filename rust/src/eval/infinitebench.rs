//! Table 1: InfiniteBench-sim scores for every method × task.
//!
//! Exact-match tasks score retrieval accuracy directly; open-ended tasks
//! score generation fidelity against the FlashAttention reference (the
//! accuracy-preservation quantity Table 1 tracks).  FlashAttn's own row
//! reports 100 on fidelity tasks by construction — it *is* the reference —
//! matching the paper's framing of dense attention as the upper bound.

use anyhow::Result;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::runtime::Registry;
use crate::util::ascii::markdown_table;
use crate::workloads::scoring::{exact_match, fidelity};
use crate::workloads::tasks::{task_samples, Task, TASK_NAMES};

use super::build_engine;

/// Scores per method per task (+ average), plus pattern stats.
#[derive(Debug, Clone)]
pub struct Table1 {
    pub model: String,
    pub ctx_len: usize,
    /// method → task name → score.
    pub scores: BTreeMap<MethodKind, BTreeMap<&'static str, f64>>,
    /// method → mean prefill density.
    pub density: BTreeMap<MethodKind, f64>,
    /// method → mean prefill latency (ms).
    pub prefill_ms: BTreeMap<MethodKind, f64>,
}

impl Table1 {
    pub fn average(&self, m: MethodKind) -> f64 {
        let s = &self.scores[&m];
        s.values().sum::<f64>() / s.len().max(1) as f64
    }

    pub fn render(&self) -> String {
        // only the tasks actually evaluated
        let names: Vec<&'static str> = TASK_NAMES.iter()
            .filter(|(_, n)| self.scores.values()
                .next().is_some_and(|s| s.contains_key(n)))
            .map(|(_, n)| *n)
            .collect();
        let mut rows = Vec::new();
        for (m, scores) in &self.scores {
            let mut row = vec![m.name().to_string()];
            for name in &names {
                row.push(format!("{:.1}", scores.get(name).unwrap_or(&0.0)));
            }
            row.push(format!("{:.1}", self.average(*m)));
            row.push(format!("{:.0}", self.prefill_ms[m]));
            row.push(format!("{:.2}", self.density[m]));
            rows.push(row);
        }
        let mut headers = vec!["Method"];
        headers.extend(names.iter());
        headers.extend(["Avg", "prefill ms", "density"]);
        format!("### Table 1 — {} @ ctx {}\n\n{}",
                self.model, self.ctx_len, markdown_table(&headers, &rows))
    }
}

/// Run the suite.  `samples_per_task` trades runtime for variance.
pub fn run_table1(registry: &Rc<Registry>, cfg: &Config, model: &str,
                  methods: &[MethodKind], tasks: &[Task],
                  samples_per_task: usize, ctx_len: usize)
                  -> Result<Table1> {
    // 1) dense reference generations (also FlashAttn's timing row)
    let mut reference: BTreeMap<(usize, usize), Vec<i32>> = BTreeMap::new();
    let mut out = Table1 {
        model: model.to_string(),
        ctx_len,
        scores: BTreeMap::new(),
        density: BTreeMap::new(),
        prefill_ms: BTreeMap::new(),
    };
    // ensure Flash runs first so references exist
    let mut ordered: Vec<MethodKind> = vec![MethodKind::Flash];
    ordered.extend(methods.iter().copied()
        .filter(|&m| m != MethodKind::Flash));

    for kind in ordered {
        let wanted = kind == MethodKind::Flash
            || methods.contains(&kind);
        let mut engine = build_engine(registry, cfg, model, kind)?;
        let mut scores: BTreeMap<&'static str, f64> = BTreeMap::new();
        let mut dens = 0f64;
        let mut lat_ms = 0f64;
        let mut n_runs = 0usize;
        for (ti, task) in tasks.iter().enumerate() {
            let samples = task_samples(*task, samples_per_task, ctx_len);
            let mut task_score = 0f64;
            for (si, s) in samples.iter().enumerate() {
                let pre = engine.prefill(&s.prompt)?;
                dens += pre.stats.density();
                lat_ms += pre.stats.latency_us as f64 / 1e3;
                n_runs += 1;
                let (generated, _) = engine.decode(&pre, s.gen_tokens)?;
                // Scoring: exact-match where the dense reference itself
                // retrieves correctly (the paper's absolute metric);
                // otherwise generation fidelity vs. the dense reference
                // (accuracy preservation) — so the comparison stays
                // informative even where the tiny model's absolute task
                // ability saturates (DESIGN.md "Substitutions").
                let score = if kind == MethodKind::Flash {
                    reference.insert((ti, si), generated.clone());
                    match &s.answer {
                        // if dense retrieves, it scores 100 by definition;
                        // if not, it is still the fidelity reference (100)
                        Some(_) | None => 100.0,
                    }
                } else {
                    let rf = reference.get(&(ti, si))
                        .map(Vec::as_slice).unwrap_or(&[]);
                    match &s.answer {
                        Some(ans) if exact_match(rf, ans) > 0.0 => {
                            exact_match(&generated, ans)
                        }
                        _ => fidelity(&generated, rf),
                    }
                };
                task_score += score;
            }
            scores.insert(task.name(),
                          task_score / samples.len().max(1) as f64);
        }
        if wanted {
            out.scores.insert(kind, scores);
            out.density.insert(kind, dens / n_runs.max(1) as f64);
            out.prefill_ms.insert(kind, lat_ms / n_runs.max(1) as f64);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t1() -> Table1 {
        let mut scores = BTreeMap::new();
        let mut flash = BTreeMap::new();
        flash.insert("En.Sum", 100.0);
        flash.insert("Retr.KV", 100.0);
        let mut ours = BTreeMap::new();
        ours.insert("En.Sum", 90.0);
        ours.insert("Retr.KV", 80.0);
        scores.insert(MethodKind::Flash, flash);
        scores.insert(MethodKind::SharePrefill, ours);
        let mut density = BTreeMap::new();
        density.insert(MethodKind::Flash, 1.0);
        density.insert(MethodKind::SharePrefill, 0.6);
        let mut ms = BTreeMap::new();
        ms.insert(MethodKind::Flash, 100.0);
        ms.insert(MethodKind::SharePrefill, 70.0);
        Table1 { model: "m".into(), ctx_len: 512, scores, density,
                 prefill_ms: ms }
    }

    #[test]
    fn average_over_evaluated_tasks() {
        let t = t1();
        assert!((t.average(MethodKind::SharePrefill) - 85.0).abs() < 1e-9);
        assert!((t.average(MethodKind::Flash) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn render_only_evaluated_columns() {
        let r = t1().render();
        assert!(r.contains("En.Sum") && r.contains("Retr.KV"));
        assert!(!r.contains("Math.Find"), "unevaluated task leaked:\n{r}");
        assert!(r.contains("SharePrefill"));
    }
}
