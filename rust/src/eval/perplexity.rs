//! Figure 4: PG19-sim perplexity vs. context length per method.

use anyhow::Result;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::runtime::Registry;
use crate::util::ascii::{line_chart, markdown_table};
use crate::workloads::scoring::perplexity;
use crate::workloads::tasks::pg19_sample;

use super::build_engine;

#[derive(Debug, Clone)]
pub struct PplCurves {
    pub model: String,
    pub ctx_lens: Vec<usize>,
    /// method → ppl per ctx length.
    pub curves: BTreeMap<MethodKind, Vec<f64>>,
}

impl PplCurves {
    pub fn render(&self) -> String {
        let mut rows = Vec::new();
        for (m, c) in &self.curves {
            let mut row = vec![m.name().to_string()];
            row.extend(c.iter().map(|p| format!("{p:.3}")));
            rows.push(row);
        }
        let mut headers = vec!["Method".to_string()];
        headers.extend(self.ctx_lens.iter().map(|l| l.to_string()));
        let href: Vec<&str> = headers.iter().map(String::as_str).collect();
        let series: Vec<(&str, Vec<f64>)> = self.curves.iter()
            .map(|(m, c)| (m.name(), c.clone()))
            .collect();
        format!("### Figure 4 — perplexity, {}\n\n{}\n```\n{}```\n",
                self.model, markdown_table(&href, &rows),
                line_chart(&series, 48, 10))
    }
}

pub fn run_ppl(registry: &Rc<Registry>, cfg: &Config, model: &str,
               methods: &[MethodKind], ctx_lens: &[usize],
               samples: usize) -> Result<PplCurves> {
    let spec = registry.model(model)?.clone();
    let mut curves = BTreeMap::new();
    for &kind in methods {
        let mut engine = build_engine(registry, cfg, model, kind)?;
        let mut curve = Vec::new();
        for &len in ctx_lens {
            let mut acc = 0f64;
            for s in 0..samples {
                let tokens = pg19_sample(s as u64, len);
                let pre = engine.prefill(&tokens)?;
                let logits = engine.logits_full(&pre)?;
                acc += perplexity(logits.as_f32()?, spec.vocab, &tokens,
                                  pre.real_len);
            }
            curve.push(acc / samples.max(1) as f64);
        }
        curves.insert(kind, curve);
    }
    Ok(PplCurves {
        model: model.to_string(),
        ctx_lens: ctx_lens.to_vec(),
        curves,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_table_and_chart() {
        let mut curves = BTreeMap::new();
        curves.insert(MethodKind::Flash, vec![3.0, 3.5]);
        curves.insert(MethodKind::FlexPrefill, vec![4.0, 6.0]);
        let c = PplCurves { model: "m".into(), ctx_lens: vec![256, 512],
                            curves };
        let r = c.render();
        assert!(r.contains("FlexPrefill") && r.contains("256"));
        assert!(r.contains("ymax"));
    }
}
