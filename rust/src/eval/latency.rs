//! Figure 5 (latency vs. context length) and the Figure 1 tradeoff data.

use anyhow::Result;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::runtime::Registry;
use crate::util::ascii::markdown_table;
use crate::workloads::tasks::latency_prompt;

use super::build_engine;

#[derive(Debug, Clone)]
pub struct LatencyCurves {
    pub model: String,
    pub ctx_lens: Vec<usize>,
    /// method → (mean prefill ms per ctx, mean density per ctx).
    pub curves: BTreeMap<MethodKind, Vec<(f64, f64)>>,
}

impl LatencyCurves {
    pub fn render(&self) -> String {
        let mut rows = Vec::new();
        for (m, c) in &self.curves {
            let mut row = vec![m.name().to_string()];
            row.extend(c.iter().map(|(ms, d)| format!("{ms:.0} ({d:.2})")));
            rows.push(row);
        }
        let mut headers = vec!["Method".to_string()];
        headers.extend(self.ctx_lens.iter().map(|l| format!("{l} tok")));
        let href: Vec<&str> = headers.iter().map(String::as_str).collect();
        format!("### Figure 5 — prefill latency ms (density), {}\n\n{}",
                self.model, markdown_table(&href, &rows))
    }

    /// Speedup of each method vs. FlashAttn at the longest context.
    pub fn speedups(&self) -> BTreeMap<MethodKind, f64> {
        let flash = self.curves.get(&MethodKind::Flash)
            .and_then(|c| c.last())
            .map(|(ms, _)| *ms)
            .unwrap_or(0.0);
        self.curves.iter()
            .map(|(m, c)| (*m, flash / c.last().map(|(ms, _)| *ms)
                .unwrap_or(1.0)))
            .collect()
    }
}

/// Prefill-latency sweep with warmup (compile excluded from timing).
pub fn run_latency(registry: &Rc<Registry>, cfg: &Config, model: &str,
                   methods: &[MethodKind], ctx_lens: &[usize],
                   repeats: usize) -> Result<LatencyCurves> {
    let mut curves = BTreeMap::new();
    for &kind in methods {
        let mut engine = build_engine(registry, cfg, model, kind)?;
        let mut curve = Vec::new();
        for &len in ctx_lens {
            let prompt = latency_prompt(len);
            // warmup (compiles artifacts for this bucket)
            let _ = engine.prefill(&prompt)?;
            let mut ms = 0f64;
            let mut dens = 0f64;
            for _ in 0..repeats {
                let pre = engine.prefill(&prompt)?;
                ms += pre.stats.latency_us as f64 / 1e3;
                dens += pre.stats.density();
            }
            curve.push((ms / repeats.max(1) as f64,
                        dens / repeats.max(1) as f64));
        }
        curves.insert(kind, curve);
    }
    Ok(LatencyCurves {
        model: model.to_string(),
        ctx_lens: ctx_lens.to_vec(),
        curves,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_relative_to_flash() {
        let mut curves = BTreeMap::new();
        curves.insert(MethodKind::Flash, vec![(100.0, 1.0), (400.0, 1.0)]);
        curves.insert(MethodKind::SharePrefill,
                      vec![(90.0, 0.5), (200.0, 0.5)]);
        let lc = LatencyCurves { model: "m".into(),
                                 ctx_lens: vec![512, 1024], curves };
        let s = lc.speedups();
        assert!((s[&MethodKind::SharePrefill] - 2.0).abs() < 1e-9);
        assert!((s[&MethodKind::Flash] - 1.0).abs() < 1e-9);
        assert!(lc.render().contains("1024 tok"));
    }
}
