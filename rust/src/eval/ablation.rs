//! Table 2: ablation variants of SharePrefill.
//!
//! * "Ours w/o Sharing"  — τ = 0 (pure vertical-slash, no pivotal sharing)
//! * "Ours w/o Exclusion" — δ = 1.01 (highly sparse heads also share)
//! * "Ours"               — paper defaults τ=0.2, δ=0.3
//!
//! Reports the task-suite scores plus the prefill latency at the largest
//! bucket (the paper's "128K latency" column, scaled to this testbed).

use anyhow::Result;
use std::rc::Rc;

use crate::config::{Config, MethodKind};
use crate::runtime::Registry;
use crate::util::ascii::markdown_table;
use crate::workloads::tasks::{Task, TASK_NAMES};

use super::infinitebench::run_table1;
use super::latency::run_latency;

pub struct AblationRow {
    pub name: &'static str,
    pub tau: f64,
    pub delta: f64,
    pub scores: Vec<(String, f64)>,
    pub avg: f64,
    pub max_ctx_latency_ms: f64,
}

pub fn run_ablation(registry: &Rc<Registry>, cfg: &Config, model: &str,
                    tasks: &[Task], samples_per_task: usize,
                    ctx_len: usize, latency_ctx: usize)
                    -> Result<Vec<AblationRow>> {
    let variants: [(&'static str, f64, f64); 3] = [
        ("Ours w/o Sharing (tau=0)", 0.0, cfg.method.delta),
        ("Ours w/o Exclusion (delta=1.01)", cfg.method.tau, 1.01),
        ("Ours", cfg.method.tau, cfg.method.delta),
    ];
    let mut rows = Vec::new();
    for (name, tau, delta) in variants {
        let mut vcfg = cfg.clone();
        vcfg.method.tau = tau;
        vcfg.method.delta = delta;
        let t1 = run_table1(registry, &vcfg, model,
                            &[MethodKind::SharePrefill], tasks,
                            samples_per_task, ctx_len)?;
        let lat = run_latency(registry, &vcfg, model,
                              &[MethodKind::SharePrefill], &[latency_ctx],
                              1)?;
        let scores: Vec<(String, f64)> = t1.scores
            [&MethodKind::SharePrefill]
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        rows.push(AblationRow {
            name,
            tau,
            delta,
            avg: t1.average(MethodKind::SharePrefill),
            scores,
            max_ctx_latency_ms: lat.curves[&MethodKind::SharePrefill][0].0,
        });
    }
    Ok(rows)
}

pub fn render(rows: &[AblationRow], ctx_len: usize, latency_ctx: usize)
              -> String {
    let mut headers = vec!["Variant"];
    let task_names: Vec<&str> = TASK_NAMES.iter().map(|(_, n)| *n).collect();
    headers.extend(task_names.iter());
    headers.extend(["Avg", "latency ms"]);
    let table_rows: Vec<Vec<String>> = rows.iter().map(|r| {
        let mut row = vec![r.name.to_string()];
        for n in &task_names {
            let v = r.scores.iter().find(|(k, _)| k == n)
                .map(|(_, v)| *v).unwrap_or(0.0);
            row.push(format!("{v:.1}"));
        }
        row.push(format!("{:.1}", r.avg));
        row.push(format!("{:.0}", r.max_ctx_latency_ms));
        row
    }).collect();
    format!("### Table 2 — ablations @ ctx {} (latency @ {})\n\n{}",
            ctx_len, latency_ctx, markdown_table(&headers, &table_rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_variants_and_latency() {
        let rows = vec![AblationRow {
            name: "Ours",
            tau: 0.2,
            delta: 0.3,
            scores: vec![("En.Sum".into(), 88.0)],
            avg: 88.0,
            max_ctx_latency_ms: 123.0,
        }];
        let r = render(&rows, 1024, 4096);
        assert!(r.contains("Ours") && r.contains("123") && r.contains("88.0"));
    }
}
