//! # SharePrefill
//!
//! Reproduction of *"Accelerating Prefilling for Long-Context LLMs via
//! Sparse Pattern Sharing"* (Peng et al., 2025) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request router, dynamic
//!   batcher, paged KV cache, prefill/decode scheduler, and the paper's
//!   contribution: the [`methods`] pattern engine (offline head clustering +
//!   online pivotal-pattern construction and sharing), plus the
//!   FlashAttention / MInference / FlexPrefill baselines.
//! * **L2** — a JAX transformer decomposed into weight-as-input HLO
//!   artifacts (built once by `make artifacts`, loaded by [`runtime`]).
//! * **L1** — Pallas block-sparse flash-attention kernels inside those
//!   artifacts, budget-bucketed so executed FLOPs track the sparsity the
//!   coordinator achieves.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `shareprefill` binary is self-contained (HLO text → PJRT CPU client).
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every table/figure of the paper to a module + bench target.

// The clippy style baseline for the hand-written tree lives in the
// root Cargo.toml `[lints.clippy]` table (so it also covers the
// integration tests, benches and examples, which compile as separate
// crates); CI runs `clippy --all-targets -- -D warnings` as a
// blocking gate on top of it.

pub mod attention;
pub mod bench;
pub mod clustering;
pub mod config;
pub mod eval;
pub mod exec;
pub mod linalg;
pub mod lint;
pub mod methods;
pub mod model;
pub mod runtime;
pub mod serving;
pub mod substrate;
pub mod util;
pub mod workloads;

/// Block size of the block-sparse attention grid — must match
/// `python/compile/configs.py::BLOCK_SIZE` (checked against the manifest at
/// load time).
pub const BLOCK_SIZE: usize = 64;

/// CLI dispatcher (implemented in `cli_main`; kept out of `main.rs` so the
/// binary stays a thin shim and the dispatcher is unit-testable).
pub mod cli_main;
pub use cli_main::run_cli;
