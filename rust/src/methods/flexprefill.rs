//! FlexPrefill baseline: query-aware block patterns estimated from
//! *pooled* Q/K representations, with a vertical-slash fallback for
//! "structured" heads — the estimator whose token-alignment and smoothing
//! failure modes Section 3 of the paper analyzes.
//!
//! Per head: the pooled block map (flex probe) yields (a) a query-aware
//! candidate mask via per-row cumulative-γ selection and (b) an estimated
//! last-row distribution.  The head's *true* last-row distribution (from
//! the vslash probe, block-pooled) is compared to the estimate with the JS
//! distance: if the pooled estimate tracks reality (`d < flex_tau`) the
//! query-aware pattern is used, otherwise the conservative vertical-slash
//! pattern.  Accuracy loss arises exactly when the pooled estimate is
//! *confidently wrong* — it passes the test yet mis-ranks blocks.

use anyhow::Result;
use std::rc::Rc;

use crate::attention::{search_vslash, BlockMask};
use crate::config::MethodKind;
use crate::exec::WorkerPool;
use crate::util::math::{cumulative_select, js_distance};
use crate::BLOCK_SIZE;

use super::{HeadPlan, NoState, PatternLabel, PatternState,
            PatternStrategy, Probes};

pub struct FlexPrefill {
    gamma: f32,
    flex_tau: f64,
    /// Engine-owned worker pool: each head's estimate/decision/search
    /// is independent, so the whole per-head loop fans out (serial by
    /// default; any width is bit-identical).
    pool: Rc<WorkerPool>,
}

impl FlexPrefill {
    pub fn new(gamma: f32, flex_tau: f64) -> FlexPrefill {
        FlexPrefill {
            gamma,
            flex_tau,
            pool: Rc::new(WorkerPool::serial()),
        }
    }

    /// Attach the engine-owned worker pool.
    pub fn with_pool(mut self, pool: Rc<WorkerPool>) -> FlexPrefill {
        self.pool = pool;
        self
    }

    /// Query-aware mask: per row-block, minimal cumulative-γ selection
    /// over the pooled row distribution.  (Associated fn, not a method:
    /// it runs inside the head-parallel fan-out, which must not borrow
    /// the strategy — the strategy holds the non-`Sync` pool handle.)
    fn query_aware_mask(gamma: f32, pooled: &[f32], nb: usize)
                        -> BlockMask {
        let mut mask = BlockMask::empty(nb);
        for i in 0..nb {
            let row = &pooled[i * nb..(i + 1) * nb];
            for j in cumulative_select(&row[..=i], gamma) {
                mask.insert(i, j);
            }
        }
        mask.ensure_diagonal();
        mask
    }
}

/// Block-pool a `[BS, S]` attention map's rows into a `[NB]` distribution.
pub fn pool_last_row(amap: &[f32], bs: usize, seq: usize) -> Vec<f32> {
    let nb = seq / BLOCK_SIZE;
    let mut out = vec![0f32; nb];
    for r in 0..bs {
        for j in 0..nb {
            let mut s = 0f32;
            for c in 0..BLOCK_SIZE {
                s += amap[r * seq + j * BLOCK_SIZE + c];
            }
            out[j] += s;
        }
    }
    let total: f32 = out.iter().sum();
    if total > 0.0 {
        out.iter_mut().for_each(|x| *x /= total);
    }
    out
}

impl PatternStrategy for FlexPrefill {
    fn kind(&self) -> MethodKind {
        MethodKind::FlexPrefill
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        // patterns are re-estimated per layer from the pooled probes;
        // nothing carries across layers or requests
        Box::new(NoState)
    }

    fn plan_layer(&self, _state: &mut dyn PatternState, _layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        let nb = seq / BLOCK_SIZE;
        let flex_t = probes.flex_map()?.clone();
        let amap_t = probes.vslash_map()?.clone();
        let flex = flex_t.as_f32()?;
        let amap = amap_t.as_f32()?;
        // each head's estimate check + mask construction is independent
        // of every other head's: the whole loop fans out with
        // head-indexed plan slots (scalars are copied out so the
        // closure never borrows the strategy itself)
        let gamma = self.gamma;
        let flex_tau = self.flex_tau;
        let plans = self.pool.fan_out(num_heads, |h| {
            let pooled = &flex[h * nb * nb..(h + 1) * nb * nb];
            let head_map =
                &amap[h * BLOCK_SIZE * seq..(h + 1) * BLOCK_SIZE * seq];
            // estimated vs. true last-row distributions
            let est_last = {
                let mut v = pooled[(nb - 1) * nb..].to_vec();
                let s: f32 = v.iter().sum();
                if s > 0.0 {
                    v.iter_mut().for_each(|x| *x /= s);
                }
                v
            };
            let true_last = pool_last_row(head_map, BLOCK_SIZE, seq);
            let d = js_distance(&est_last, &true_last);
            if d < flex_tau {
                HeadPlan::sparse(
                    FlexPrefill::query_aware_mask(gamma, pooled, nb),
                    PatternLabel::QueryAware)
            } else {
                HeadPlan::sparse(
                    search_vslash(head_map, BLOCK_SIZE, seq, gamma),
                    PatternLabel::VSlash)
            }
        });
        Ok(plans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::FakeProbes;

    #[test]
    fn pool_last_row_is_distribution() {
        let seq = 2 * BLOCK_SIZE;
        let bs = BLOCK_SIZE;
        let mut m = vec![0f32; bs * seq];
        for r in 0..bs {
            for c in 0..seq {
                m[r * seq + c] = 1.0 / seq as f32;
            }
        }
        let p = pool_last_row(&m, bs, seq);
        assert_eq!(p.len(), 2);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!((p[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn accurate_estimate_uses_query_aware() {
        let seq = 4 * BLOCK_SIZE;
        // structured probes where pooled estimate == truth
        let mut probes = FakeProbes::consistent(3, seq);
        let f = FlexPrefill::new(0.9, 0.5);
        let mut st = f.begin_request(seq);
        let plans = f.plan_layer(st.as_mut(), 0, seq, 3, &mut probes)
            .unwrap();
        assert!(plans.iter().any(|p| p.label == PatternLabel::QueryAware));
    }

    #[test]
    fn inaccurate_estimate_falls_back_to_vslash() {
        let seq = 4 * BLOCK_SIZE;
        // probes where pooled map disagrees with the true map
        let mut probes = FakeProbes::inconsistent(2, seq);
        let f = FlexPrefill::new(0.9, 0.05);
        let mut st = f.begin_request(seq);
        let plans = f.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
    }

    #[test]
    fn worker_pool_matches_serial_bitwise() {
        let seq = 4 * BLOCK_SIZE;
        let run = |workers: usize,
                   probes_of: fn(usize, usize) -> FakeProbes| {
            let mut probes = probes_of(3, seq);
            let f = FlexPrefill::new(0.9, 0.1)
                .with_pool(Rc::new(WorkerPool::new(workers)));
            let mut st = f.begin_request(seq);
            f.plan_layer(st.as_mut(), 0, seq, 3, &mut probes)
                .unwrap()
                .into_iter()
                .map(|p| (p.label, p.mask))
                .collect::<Vec<_>>()
        };
        for probes_of in [FakeProbes::consistent
                              as fn(usize, usize) -> FakeProbes,
                          FakeProbes::inconsistent] {
            assert_eq!(run(1, probes_of), run(4, probes_of),
                       "pool width changed a query-aware/vslash plan");
        }
    }

    #[test]
    fn masks_are_causal_with_diagonal() {
        let seq = 4 * BLOCK_SIZE;
        let mut probes = FakeProbes::consistent(2, seq);
        let f = FlexPrefill::new(0.9, 0.9);
        let mut st = f.begin_request(seq);
        for p in f.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap() {
            let m = p.mask.unwrap();
            for i in 0..m.nb {
                assert!(m.contains(i, i));
            }
        }
    }
}
