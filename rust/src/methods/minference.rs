//! MInference baseline (default vertical-slash configuration, as used in
//! the paper's comparison): every head gets a *dynamically indexed* but
//! *statically typed* vertical-slash pattern — the slash/vertical indices
//! are re-searched per input from the last-block attention probe, while
//! the pattern family never adapts (the limitation Section 3 discusses).

use anyhow::Result;
use std::rc::Rc;

use crate::attention::search_vslash_heads;
use crate::config::MethodKind;
use crate::exec::WorkerPool;
use crate::BLOCK_SIZE;

use super::{HeadPlan, NoState, PatternLabel, PatternState,
            PatternStrategy, Probes};

pub struct MInference {
    gamma: f32,
    /// Optional per-(layer, head) γ overrides from offline calibration
    /// (`shareprefill calibrate-minference`), mirroring MInference's
    /// offline per-head config search.
    pub per_head_gamma: Option<Vec<f32>>,
    /// Engine-owned worker pool for the per-head vslash searches
    /// (serial by default; any width is bit-identical).
    pool: Rc<WorkerPool>,
}

impl MInference {
    pub fn new(gamma: f32) -> MInference {
        MInference {
            gamma,
            per_head_gamma: None,
            pool: Rc::new(WorkerPool::serial()),
        }
    }

    /// Attach the engine-owned worker pool.
    pub fn with_pool(mut self, pool: Rc<WorkerPool>) -> MInference {
        self.pool = pool;
        self
    }

    fn head_gamma(&self, layer: usize, head: usize, num_heads: usize)
                  -> f32 {
        match &self.per_head_gamma {
            Some(v) => {
                let idx = layer * num_heads + head;
                v.get(idx).copied().unwrap_or(self.gamma)
            }
            None => self.gamma,
        }
    }
}

impl PatternStrategy for MInference {
    fn kind(&self) -> MethodKind {
        MethodKind::MInference
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        // indices are re-searched per layer from the probes; nothing
        // carries across layers, so requests share the no-op state
        Box::new(NoState)
    }

    fn plan_layer(&self, _state: &mut dyn PatternState, layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        let amap_t = probes.vslash_map()?.clone();
        let amap = amap_t.as_f32()?;
        // every head searches; fan out with head-indexed slots
        let jobs: Vec<(usize, f32)> = (0..num_heads)
            .map(|h| (h, self.head_gamma(layer, h, num_heads)))
            .collect();
        let masks = search_vslash_heads(&self.pool, amap, &jobs,
                                        BLOCK_SIZE, seq);
        Ok(masks.into_iter()
            .map(|m| HeadPlan::sparse(m, PatternLabel::VSlash))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::FakeProbes;

    #[test]
    fn every_head_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let mut probes = FakeProbes::structured(2, seq);
        let m = MInference::new(0.9);
        let mut st = m.begin_request(seq);
        let plans = m.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans.len(), 2);
        for p in &plans {
            assert_eq!(p.label, PatternLabel::VSlash);
            let mask = p.mask.as_ref().unwrap();
            assert!(mask.count() > 0);
            assert!(mask.density() <= 1.0);
        }
    }

    #[test]
    fn worker_pool_matches_serial_bitwise() {
        let seq = 4 * BLOCK_SIZE;
        let run = |workers: usize| {
            let mut probes = FakeProbes::structured(3, seq);
            let mut m = MInference::new(0.9)
                .with_pool(Rc::new(WorkerPool::new(workers)));
            m.per_head_gamma = Some(vec![0.5, 0.9, 0.99]);
            let mut st = m.begin_request(seq);
            m.plan_layer(st.as_mut(), 0, seq, 3, &mut probes)
                .unwrap()
                .into_iter()
                .map(|p| p.mask.unwrap())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "pool width changed a vslash mask");
    }

    #[test]
    fn per_head_gamma_applied() {
        let seq = 4 * BLOCK_SIZE;
        let mut probes = FakeProbes::structured(2, seq);
        let mut m = MInference::new(0.9);
        m.per_head_gamma = Some(vec![0.5, 0.99]);
        let mut st = m.begin_request(seq);
        let plans = m.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        let c0 = plans[0].mask.as_ref().unwrap().count();
        let c1 = plans[1].mask.as_ref().unwrap().count();
        assert!(c0 <= c1, "lower γ must not select more blocks ({c0} vs {c1})");
    }
}
