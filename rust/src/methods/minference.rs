//! MInference baseline (default vertical-slash configuration, as used in
//! the paper's comparison): every head gets a *dynamically indexed* but
//! *statically typed* vertical-slash pattern — the slash/vertical indices
//! are re-searched per input from the last-block attention probe, while
//! the pattern family never adapts (the limitation Section 3 discusses).

use anyhow::Result;

use crate::attention::search_vslash;
use crate::config::MethodKind;
use crate::BLOCK_SIZE;

use super::{HeadPlan, NoState, PatternLabel, PatternState,
            PatternStrategy, Probes};

pub struct MInference {
    gamma: f32,
    /// Optional per-(layer, head) γ overrides from offline calibration
    /// (`shareprefill calibrate-minference`), mirroring MInference's
    /// offline per-head config search.
    pub per_head_gamma: Option<Vec<f32>>,
}

impl MInference {
    pub fn new(gamma: f32) -> MInference {
        MInference { gamma, per_head_gamma: None }
    }

    fn head_gamma(&self, layer: usize, head: usize, num_heads: usize)
                  -> f32 {
        match &self.per_head_gamma {
            Some(v) => {
                let idx = layer * num_heads + head;
                v.get(idx).copied().unwrap_or(self.gamma)
            }
            None => self.gamma,
        }
    }
}

impl PatternStrategy for MInference {
    fn kind(&self) -> MethodKind {
        MethodKind::MInference
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        // indices are re-searched per layer from the probes; nothing
        // carries across layers, so requests share the no-op state
        Box::new(NoState)
    }

    fn plan_layer(&self, _state: &mut dyn PatternState, layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        let amap = probes.vslash_map()?;
        let bs = BLOCK_SIZE;
        let mut plans = Vec::with_capacity(num_heads);
        for h in 0..num_heads {
            let head_map = amap.index_axis0(h)?;
            let mask = search_vslash(head_map.as_f32()?, bs, seq,
                                     self.head_gamma(layer, h, num_heads));
            plans.push(HeadPlan::sparse(mask, PatternLabel::VSlash));
        }
        Ok(plans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::FakeProbes;

    #[test]
    fn every_head_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let mut probes = FakeProbes::structured(2, seq);
        let m = MInference::new(0.9);
        let mut st = m.begin_request(seq);
        let plans = m.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans.len(), 2);
        for p in &plans {
            assert_eq!(p.label, PatternLabel::VSlash);
            let mask = p.mask.as_ref().unwrap();
            assert!(mask.count() > 0);
            assert!(mask.density() <= 1.0);
        }
    }

    #[test]
    fn per_head_gamma_applied() {
        let seq = 4 * BLOCK_SIZE;
        let mut probes = FakeProbes::structured(2, seq);
        let mut m = MInference::new(0.9);
        m.per_head_gamma = Some(vec![0.5, 0.99]);
        let mut st = m.begin_request(seq);
        let plans = m.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        let c0 = plans[0].mask.as_ref().unwrap().count();
        let c1 = plans[1].mask.as_ref().unwrap().count();
        assert!(c0 <= c1, "lower γ must not select more blocks ({c0} vs {c1})");
    }
}
