//! Pattern strategies: the paper's SharePrefill plus the baselines it is
//! compared against (FlashAttention-2 dense, MInference vertical-slash,
//! FlexPrefill pooled query-aware patterns, and the FlashPrefill-style
//! thresholded discovery in [`flash_threshold`]).
//!
//! A strategy consumes per-layer *probe* statistics (computed lazily by
//! the engine through [`Probes`]) and emits one [`HeadPlan`] per query
//! head; the serving engine packs each plan into the budgeted L1 kernel
//! call.  SharePrefill additionally receives the full block-averaged QK
//! map of heads that ran dense (via [`PatternStrategy::publish_abar`]) to
//! construct pivotal patterns (Alg. 2).
//!
//! Strategies are *stateless planners*: everything a request mutates
//! (SharePrefill's evolving pivotal dictionary) lives in a
//! [`PatternState`] value minted per request, carried by the prefill
//! task, so concurrent prefills never share or clobber pattern state.

pub mod flash;
pub mod flash_threshold;
pub mod flexprefill;
pub mod minference;
pub mod pattern_cache;
pub mod shareprefill;

use anyhow::Result;
use std::any::Any;
use std::cell::RefCell;
use std::rc::Rc;

use crate::attention::BlockMask;
use crate::config::{MethodConfig, MethodKind};
use crate::exec::WorkerPool;
use crate::runtime::Tensor;

pub use flash::Flash;
pub use flash_threshold::FlashThreshold;
pub use flexprefill::FlexPrefill;
pub use minference::MInference;
pub use pattern_cache::{PatternCache, PatternCacheStats};
pub use shareprefill::{SharePrefill, SharePrefillState};

/// Label of the pattern a head ended up with (drives Figure 6 and the
/// pattern-distribution metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PatternLabel {
    /// Full attention (dense baseline or pivotal bootstrap head).
    Dense,
    /// Shared pivotal pattern (SharePrefill).
    Shared,
    /// Vertical-slash pattern.
    VSlash,
    /// FlexPrefill's pooled query-aware block pattern.
    QueryAware,
}

impl PatternLabel {
    pub fn name(&self) -> &'static str {
        match self {
            PatternLabel::Dense => "dense",
            PatternLabel::Shared => "shared",
            PatternLabel::VSlash => "vslash",
            PatternLabel::QueryAware => "query-aware",
        }
    }
}

/// How the cross-request pattern cache participated in a head's plan
/// (drives the cache hit/miss/invalidation metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheDecision {
    /// Cache disabled, or not applicable to this head (only heads that
    /// would otherwise bootstrap dense consult it).
    Off,
    /// Cache enabled but held no pattern for this head's cluster at
    /// this length bucket — the exact (dense bootstrap) path ran.
    Miss,
    /// A cached pattern passed probe validation and was reused: the
    /// head skipped the full-attention pivotal computation.
    Hit,
    /// A cached pattern existed but failed probe validation — the
    /// exact path ran and its fresh pattern will refresh the cache.
    Rejected,
}

/// Per-head plan for one layer.
#[derive(Debug, Clone)]
pub struct HeadPlan {
    /// `None` → dense full-causal pattern at the max budget.
    pub mask: Option<BlockMask>,
    pub label: PatternLabel,
    /// SharePrefill: this head's full abar map must be scattered and handed
    /// back via `publish_abar` after the attention call.
    pub publish: bool,
    /// Cross-request cache involvement (Off everywhere the cache is
    /// disabled, so cache-off plans are indistinguishable from a
    /// cache-less build).
    pub cache: CacheDecision,
}

impl HeadPlan {
    pub fn dense(publish: bool) -> HeadPlan {
        HeadPlan {
            mask: None,
            label: PatternLabel::Dense,
            publish,
            cache: CacheDecision::Off,
        }
    }

    pub fn sparse(mask: BlockMask, label: PatternLabel) -> HeadPlan {
        HeadPlan {
            mask: Some(mask),
            label,
            publish: false,
            cache: CacheDecision::Off,
        }
    }
}

/// Lazy probe access: strategies only pay for the statistics they use
/// (e.g. Flash requests nothing; SharePrefill requests the vslash probe
/// only on layers where some head actually falls back).
pub trait Probes {
    /// Block-pooled last-row-block attention â: `[H, NB]`.
    fn ahat(&mut self) -> Result<&Tensor>;
    /// Softmaxed last-block attention map Â: `[H, BS, S]`.
    fn vslash_map(&mut self) -> Result<&Tensor>;
    /// FlexPrefill pooled block map: `[H, NB, NB]`.
    fn flex_map(&mut self) -> Result<&Tensor>;
}

/// Per-request mutable pattern state.  Minted by
/// [`PatternStrategy::begin_request`], owned by the request's
/// `PrefillTask`, and dropped with it — so any number of prefills can
/// be in flight on one engine, and the state of a half-done prefill is
/// a *value* a future multi-engine router can hand around.
///
/// Strategies downcast to their concrete type with [`state_mut`] /
/// [`state_ref`]; stateless strategies share [`NoState`].
pub trait PatternState: Any {
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// The shared no-op state for strategies with no per-request memory.
pub struct NoState;

impl PatternState for NoState {
    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Downcast a request's pattern state to a strategy's concrete type.
/// Panics on mismatch — a task can only ever be driven by the strategy
/// that began it, so a mismatch is a caller bug, not a runtime input.
pub fn state_mut<T: PatternState>(state: &mut dyn PatternState) -> &mut T {
    state.as_any_mut().downcast_mut::<T>()
        .expect("pattern state downcast: task begun by a different strategy")
}

/// Shared-reference counterpart of [`state_mut`].
pub fn state_ref<T: PatternState>(state: &dyn PatternState) -> &T {
    state.as_any().downcast_ref::<T>()
        .expect("pattern state downcast: task begun by a different strategy")
}

/// A pattern strategy (one per method): a *stateless planner*.  All
/// per-request mutable state (SharePrefill's evolving pivotal
/// dictionary) lives in the [`PatternState`] value minted by
/// [`PatternStrategy::begin_request`] and carried by the request's
/// prefill task, so chunks of any number of concurrent prefills may
/// interleave on one engine without crosstalk.
pub trait PatternStrategy {
    fn kind(&self) -> MethodKind;

    /// Mint fresh per-request state (pattern dictionaries are
    /// input-dependent; one state per prefill, dropped with its task).
    fn begin_request(&self, seq: usize) -> Box<dyn PatternState>;

    /// Decide a plan per query head for this layer of the request that
    /// owns `state`.
    fn plan_layer(&self, state: &mut dyn PatternState, layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>>;

    /// Receive the full `[NB, NB]` block-averaged QK map of a head whose
    /// plan had `publish = true` (ran dense), into the owning request's
    /// state. Default: ignore.
    fn publish_abar(&self, _state: &mut dyn PatternState, _layer: usize,
                    _head: usize, _nb: usize, _abar: &[f32]) {
    }

    /// The request's prefill completed: distill whatever of its pattern
    /// state should outlive it.  SharePrefill publishes the request's
    /// pivotal dictionary into the cross-request [`PatternCache`]; the
    /// engine calls this exactly once per task, at completion, so
    /// interleaved prefills never observe half-built patterns.
    /// Default: no-op.
    fn end_request(&self, _state: &dyn PatternState, _seq: usize) {
    }
}

/// Instantiate the strategy for a method config.  `cache` is the
/// engine-owned cross-request pattern cache; only SharePrefill consumes
/// it (and only when the cache is enabled).  `pool` is the engine-owned
/// worker pool every per-head planning fan-out runs on (pass a serial
/// pool — `WorkerPool::serial()` — for the single-threaded path; any
/// width plans bit-identically).
pub fn build_strategy(cfg: &MethodConfig, num_layers: usize,
                      num_heads: usize,
                      clusters: Option<Vec<Option<usize>>>,
                      cache: Option<Rc<RefCell<PatternCache>>>,
                      pool: Rc<WorkerPool>)
                      -> Box<dyn PatternStrategy> {
    match cfg.kind {
        MethodKind::Flash => Box::new(Flash::new()),
        MethodKind::FlashPrefill => {
            Box::new(FlashThreshold::new(cfg.gamma).with_pool(pool))
        }
        MethodKind::MInference => {
            Box::new(MInference::new(cfg.gamma).with_pool(pool))
        }
        MethodKind::FlexPrefill => {
            Box::new(FlexPrefill::new(cfg.gamma, cfg.flex_tau)
                .with_pool(pool))
        }
        MethodKind::SharePrefill => Box::new(
            SharePrefill::new(cfg.tau, cfg.delta, cfg.gamma, num_layers,
                              num_heads, clusters)
                .with_cache(cache)
                .with_pool(pool)),
    }
}

#[cfg(test)]
pub mod tests_support {
    //! Probe fakes for strategy unit tests.
    use super::Probes;
    use crate::runtime::Tensor;
    use crate::util::rng::Rng;
    use crate::BLOCK_SIZE;
    use anyhow::{bail, Result};

    /// Panics if any probe is touched (Flash must not probe).
    pub struct NoProbes;

    impl Probes for NoProbes {
        fn ahat(&mut self) -> Result<&Tensor> {
            bail!("ahat probe must not be used")
        }
        fn vslash_map(&mut self) -> Result<&Tensor> {
            bail!("vslash probe must not be used")
        }
        fn flex_map(&mut self) -> Result<&Tensor> {
            bail!("flex probe must not be used")
        }
    }

    /// Precomputed probe tensors.
    pub struct FakeProbes {
        ahat: Tensor,
        vslash: Tensor,
        flex: Tensor,
    }

    impl FakeProbes {
        fn build(h: usize, seq: usize,
                 mut rowval: impl FnMut(usize, usize, usize) -> f32)
                 -> FakeProbes {
            let nb = seq / BLOCK_SIZE;
            let bs = BLOCK_SIZE;
            // vslash map rows: normalized per row
            let mut vm = vec![0f32; h * bs * seq];
            for hh in 0..h {
                for r in 0..bs {
                    let qpos = seq - bs + r;
                    let mut sum = 0f32;
                    for k in 0..=qpos {
                        let v = rowval(hh, r, k).max(0.0) + 1e-6;
                        vm[hh * bs * seq + r * seq + k] = v;
                        sum += v;
                    }
                    for k in 0..=qpos {
                        vm[hh * bs * seq + r * seq + k] /= sum;
                    }
                }
            }
            // ahat = block-pooled last rows of vslash map
            let mut ah = vec![0f32; h * nb];
            for hh in 0..h {
                for j in 0..nb {
                    let mut s = 0f32;
                    for r in 0..bs {
                        for c in 0..bs {
                            s += vm[hh * bs * seq + r * seq + j * bs + c];
                        }
                    }
                    ah[hh * nb + j] = s;
                }
                let tot: f32 = ah[hh * nb..(hh + 1) * nb].iter().sum();
                for j in 0..nb {
                    ah[hh * nb + j] /= tot;
                }
            }
            // flex map rows mirror ahat for every row (consistent default)
            let mut fm = vec![0f32; h * nb * nb];
            for hh in 0..h {
                for i in 0..nb {
                    let mut sum = 0f32;
                    for j in 0..=i {
                        let v = ah[hh * nb + j] + 1e-6;
                        fm[hh * nb * nb + i * nb + j] = v;
                        sum += v;
                    }
                    for j in 0..=i {
                        fm[hh * nb * nb + i * nb + j] /= sum;
                    }
                }
            }
            FakeProbes {
                ahat: Tensor::f32(vec![h, nb], ah),
                vslash: Tensor::f32(vec![h, bs, seq], vm),
                flex: Tensor::f32(vec![h, nb, nb], fm),
            }
        }

        /// Uniform-ish probes (not sparse, all heads similar).
        pub fn flat(h: usize, seq: usize) -> FakeProbes {
            Self::build(h, seq, |_, _, _| 1.0)
        }

        /// Random structured probes (vertical stripes per head).
        pub fn structured(h: usize, seq: usize) -> FakeProbes {
            let mut rng = Rng::new(42);
            let stripes: Vec<usize> =
                (0..h).map(|_| rng.below(seq)).collect();
            Self::build(h, seq, move |hh, _, k| {
                if k.abs_diff(stripes[hh]) < BLOCK_SIZE { 5.0 } else { 0.2 }
            })
        }

        /// Pooled estimate matches truth (FlexPrefill happy path).
        pub fn consistent(h: usize, seq: usize) -> FakeProbes {
            Self::flat(h, seq)
        }

        /// Pooled estimate contradicts the true map.
        pub fn inconsistent(h: usize, seq: usize) -> FakeProbes {
            let mut p = Self::build(h, seq, |_, _, k| {
                if k < BLOCK_SIZE { 10.0 } else { 0.01 }
            });
            // overwrite flex map with mass on the *diagonal* instead
            let nb = seq / BLOCK_SIZE;
            let mut fm = vec![0f32; h * nb * nb];
            for hh in 0..h {
                for i in 0..nb {
                    fm[hh * nb * nb + i * nb + i] = 1.0;
                }
            }
            p.flex = Tensor::f32(vec![h, nb, nb], fm);
            p
        }
    }

    impl Probes for FakeProbes {
        fn ahat(&mut self) -> Result<&Tensor> {
            Ok(&self.ahat)
        }
        fn vslash_map(&mut self) -> Result<&Tensor> {
            Ok(&self.vslash)
        }
        fn flex_map(&mut self) -> Result<&Tensor> {
            Ok(&self.flex)
        }
    }
}
