//! FlashAttention-2 baseline: exact dense causal attention for every head.
//! The budgeted kernel at budget = NB with full causal indices *is* a
//! blocked flash attention; no probes, no pattern search.

use anyhow::Result;

use crate::config::MethodKind;

use super::{HeadPlan, NoState, PatternState, PatternStrategy, Probes};

#[derive(Default)]
pub struct Flash;

impl Flash {
    pub fn new() -> Flash {
        Flash
    }
}

impl PatternStrategy for Flash {
    fn kind(&self) -> MethodKind {
        MethodKind::Flash
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        Box::new(NoState)
    }

    fn plan_layer(&self, _state: &mut dyn PatternState, _layer: usize,
                  _seq: usize, num_heads: usize, _probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        Ok((0..num_heads).map(|_| HeadPlan::dense(false)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::NoProbes;

    #[test]
    fn all_heads_dense_no_probes() {
        let f = Flash::new();
        let mut st = f.begin_request(1024);
        let plans = f.plan_layer(st.as_mut(), 0, 1024, 8, &mut NoProbes)
            .unwrap();
        assert_eq!(plans.len(), 8);
        assert!(plans.iter().all(|p| p.mask.is_none() && !p.publish));
    }
}
