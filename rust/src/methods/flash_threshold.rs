//! **FlashPrefill**-style thresholded discovery (arxiv 2603.06199):
//! every head gets a vertical-slash pattern whose vertical columns and
//! slash offsets are selected by thresholding the probe map directly —
//! no sort, no cumulative scan.
//!
//! Calibration: the existing γ knob maps to the per-score threshold
//! `θ(γ) = (1-γ)·mass/positions` (see `util::math::threshold_select`) —
//! every score rejected by θ carries less than an equal share of the
//! `(1-γ)` slack, so the kept set always covers ≥ γ of the probe mass,
//! the same budget contract `cumulative_select` meets by sorting.  In
//! exact arithmetic the thresholded selection is a superset of the
//! cumulative-γ prefix, which is what the mask-recall test against
//! SharePrefill below leans on.
//!
//! Like the other planners in `methods/`, this file is on the
//! panic-hygiene hot path enforced by `pallas-lint`.

use anyhow::Result;
use std::rc::Rc;

use crate::attention::search_vslash_threshold_heads;
use crate::config::MethodKind;
use crate::exec::WorkerPool;
use crate::BLOCK_SIZE;

use super::{HeadPlan, NoState, PatternLabel, PatternState,
            PatternStrategy, Probes};

pub struct FlashThreshold {
    gamma: f32,
    /// Engine-owned worker pool for the per-head thresholded searches
    /// (serial by default; any width is bit-identical).
    pool: Rc<WorkerPool>,
}

impl FlashThreshold {
    pub fn new(gamma: f32) -> FlashThreshold {
        FlashThreshold { gamma, pool: Rc::new(WorkerPool::serial()) }
    }

    /// Attach the engine-owned worker pool.
    pub fn with_pool(mut self, pool: Rc<WorkerPool>) -> FlashThreshold {
        self.pool = pool;
        self
    }
}

impl PatternStrategy for FlashThreshold {
    fn kind(&self) -> MethodKind {
        MethodKind::FlashPrefill
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        // patterns are re-thresholded per layer from the probe map;
        // nothing carries across layers or requests
        Box::new(NoState)
    }

    fn plan_layer(&self, _state: &mut dyn PatternState, _layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        let amap_t = probes.vslash_map()?.clone();
        let amap = amap_t.as_f32()?;
        // every head thresholds; fan out with head-indexed slots
        let jobs: Vec<(usize, f32)> =
            (0..num_heads).map(|h| (h, self.gamma)).collect();
        let masks = search_vslash_threshold_heads(&self.pool, amap, &jobs,
                                                  BLOCK_SIZE, seq);
        Ok(masks.into_iter()
            .map(|m| HeadPlan::sparse(m, PatternLabel::VSlash))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::BlockMask;
    use crate::methods::shareprefill::SharePrefill;
    use crate::methods::tests_support::FakeProbes;

    #[test]
    fn every_head_gets_causal_vslash_plan() {
        let seq = 4 * BLOCK_SIZE;
        let nb = seq / BLOCK_SIZE;
        let mut probes = FakeProbes::structured(2, seq);
        let f = FlashThreshold::new(0.9);
        assert_eq!(f.kind(), MethodKind::FlashPrefill);
        let mut st = f.begin_request(seq);
        let plans = f.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans.len(), 2);
        for p in &plans {
            assert_eq!(p.label, PatternLabel::VSlash);
            let mask = p.mask.as_ref().unwrap();
            assert!(mask.count() > 0);
            for i in 0..nb {
                assert!(mask.contains(i, i), "diag missing at {i}");
                for j in mask.row(i) {
                    assert!((j as usize) <= i, "causality violated");
                }
            }
        }
    }

    #[test]
    fn worker_pool_matches_serial_bitwise() {
        let seq = 4 * BLOCK_SIZE;
        let run = |workers: usize| {
            let mut probes = FakeProbes::structured(3, seq);
            let f = FlashThreshold::new(0.9)
                .with_pool(Rc::new(WorkerPool::new(workers)));
            let mut st = f.begin_request(seq);
            f.plan_layer(st.as_mut(), 0, seq, 3, &mut probes)
                .unwrap()
                .into_iter()
                .map(|p| p.mask.unwrap())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "pool width changed a threshold mask");
    }

    #[test]
    fn gamma_monotone_in_selection_size() {
        let seq = 4 * BLOCK_SIZE;
        let count_at = |gamma: f32| {
            let mut probes = FakeProbes::structured(2, seq);
            let f = FlashThreshold::new(gamma);
            let mut st = f.begin_request(seq);
            f.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
                .unwrap()
                .iter()
                .map(|p| p.mask.as_ref().unwrap().count())
                .sum::<usize>()
        };
        assert!(count_at(0.5) <= count_at(0.95),
                "higher γ (lower θ) must not shrink the selection");
    }

    /// Strategy-level mask-recall against SharePrefill: with sharing
    /// ablated (`tau <= 0`) SharePrefill plans every head through the
    /// exact cumulative-γ vslash search, so the thresholded strategy's
    /// masks — built from superset selections at the same γ — must
    /// recall (cover) essentially all of SharePrefill's mask blocks.
    #[test]
    fn mask_recall_against_shareprefill() {
        let seq = 4 * BLOCK_SIZE;
        let nb = seq / BLOCK_SIZE;
        let heads = 3;
        let gamma = 0.9f32;

        let sp = SharePrefill::new(0.0, 0.3, gamma, 1, heads, None);
        let mut sp_state = sp.begin_request(seq);
        let mut probes = FakeProbes::structured(heads, seq);
        let sp_plans = sp
            .plan_layer(sp_state.as_mut(), 0, seq, heads, &mut probes)
            .unwrap();

        let f = FlashThreshold::new(gamma);
        let mut f_state = f.begin_request(seq);
        let mut probes = FakeProbes::structured(heads, seq);
        let f_plans = f
            .plan_layer(f_state.as_mut(), 0, seq, heads, &mut probes)
            .unwrap();

        let mut covered = 0usize;
        let mut wanted = 0usize;
        for h in 0..heads {
            let sp_mask: &BlockMask = sp_plans[h].mask.as_ref().unwrap();
            let f_mask: &BlockMask = f_plans[h].mask.as_ref().unwrap();
            for i in 0..nb {
                for j in sp_mask.row(i) {
                    wanted += 1;
                    if f_mask.contains(i, j as usize) {
                        covered += 1;
                    }
                }
            }
        }
        assert!(wanted > 0);
        let recall = covered as f64 / wanted as f64;
        assert!(recall >= 0.9,
                "thresholded masks recall only {recall:.3} of \
                 SharePrefill's blocks ({covered}/{wanted})");
    }
}
