//! **SharePrefill** — the paper's contribution (Section 5, Algorithm 1).
//!
//! Offline: heads are clustered by attention-map similarity
//! (`clustering::offline`).  Online, per layer and head:
//!
//! 1. *Determine Sparse Pattern* (Alg. 3): probe â, JS sparsity test vs.
//!    uniform (δ), JS similarity test vs. the cluster's pivotal
//!    representative ã (τ).
//! 2. *Share Pivotal Pattern* (Alg. 4): reuse the cluster's mask if
//!    present; otherwise the first head of the cluster runs **dense**.
//! 3. After the dense head's sparse-attention call returns its full
//!    block-averaged QK map Ã, *Construct Pivotal Pattern* (Alg. 2)
//!    publishes (ã, M) into the evolving per-request dictionary.
//!
//! Ablations (Table 2): `tau <= 0` disables sharing entirely (no dense
//! bootstrap either — pure vertical-slash); `delta > 1` disables the
//! highly-sparse-head exclusion.

use anyhow::Result;

use crate::attention::{construct_pivotal, decide_pattern, search_vslash,
                       Decision, PivotalDict};
use crate::config::MethodKind;
use crate::BLOCK_SIZE;

use super::{HeadPlan, PatternLabel, PatternStrategy, Probes};

pub struct SharePrefill {
    tau: f64,
    delta: f64,
    gamma: f32,
    num_heads: usize,
    /// (layer * num_heads + head) → cluster id (None = noise).
    clusters: Vec<Option<usize>>,
    /// Evolving per-request pivotal dictionary (cluster → (ã, M)).
    dict: PivotalDict,
    /// Decision statistics for the current request (Figure 6).
    pub stats: DecisionStats,
}

/// Counts of pattern kinds chosen during a request.
#[derive(Debug, Default, Clone)]
pub struct DecisionStats {
    pub dense: usize,
    pub shared: usize,
    pub vslash: usize,
}

impl SharePrefill {
    pub fn new(tau: f64, delta: f64, gamma: f32, num_layers: usize,
               num_heads: usize, clusters: Option<Vec<Option<usize>>>)
               -> SharePrefill {
        let clusters = clusters.unwrap_or_else(|| {
            // Without an offline clustering file, fall back to one cluster
            // per (head index) across layers — heads at the same position
            // often align; the similarity gate (τ) still protects sharing.
            (0..num_layers * num_heads)
                .map(|i| Some(i % num_heads))
                .collect()
        });
        assert_eq!(clusters.len(), num_layers * num_heads,
                   "cluster table must cover every (layer, head)");
        SharePrefill {
            tau,
            delta,
            gamma,
            num_heads,
            clusters,
            dict: PivotalDict::new(),
            stats: DecisionStats::default(),
        }
    }

    fn cluster_of(&self, layer: usize, head: usize) -> Option<usize> {
        self.clusters[layer * self.num_heads + head]
    }
}

impl PatternStrategy for SharePrefill {
    fn kind(&self) -> MethodKind {
        MethodKind::SharePrefill
    }

    fn begin_request(&mut self, _seq: usize) {
        // Patterns are input-dependent: the dictionary evolves within one
        // prefill and resets across requests.
        self.dict.clear();
        self.stats = DecisionStats::default();
    }

    fn plan_layer(&mut self, layer: usize, seq: usize, num_heads: usize,
                  probes: &mut dyn Probes) -> Result<Vec<HeadPlan>> {
        debug_assert_eq!(num_heads, self.num_heads);
        let ahat_t = probes.ahat()?.clone();
        let nb = seq / BLOCK_SIZE;
        let mut plans = Vec::with_capacity(num_heads);
        // vslash probe is fetched lazily only if some head needs it
        for h in 0..num_heads {
            let ahat_h = ahat_t.index_axis0(h)?;
            let ahat = ahat_h.as_f32()?;
            let cluster = if self.tau <= 0.0 {
                // "w/o sharing" ablation: no cluster machinery at all.
                None
            } else {
                self.cluster_of(layer, h)
            };
            let info = decide_pattern(ahat, cluster, &self.dict, self.delta,
                                      self.tau);
            match info.decision {
                Decision::Dense => {
                    self.stats.dense += 1;
                    plans.push(HeadPlan::dense(true));
                }
                Decision::SharedPivot => {
                    self.stats.shared += 1;
                    let entry = &self.dict[&info.cluster.unwrap()];
                    plans.push(HeadPlan {
                        mask: Some(entry.mask.clone()),
                        label: PatternLabel::Shared,
                        publish: false,
                    });
                }
                Decision::VSlash => {
                    self.stats.vslash += 1;
                    let amap_t = probes.vslash_map()?.index_axis0(h)?;
                    let mask = search_vslash(amap_t.as_f32()?, BLOCK_SIZE,
                                             seq, self.gamma);
                    plans.push(HeadPlan::sparse(mask, PatternLabel::VSlash));
                }
            }
            debug_assert!(plans.last().unwrap().mask.as_ref()
                .map_or(true, |m| m.nb == nb));
        }
        Ok(plans)
    }

    fn publish_abar(&mut self, layer: usize, head: usize, nb: usize,
                    abar: &[f32]) {
        if let Some(c) = self.cluster_of(layer, head) {
            let entry = construct_pivotal(abar, nb, self.gamma,
                                          (layer, head));
            self.dict.insert(c, entry);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::FakeProbes;
    use crate::util::math::NEG_INF;

    fn uniform_abar(nb: usize) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = 0.0;
            }
        }
        m
    }

    #[test]
    fn first_head_dense_then_shared() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        // two heads, same cluster, flat probes (similar + not sparse)
        let clusters = vec![Some(0), Some(0)];
        let mut sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2, Some(clusters));
        sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(0, seq, 2, &mut probes).unwrap();
        assert!(plans[0].mask.is_none() && plans[0].publish,
                "first head must bootstrap dense");
        // publish the dense head's map, re-plan: second head shares
        sp.publish_abar(0, 0, nb, &uniform_abar(nb));
        let plans2 = sp.plan_layer(0, seq, 2, &mut probes).unwrap();
        assert_eq!(plans2[1].label, PatternLabel::Shared);
        assert!(sp.stats.shared >= 1);
    }

    #[test]
    fn noise_cluster_uses_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let mut sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2,
                                       Some(vec![None, None]));
        sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(0, seq, 2, &mut probes).unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
    }

    #[test]
    fn tau_zero_is_pure_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let mut sp = SharePrefill::new(0.0, 0.3, 0.9, 1, 2,
                                       Some(vec![Some(0), Some(0)]));
        sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(0, seq, 2, &mut probes).unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
        assert_eq!(sp.stats.dense, 0);
    }

    #[test]
    fn dict_resets_between_requests() {
        let seq = 4 * BLOCK_SIZE;
        let mut sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 1,
                                       Some(vec![Some(0)]));
        sp.begin_request(seq);
        sp.publish_abar(0, 0, 4, &uniform_abar(4));
        assert!(!sp.dict.is_empty());
        sp.begin_request(seq);
        assert!(sp.dict.is_empty());
    }

    #[test]
    fn default_cluster_fallback_covers_all_heads() {
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 3, 4, None);
        assert_eq!(sp.clusters.len(), 12);
        assert!(sp.clusters.iter().all(Option::is_some));
    }
}
