//! **SharePrefill** — the paper's contribution (Section 5, Algorithm 1).
//!
//! Offline: heads are clustered by attention-map similarity
//! (`clustering::offline`).  Online, per layer and head:
//!
//! 1. *Determine Sparse Pattern* (Alg. 3): probe â, JS sparsity test vs.
//!    uniform (δ), JS similarity test vs. the cluster's pivotal
//!    representative ã (τ).
//! 2. *Share Pivotal Pattern* (Alg. 4): reuse the cluster's mask if
//!    present; otherwise the first head of the cluster runs **dense**.
//! 3. After the dense head's sparse-attention call returns its full
//!    block-averaged QK map Ã, *Construct Pivotal Pattern* (Alg. 2)
//!    publishes (ã, M) into the evolving per-request dictionary.
//!
//! The strategy itself is a stateless planner (τ, δ, γ, the offline
//! cluster table); the evolving pivotal dictionary is *request* state,
//! held in [`SharePrefillState`] — one per in-flight prefill, so chunks
//! of concurrent prompts can interleave without sharing patterns across
//! requests (patterns are input-dependent, Section 4).
//!
//! Ablations (Table 2): `tau <= 0` disables sharing entirely (no dense
//! bootstrap either — pure vertical-slash); `delta > 1` disables the
//! highly-sparse-head exclusion.

use anyhow::Result;
use std::any::Any;

use crate::attention::{construct_pivotal, decide_pattern, search_vslash,
                       Decision, PivotalDict};
use crate::config::MethodKind;
use crate::BLOCK_SIZE;

use super::{state_mut, HeadPlan, PatternLabel, PatternState,
            PatternStrategy, Probes};

pub struct SharePrefill {
    tau: f64,
    delta: f64,
    gamma: f32,
    num_heads: usize,
    /// (layer * num_heads + head) → cluster id (None = noise).
    clusters: Vec<Option<usize>>,
}

/// Per-request pattern state: the evolving pivotal dictionary plus the
/// request's decision statistics (Figure 6).
pub struct SharePrefillState {
    /// Evolving pivotal dictionary (cluster → (ã, M)) for one request.
    dict: PivotalDict,
    pub stats: DecisionStats,
}

impl PatternState for SharePrefillState {
    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Counts of pattern kinds chosen during a request.
#[derive(Debug, Default, Clone)]
pub struct DecisionStats {
    pub dense: usize,
    pub shared: usize,
    pub vslash: usize,
}

impl SharePrefill {
    pub fn new(tau: f64, delta: f64, gamma: f32, num_layers: usize,
               num_heads: usize, clusters: Option<Vec<Option<usize>>>)
               -> SharePrefill {
        let clusters = clusters.unwrap_or_else(|| {
            // Without an offline clustering file, fall back to one cluster
            // per (head index) across layers — heads at the same position
            // often align; the similarity gate (τ) still protects sharing.
            (0..num_layers * num_heads)
                .map(|i| Some(i % num_heads))
                .collect()
        });
        assert_eq!(clusters.len(), num_layers * num_heads,
                   "cluster table must cover every (layer, head)");
        SharePrefill { tau, delta, gamma, num_heads, clusters }
    }

    fn cluster_of(&self, layer: usize, head: usize) -> Option<usize> {
        self.clusters[layer * self.num_heads + head]
    }
}

impl PatternStrategy for SharePrefill {
    fn kind(&self) -> MethodKind {
        MethodKind::SharePrefill
    }

    fn begin_request(&self, _seq: usize) -> Box<dyn PatternState> {
        // Patterns are input-dependent: each request evolves its own
        // dictionary from scratch, independent of concurrent prefills.
        Box::new(SharePrefillState {
            dict: PivotalDict::new(),
            stats: DecisionStats::default(),
        })
    }

    fn plan_layer(&self, state: &mut dyn PatternState, layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        debug_assert_eq!(num_heads, self.num_heads);
        let st = state_mut::<SharePrefillState>(state);
        let ahat_t = probes.ahat()?.clone();
        let nb = seq / BLOCK_SIZE;
        let mut plans = Vec::with_capacity(num_heads);
        // vslash probe is fetched lazily only if some head needs it
        for h in 0..num_heads {
            let ahat_h = ahat_t.index_axis0(h)?;
            let ahat = ahat_h.as_f32()?;
            let cluster = if self.tau <= 0.0 {
                // "w/o sharing" ablation: no cluster machinery at all.
                None
            } else {
                self.cluster_of(layer, h)
            };
            let info = decide_pattern(ahat, cluster, &st.dict, self.delta,
                                      self.tau);
            match info.decision {
                Decision::Dense => {
                    st.stats.dense += 1;
                    plans.push(HeadPlan::dense(true));
                }
                Decision::SharedPivot => {
                    st.stats.shared += 1;
                    let entry = &st.dict[&info.cluster.unwrap()];
                    plans.push(HeadPlan {
                        mask: Some(entry.mask.clone()),
                        label: PatternLabel::Shared,
                        publish: false,
                    });
                }
                Decision::VSlash => {
                    st.stats.vslash += 1;
                    let amap_t = probes.vslash_map()?.index_axis0(h)?;
                    let mask = search_vslash(amap_t.as_f32()?, BLOCK_SIZE,
                                             seq, self.gamma);
                    plans.push(HeadPlan::sparse(mask, PatternLabel::VSlash));
                }
            }
            debug_assert!(plans.last().unwrap().mask.as_ref()
                .map_or(true, |m| m.nb == nb));
        }
        Ok(plans)
    }

    fn publish_abar(&self, state: &mut dyn PatternState, layer: usize,
                    head: usize, nb: usize, abar: &[f32]) {
        if let Some(c) = self.cluster_of(layer, head) {
            let st = state_mut::<SharePrefillState>(state);
            let entry = construct_pivotal(abar, nb, self.gamma,
                                          (layer, head));
            st.dict.insert(c, entry);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::FakeProbes;
    use crate::methods::state_ref;
    use crate::util::math::NEG_INF;

    fn uniform_abar(nb: usize) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = 0.0;
            }
        }
        m
    }

    fn stats_of(state: &dyn PatternState) -> &DecisionStats {
        &state_ref::<SharePrefillState>(state).stats
    }

    #[test]
    fn first_head_dense_then_shared() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        // two heads, same cluster, flat probes (similar + not sparse)
        let clusters = vec![Some(0), Some(0)];
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2, Some(clusters));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans[0].mask.is_none() && plans[0].publish,
                "first head must bootstrap dense");
        // publish the dense head's map, re-plan: second head shares
        sp.publish_abar(st.as_mut(), 0, 0, nb, &uniform_abar(nb));
        let plans2 = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans2[1].label, PatternLabel::Shared);
        assert!(stats_of(st.as_ref()).shared >= 1);
    }

    #[test]
    fn noise_cluster_uses_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2,
                                   Some(vec![None, None]));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
    }

    #[test]
    fn tau_zero_is_pure_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let sp = SharePrefill::new(0.0, 0.3, 0.9, 1, 2,
                                   Some(vec![Some(0), Some(0)]));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
        assert_eq!(stats_of(st.as_ref()).dense, 0);
    }

    #[test]
    fn each_request_gets_fresh_independent_state() {
        let seq = 4 * BLOCK_SIZE;
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 1,
                                   Some(vec![Some(0)]));
        let mut s1 = sp.begin_request(seq);
        sp.publish_abar(s1.as_mut(), 0, 0, 4, &uniform_abar(4));
        assert!(!state_ref::<SharePrefillState>(s1.as_ref())
            .dict.is_empty());
        // a second request starts empty…
        let s2 = sp.begin_request(seq);
        assert!(state_ref::<SharePrefillState>(s2.as_ref())
            .dict.is_empty());
        // …and the first keeps its dictionary: states are independent
        assert!(!state_ref::<SharePrefillState>(s1.as_ref())
            .dict.is_empty());
    }

    /// Advance one request through all layers, optionally interleaving a
    /// second request (its own probes + state) between our layers; dense
    /// heads publish a uniform abar so sharing kicks in.
    fn plan_request(
        sp: &SharePrefill, seq: usize, layers: usize, nb: usize,
        probes: &mut FakeProbes,
        mut other: Option<(&mut FakeProbes, &mut dyn PatternState)>,
    ) -> Vec<(usize, PatternLabel, Option<crate::attention::BlockMask>)> {
        let mut st = sp.begin_request(seq);
        let mut out = Vec::new();
        for layer in 0..layers {
            let plans = sp.plan_layer(st.as_mut(), layer, seq, 2, probes)
                .unwrap();
            for (h, p) in plans.iter().enumerate() {
                if p.publish {
                    sp.publish_abar(st.as_mut(), layer, h, nb,
                                    &uniform_abar(nb));
                }
                out.push((layer, p.label, p.mask.clone()));
            }
            // advance the *other* request between our layers
            if let Some((op, ost)) = other.as_mut() {
                let oplans = sp.plan_layer(&mut **ost, layer, seq, 2,
                                           &mut **op).unwrap();
                for (h, p) in oplans.iter().enumerate() {
                    if p.publish {
                        sp.publish_abar(&mut **ost, layer, h, nb,
                                        &uniform_abar(nb));
                    }
                }
            }
        }
        out
    }

    /// The tentpole property at the strategy level: two requests planned
    /// with interleaved `plan_layer`/`publish_abar` calls produce exactly
    /// the plans each would get planned serially.
    #[test]
    fn interleaved_requests_match_serial_plans() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let layers = 2;
        let sp = SharePrefill::new(0.2, 0.3, 0.9, layers, 2,
                                   Some(vec![Some(0); 4]));

        // one request plans over flat probes, the other over structured
        // ones — different inputs, so leaked state would change plans
        let mut flat = FakeProbes::flat(2, seq);
        let serial = plan_request(&sp, seq, layers, nb, &mut flat, None);

        let mut flat2 = FakeProbes::flat(2, seq);
        let mut structured = FakeProbes::structured(2, seq);
        let mut other_state = sp.begin_request(seq);
        let interleaved = plan_request(
            &sp, seq, layers, nb, &mut flat2,
            Some((&mut structured, other_state.as_mut())));

        assert_eq!(serial.len(), interleaved.len());
        for (a, b) in serial.iter().zip(interleaved.iter()) {
            assert_eq!(a.0, b.0, "layer mismatch");
            assert_eq!(a.1, b.1, "label changed under interleaving");
            assert_eq!(a.2, b.2, "mask changed under interleaving");
        }
    }

    #[test]
    fn default_cluster_fallback_covers_all_heads() {
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 3, 4, None);
        assert_eq!(sp.clusters.len(), 12);
        assert!(sp.clusters.iter().all(Option::is_some));
    }
}
