//! **SharePrefill** — the paper's contribution (Section 5, Algorithm 1).
//!
//! Offline: heads are clustered by attention-map similarity
//! (`clustering::offline`).  Online, per layer and head:
//!
//! 1. *Determine Sparse Pattern* (Alg. 3): probe â, JS sparsity test vs.
//!    uniform (δ), JS similarity test vs. the cluster's pivotal
//!    representative ã (τ).
//! 2. *Share Pivotal Pattern* (Alg. 4): reuse the cluster's mask if
//!    present; otherwise the first head of the cluster runs **dense**.
//! 3. After the dense head's sparse-attention call returns its full
//!    block-averaged QK map Ã, *Construct Pivotal Pattern* (Alg. 2)
//!    publishes (ã, M) into the evolving per-request dictionary.
//!
//! The strategy itself is a stateless planner (τ, δ, γ, the offline
//! cluster table); the evolving pivotal dictionary is *request* state,
//! held in [`SharePrefillState`] — one per in-flight prefill, so chunks
//! of concurrent prompts can interleave without sharing patterns across
//! requests (patterns are input-dependent, Section 4).
//!
//! Ablations (Table 2): `tau <= 0` disables sharing entirely (no dense
//! bootstrap either — pure vertical-slash); `delta > 1` disables the
//! highly-sparse-head exclusion.

use anyhow::Result;
use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::attention::{construct_pivotal_scratch, decide_pattern,
                       search_vslash_heads, BlockMask, Decision,
                       PivotalDict, PivotalEntry};
use crate::config::MethodKind;
use crate::exec::WorkerPool;
use crate::BLOCK_SIZE;

use super::pattern_cache::{probe_recall, PatternCache};
use super::{state_mut, state_ref, CacheDecision, HeadPlan, PatternLabel,
            PatternState, PatternStrategy, Probes};

pub struct SharePrefill {
    tau: f64,
    delta: f64,
    gamma: f32,
    num_heads: usize,
    /// (layer * num_heads + head) → cluster id (None = noise).
    clusters: Vec<Option<usize>>,
    /// Engine-owned cross-request pattern cache: consulted at
    /// `begin_request` (warm candidates), refreshed at `end_request`.
    cache: Option<Rc<RefCell<PatternCache>>>,
    /// Engine-owned worker pool: per-head planning work (vslash
    /// searches, cache-validation probes) fans out on it with
    /// head-indexed slots, so any pool width plans bit-identically to
    /// the serial default.
    pool: Rc<WorkerPool>,
}

/// Per-request pattern state: the evolving pivotal dictionary plus the
/// request's decision statistics (Figure 6).
pub struct SharePrefillState {
    /// Evolving pivotal dictionary (cluster → (ã, M)) for one request.
    dict: PivotalDict,
    /// Cached patterns for this request's length bucket, snapshotted at
    /// `begin_request` (empty when the cache is off or cold).  Shared
    /// immutable entries: validated per head before use and never
    /// mutated mid-request, so interleaved prefills cannot observe
    /// each other's patterns.
    warm: HashMap<usize, Rc<PivotalEntry>>,
    /// Clusters whose warm candidate was adopted verbatim (cache hits)
    /// — published back by freshness bump, not deep copy.
    adopted: Vec<usize>,
    /// Whether the cross-request cache participates in this request.
    cache_on: bool,
    /// Probe-recall threshold warm candidates must pass (copied from
    /// the cache config so `plan_layer` never re-borrows the cache).
    validation: f64,
    /// Scratch buffer for pivotal construction, reused across every
    /// `publish_abar` of the request (one nb² softmax workspace instead
    /// of an allocation per publishing head).
    scratch: Vec<f32>,
    pub stats: DecisionStats,
}

impl PatternState for SharePrefillState {
    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Counts of pattern kinds chosen during a request, plus how the
/// cross-request cache participated (all-zero when the cache is off).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DecisionStats {
    pub dense: usize,
    pub shared: usize,
    pub vslash: usize,
    /// Heads that reused a validated cached pattern (skipped the dense
    /// pivotal bootstrap).
    pub cache_hits: usize,
    /// Dense-bootstrap heads the enabled cache had no pattern for.
    pub cache_misses: usize,
    /// Heads whose cached pattern failed probe validation (exact path
    /// ran instead).
    pub cache_rejected: usize,
}

impl SharePrefill {
    pub fn new(tau: f64, delta: f64, gamma: f32, num_layers: usize,
               num_heads: usize, clusters: Option<Vec<Option<usize>>>)
               -> SharePrefill {
        let clusters = clusters.unwrap_or_else(|| {
            // Without an offline clustering file, fall back to one cluster
            // per (head index) across layers — heads at the same position
            // often align; the similarity gate (τ) still protects sharing.
            (0..num_layers * num_heads)
                .map(|i| Some(i % num_heads))
                .collect()
        });
        assert_eq!(clusters.len(), num_layers * num_heads,
                   "cluster table must cover every (layer, head)");
        SharePrefill {
            tau,
            delta,
            gamma,
            num_heads,
            clusters,
            cache: None,
            pool: Rc::new(WorkerPool::serial()),
        }
    }

    /// Attach the engine-owned cross-request pattern cache (`None` or a
    /// disabled cache leave behavior bit-identical to a cache-less
    /// build).
    pub fn with_cache(mut self, cache: Option<Rc<RefCell<PatternCache>>>)
                      -> SharePrefill {
        self.cache = cache;
        self
    }

    /// Attach the engine-owned worker pool (defaults to the serial
    /// pool; any width is bit-identical — asserted in the tests below).
    pub fn with_pool(mut self, pool: Rc<WorkerPool>) -> SharePrefill {
        self.pool = pool;
        self
    }

    fn cluster_of(&self, layer: usize, head: usize) -> Option<usize> {
        self.clusters[layer * self.num_heads + head]
    }
}

impl PatternStrategy for SharePrefill {
    fn kind(&self) -> MethodKind {
        MethodKind::SharePrefill
    }

    fn begin_request(&self, seq: usize) -> Box<dyn PatternState> {
        // Patterns are input-dependent: each request evolves its own
        // dictionary from scratch, independent of concurrent prefills.
        // With the cross-request cache enabled, patterns observed on
        // earlier requests at this length bucket ride along as warm
        // candidates — validated per head before any use.
        let (warm, cache_on, validation) = match &self.cache {
            Some(c) if c.borrow().enabled() => {
                let mut cache = c.borrow_mut();
                let validation = cache.validation();
                (cache.lookup(seq), true, validation)
            }
            _ => (HashMap::new(), false, 0.0),
        };
        Box::new(SharePrefillState {
            dict: PivotalDict::new(),
            warm,
            adopted: Vec::new(),
            cache_on,
            validation,
            scratch: Vec::new(),
            stats: DecisionStats::default(),
        })
    }

    fn plan_layer(&self, state: &mut dyn PatternState, layer: usize,
                  seq: usize, num_heads: usize, probes: &mut dyn Probes)
                  -> Result<Vec<HeadPlan>> {
        debug_assert_eq!(num_heads, self.num_heads);
        let st = state_mut::<SharePrefillState>(state);
        let ahat_t = probes.ahat()?.clone();
        let ahat_all = ahat_t.as_f32()?;
        let nb = seq / BLOCK_SIZE;
        // Cache-validation probes: a head's probe_recall against its
        // warm candidate is a pure function of this layer's â probe.
        // On a parallel pool all heads score speculatively up front
        // (head-indexed slots; the serial decision pass below consumes
        // a score only when the head actually reaches the Dense
        // decision); on the default serial pool the score is computed
        // lazily inside the Dense arm exactly as before — identical
        // outcomes either way, no wasted work at workers = 1.  A
        // bucket-mismatched candidate scores -inf (can never validate).
        let score = |cand: &PivotalEntry, h: usize| -> f64 {
            if cand.ahat_last.len() != nb || cand.mask.nb != nb {
                return f64::NEG_INFINITY;
            }
            probe_recall(&ahat_all[h * nb..(h + 1) * nb], &cand.mask)
        };
        let speculative = st.cache_on && !st.warm.is_empty()
            && self.pool.workers() > 1;
        let recalls: Vec<Option<f64>> = if speculative {
            let warm = &st.warm;
            let cands: Vec<Option<&PivotalEntry>> = (0..num_heads)
                .map(|h| {
                    let cluster = if self.tau <= 0.0 {
                        None
                    } else {
                        self.cluster_of(layer, h)
                    };
                    cluster.and_then(|c| warm.get(&c)).map(|rc| &**rc)
                })
                .collect();
            self.pool
                .fan_out(num_heads, |h| cands[h].map(|cand| score(cand, h)))
        } else {
            Vec::new()
        };
        let mut plans = Vec::with_capacity(num_heads);
        // vslash probe is fetched lazily only if some head needs it;
        // the searches themselves run in the head-parallel pass below
        let mut vslash_heads: Vec<usize> = Vec::new();
        for h in 0..num_heads {
            let ahat = &ahat_all[h * nb..(h + 1) * nb];
            let cluster = if self.tau <= 0.0 {
                // "w/o sharing" ablation: no cluster machinery at all.
                None
            } else {
                self.cluster_of(layer, h)
            };
            let info = decide_pattern(ahat, cluster, &st.dict, self.delta,
                                      self.tau);
            match info.decision {
                Decision::Dense => {
                    // Before paying for the pivotal bootstrap, try the
                    // cross-request cache: a warm candidate is adopted
                    // only if its mask covers >= `validation` of this
                    // head's observed probe mass (the pre-computed
                    // recall score) — a stale pattern can cost a
                    // rejection, never a silently-wrong mask.
                    let cache = if !st.cache_on {
                        CacheDecision::Off
                    } else {
                        let recall = if speculative {
                            recalls[h]
                        } else {
                            info.cluster
                                .and_then(|c| st.warm.get(&c))
                                .map(|rc| score(&**rc, h))
                        };
                        match recall {
                            Some(r) if r >= st.validation => {
                                CacheDecision::Hit
                            }
                            Some(_) => CacheDecision::Rejected,
                            None => CacheDecision::Miss,
                        }
                    };
                    if cache == CacheDecision::Hit {
                        let c = info.cluster.unwrap();
                        // one deep copy, only on actual adoption (the
                        // dict owns its entries)
                        let entry = (*st.warm[&c]).clone();
                        let mask = entry.mask.clone();
                        // adopted entry becomes the cluster's pivot, so
                        // later heads share against it exactly as they
                        // would against a freshly constructed one; once
                        // present it is never overwritten (Dense can't
                        // fire for this cluster again), so end_request
                        // may refresh it by sharing instead of copying
                        st.dict.insert(c, entry);
                        st.adopted.push(c);
                        st.stats.shared += 1;
                        st.stats.cache_hits += 1;
                        plans.push(HeadPlan {
                            mask: Some(mask),
                            label: PatternLabel::Shared,
                            publish: false,
                            cache,
                        });
                    } else {
                        match cache {
                            CacheDecision::Miss => st.stats.cache_misses += 1,
                            CacheDecision::Rejected => {
                                st.stats.cache_rejected += 1;
                            }
                            _ => {}
                        }
                        st.stats.dense += 1;
                        let mut plan = HeadPlan::dense(true);
                        plan.cache = cache;
                        plans.push(plan);
                    }
                }
                Decision::SharedPivot => {
                    st.stats.shared += 1;
                    let entry = &st.dict[&info.cluster.unwrap()];
                    plans.push(HeadPlan {
                        mask: Some(entry.mask.clone()),
                        label: PatternLabel::Shared,
                        publish: false,
                        cache: CacheDecision::Off,
                    });
                }
                Decision::VSlash => {
                    st.stats.vslash += 1;
                    vslash_heads.push(h);
                    // placeholder mask; the head-parallel search pass
                    // below fills the real one into this slot
                    plans.push(HeadPlan::sparse(BlockMask::empty(nb),
                                                PatternLabel::VSlash));
                }
            }
        }
        // Vertical-slash searches — the expensive per-head planning
        // work — fan out with head-indexed slots, so the pool width
        // cannot reorder or change any mask.
        if !vslash_heads.is_empty() {
            let amap_t = probes.vslash_map()?.clone();
            let amap = amap_t.as_f32()?;
            let jobs: Vec<(usize, f32)> =
                vslash_heads.iter().map(|&h| (h, self.gamma)).collect();
            let masks = search_vslash_heads(&self.pool, amap, &jobs,
                                            BLOCK_SIZE, seq);
            for (&h, mask) in vslash_heads.iter().zip(masks) {
                plans[h].mask = Some(mask);
            }
        }
        for p in &plans {
            debug_assert!(p.mask.as_ref().is_none_or(|m| m.nb == nb));
        }
        Ok(plans)
    }

    fn publish_abar(&self, state: &mut dyn PatternState, layer: usize,
                    head: usize, nb: usize, abar: &[f32]) {
        if let Some(c) = self.cluster_of(layer, head) {
            let st = state_mut::<SharePrefillState>(state);
            let entry = construct_pivotal_scratch(abar, nb, self.gamma,
                                                  (layer, head),
                                                  &mut st.scratch);
            st.dict.insert(c, entry);
            // A freshly constructed pattern replaces any cache adoption
            // for this cluster (possible when a same-layer head was
            // planned dense before another head's hit landed in the
            // dict): end_request must publish the fresh entry, not
            // freshness-bump the candidate a head just re-derived past.
            st.adopted.retain(|&a| a != c);
        }
    }

    fn end_request(&self, state: &dyn PatternState, seq: usize) {
        if let Some(cache) = &self.cache {
            let st = state_ref::<SharePrefillState>(state);
            // Publishing the whole dictionary also refreshes entries
            // this request adopted from the cache (LRU freshness);
            // adopted entries are re-shared, not deep-copied.
            let adopted: HashMap<usize, Rc<PivotalEntry>> = st.adopted
                .iter()
                .filter_map(|c| st.warm.get(c).map(|rc| (*c, rc.clone())))
                .collect();
            cache.borrow_mut().publish_request(seq, &st.dict, &adopted);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::tests_support::FakeProbes;
    use crate::methods::state_ref;
    use crate::util::math::NEG_INF;

    fn uniform_abar(nb: usize) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = 0.0;
            }
        }
        m
    }

    fn stats_of(state: &dyn PatternState) -> &DecisionStats {
        &state_ref::<SharePrefillState>(state).stats
    }

    #[test]
    fn first_head_dense_then_shared() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        // two heads, same cluster, flat probes (similar + not sparse)
        let clusters = vec![Some(0), Some(0)];
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2, Some(clusters));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans[0].mask.is_none() && plans[0].publish,
                "first head must bootstrap dense");
        // publish the dense head's map, re-plan: second head shares
        sp.publish_abar(st.as_mut(), 0, 0, nb, &uniform_abar(nb));
        let plans2 = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans2[1].label, PatternLabel::Shared);
        assert!(stats_of(st.as_ref()).shared >= 1);
    }

    #[test]
    fn noise_cluster_uses_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2,
                                   Some(vec![None, None]));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
    }

    #[test]
    fn tau_zero_is_pure_vslash() {
        let seq = 4 * BLOCK_SIZE;
        let sp = SharePrefill::new(0.0, 0.3, 0.9, 1, 2,
                                   Some(vec![Some(0), Some(0)]));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::VSlash));
        assert_eq!(stats_of(st.as_ref()).dense, 0);
    }

    #[test]
    fn each_request_gets_fresh_independent_state() {
        let seq = 4 * BLOCK_SIZE;
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 1,
                                   Some(vec![Some(0)]));
        let mut s1 = sp.begin_request(seq);
        sp.publish_abar(s1.as_mut(), 0, 0, 4, &uniform_abar(4));
        assert!(!state_ref::<SharePrefillState>(s1.as_ref())
            .dict.is_empty());
        // a second request starts empty…
        let s2 = sp.begin_request(seq);
        assert!(state_ref::<SharePrefillState>(s2.as_ref())
            .dict.is_empty());
        // …and the first keeps its dictionary: states are independent
        assert!(!state_ref::<SharePrefillState>(s1.as_ref())
            .dict.is_empty());
    }

    /// Advance one request through all layers, optionally interleaving a
    /// second request (its own probes + state) between our layers; dense
    /// heads publish a uniform abar so sharing kicks in.
    fn plan_request(
        sp: &SharePrefill, seq: usize, layers: usize, nb: usize,
        probes: &mut FakeProbes,
        mut other: Option<(&mut FakeProbes, &mut dyn PatternState)>,
    ) -> Vec<(usize, PatternLabel, Option<crate::attention::BlockMask>)> {
        let mut st = sp.begin_request(seq);
        let mut out = Vec::new();
        for layer in 0..layers {
            let plans = sp.plan_layer(st.as_mut(), layer, seq, 2, probes)
                .unwrap();
            for (h, p) in plans.iter().enumerate() {
                if p.publish {
                    sp.publish_abar(st.as_mut(), layer, h, nb,
                                    &uniform_abar(nb));
                }
                out.push((layer, p.label, p.mask.clone()));
            }
            // advance the *other* request between our layers
            if let Some((op, ost)) = other.as_mut() {
                let oplans = sp.plan_layer(&mut **ost, layer, seq, 2,
                                           &mut **op).unwrap();
                for (h, p) in oplans.iter().enumerate() {
                    if p.publish {
                        sp.publish_abar(&mut **ost, layer, h, nb,
                                        &uniform_abar(nb));
                    }
                }
            }
        }
        out
    }

    /// The tentpole property at the strategy level: two requests planned
    /// with interleaved `plan_layer`/`publish_abar` calls produce exactly
    /// the plans each would get planned serially.
    #[test]
    fn interleaved_requests_match_serial_plans() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let layers = 2;
        let sp = SharePrefill::new(0.2, 0.3, 0.9, layers, 2,
                                   Some(vec![Some(0); 4]));

        // one request plans over flat probes, the other over structured
        // ones — different inputs, so leaked state would change plans
        let mut flat = FakeProbes::flat(2, seq);
        let serial = plan_request(&sp, seq, layers, nb, &mut flat, None);

        let mut flat2 = FakeProbes::flat(2, seq);
        let mut structured = FakeProbes::structured(2, seq);
        let mut other_state = sp.begin_request(seq);
        let interleaved = plan_request(
            &sp, seq, layers, nb, &mut flat2,
            Some((&mut structured, other_state.as_mut())));

        assert_eq!(serial.len(), interleaved.len());
        for (a, b) in serial.iter().zip(interleaved.iter()) {
            assert_eq!(a.0, b.0, "layer mismatch");
            assert_eq!(a.1, b.1, "label changed under interleaving");
            assert_eq!(a.2, b.2, "mask changed under interleaving");
        }
    }

    #[test]
    fn default_cluster_fallback_covers_all_heads() {
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 3, 4, None);
        assert_eq!(sp.clusters.len(), 12);
        assert!(sp.clusters.iter().all(Option::is_some));
    }

    // ---- cross-request pattern cache ----

    use crate::attention::BlockMask;
    use crate::config::PatternCacheConfig;

    fn enabled_cache(validation: f64) -> Rc<RefCell<PatternCache>> {
        Rc::new(RefCell::new(PatternCache::new(PatternCacheConfig {
            enabled: true,
            capacity: 64,
            validation,
            max_age: 64,
        })))
    }

    fn seeded_cache(seq: usize, mask: BlockMask, validation: f64)
                    -> Rc<RefCell<PatternCache>> {
        let nb = mask.nb;
        let cache = enabled_cache(validation);
        let mut dict = PivotalDict::new();
        dict.insert(0, PivotalEntry {
            ahat_last: vec![1.0 / nb as f32; nb],
            mask,
            source: (0, 0),
        });
        cache.borrow_mut().publish(seq, &dict);
        cache
    }

    #[test]
    fn warm_cache_hit_skips_dense_bootstrap() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        // a dense cached mask covers all of the probe mass: recall 1.0
        let cache = seeded_cache(seq, BlockMask::dense(nb), 0.75);
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2,
                                   Some(vec![Some(0), Some(0)]))
            .with_cache(Some(cache));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert!(plans.iter().all(|p| p.label == PatternLabel::Shared));
        assert_eq!(plans[0].cache, CacheDecision::Hit);
        let s = stats_of(st.as_ref());
        assert_eq!(s.dense, 0, "warm request must skip the dense bootstrap");
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.shared, 2);
    }

    #[test]
    fn validation_failure_falls_back_to_exact_path() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        // diagonal-only mask: its last row covers only the last block,
        // ~14% of the flat probes' mass — far below the 0.75 threshold
        let mut mask = BlockMask::empty(nb);
        mask.ensure_diagonal();
        let cache = seeded_cache(seq, mask, 0.75);
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2,
                                   Some(vec![Some(0), Some(0)]))
            .with_cache(Some(cache));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        // both heads reject the stale pattern and run the exact dense
        // bootstrap — never a silently-wrong mask
        assert!(plans.iter().all(|p| p.mask.is_none() && p.publish));
        assert!(plans.iter()
            .all(|p| p.cache == CacheDecision::Rejected));
        let s = stats_of(st.as_ref());
        assert_eq!(s.dense, 2);
        assert_eq!(s.cache_rejected, 2);
        assert_eq!(s.cache_hits, 0);
    }

    #[test]
    fn mismatched_bucket_entry_never_validates() {
        let seq = 4 * BLOCK_SIZE;
        // entry constructed for an 8-block bucket offered at a 4-block
        // request (cannot happen through lookup's bucketing; defensive)
        let cache = seeded_cache(seq, BlockMask::dense(8), 0.75);
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 1, Some(vec![Some(0)]))
            .with_cache(Some(cache));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(1, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 1, &mut probes)
            .unwrap();
        assert_eq!(plans[0].cache, CacheDecision::Rejected);
        assert!(plans[0].publish);
    }

    #[test]
    fn patterns_published_at_end_request_warm_the_next_request() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let cache = enabled_cache(0.75);
        let sp = SharePrefill::new(0.2, 0.3, 0.9, 1, 2,
                                   Some(vec![Some(0), Some(0)]))
            .with_cache(Some(cache.clone()));
        // request 1: cold — bootstraps dense, publishes at completion
        let mut s1 = sp.begin_request(seq);
        let mut probes = FakeProbes::flat(2, seq);
        let plans = sp.plan_layer(s1.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans[0].cache, CacheDecision::Miss);
        assert_eq!(stats_of(s1.as_ref()).cache_misses, 2);
        for (h, p) in plans.iter().enumerate() {
            if p.publish {
                sp.publish_abar(s1.as_mut(), 0, h, nb, &uniform_abar(nb));
            }
        }
        sp.end_request(s1.as_ref(), seq);
        assert!(!cache.borrow().is_empty(),
                "end_request must publish into the cache");
        // request 2: warm — validated reuse, no dense bootstrap at all
        let mut s2 = sp.begin_request(seq);
        let mut probes2 = FakeProbes::flat(2, seq);
        let plans2 = sp.plan_layer(s2.as_mut(), 0, seq, 2, &mut probes2)
            .unwrap();
        assert!(plans2.iter().all(|p| p.label == PatternLabel::Shared));
        let s = stats_of(s2.as_ref());
        assert_eq!(s.dense, 0);
        assert_eq!(s.cache_hits, 1);
    }

    /// A same-layer mixed outcome: head 0 rejects the warm candidate
    /// (planned dense, publish) while head 1 adopts it.  Head 0's
    /// `publish_abar` then overwrites the adopted dict entry, so
    /// `end_request` must publish the *fresh* pattern — not
    /// freshness-bump the stale candidate head 0 just re-derived past.
    #[test]
    fn rejected_dense_publish_overrides_adopted_refresh() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        // structured probes (Rng seed 42): head 0's mass sits on blocks
        // {0,1} (~0.71/0.25), head 1's on blocks {1,2} (~0.49/0.26).  A
        // cached mask whose last row is {1,2,3} scores ~0.29 for head 0
        // (reject at 0.6) and ~0.76 for head 1 (hit).
        let mask = BlockMask::from_pairs(
            nb, [(0, 0), (1, 1), (2, 2), (3, 1), (3, 2), (3, 3)]);
        let stale_last_row = mask.row(nb - 1).len();
        let cache = seeded_cache(seq, mask, 0.6);
        // δ > 1 disables the sparsity exclusion so both heads reach the
        // Dense decision; both share cluster 0
        let sp = SharePrefill::new(0.2, 1.01, 0.9, 1, 2,
                                   Some(vec![Some(0), Some(0)]))
            .with_cache(Some(cache.clone()));
        let mut st = sp.begin_request(seq);
        let mut probes = FakeProbes::structured(2, seq);
        let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
            .unwrap();
        assert_eq!(plans[0].cache, CacheDecision::Rejected);
        assert_eq!(plans[1].cache, CacheDecision::Hit);
        // engine order: head 0's dense publish lands after the plans
        sp.publish_abar(st.as_mut(), 0, 0, nb, &uniform_abar(nb));
        sp.end_request(st.as_ref(), seq);
        // the cache now holds the fresh pattern (uniform abar at γ=0.9
        // selects the full causal mask: last row covers all 4 blocks),
        // not the stale 2-block mask that failed validation
        let republished = cache.borrow_mut().lookup(seq);
        let last_row = republished[&0].mask.row(nb - 1).len();
        assert_ne!(last_row, stale_last_row,
                   "stale rejected pattern must not be re-refreshed");
        assert_eq!(last_row, nb, "fresh dense-derived pattern expected");
    }

    /// The cache-off acceptance property at the strategy level: no
    /// cache, a disabled cache, and an enabled-but-cold cache all plan
    /// bit-identically (labels and masks) on the same inputs.
    #[test]
    fn disabled_or_cold_cache_is_bit_identical_to_cacheless() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let layers = 2;
        let clusters = vec![Some(0); 4];
        let mk = || SharePrefill::new(0.2, 0.3, 0.9, layers, 2,
                                      Some(clusters.clone()));
        let base = mk();
        let disabled = mk().with_cache(Some(Rc::new(RefCell::new(
            PatternCache::new(PatternCacheConfig::default())))));
        let cold = mk().with_cache(Some(enabled_cache(0.75)));
        for probes_of in [FakeProbes::flat
                              as fn(usize, usize) -> FakeProbes,
                          FakeProbes::structured] {
            let mut pa = probes_of(2, seq);
            let a = plan_request(&base, seq, layers, nb, &mut pa, None);
            let mut pb = probes_of(2, seq);
            let b = plan_request(&disabled, seq, layers, nb, &mut pb, None);
            let mut pc = probes_of(2, seq);
            let c = plan_request(&cold, seq, layers, nb, &mut pc, None);
            assert_eq!(a, b, "disabled cache changed the plans");
            assert_eq!(a, c, "cold enabled cache changed the plans");
        }
    }

    /// The tentpole property at the strategy level: any worker-pool
    /// width plans bit-identically to the serial default — layers,
    /// labels and masks — on both probe shapes.
    #[test]
    fn worker_pool_widths_plan_bit_identically() {
        use crate::exec::{env_workers, WorkerPool};
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let layers = 2;
        // .max(2): the parallel arm stays distinct even when the CI
        // matrix pins SHAREPREFILL_WORKERS=1
        let par = env_workers().unwrap_or(4).max(2);
        let mk = |workers: usize| {
            SharePrefill::new(0.2, 0.3, 0.9, layers, 2,
                              Some(vec![Some(0); 4]))
                .with_pool(Rc::new(WorkerPool::new(workers)))
        };
        for probes_of in [FakeProbes::flat
                              as fn(usize, usize) -> FakeProbes,
                          FakeProbes::structured] {
            let mut pa = probes_of(2, seq);
            let a = plan_request(&mk(1), seq, layers, nb, &mut pa, None);
            let mut pb = probes_of(2, seq);
            let b = plan_request(&mk(par), seq, layers, nb, &mut pb,
                                 None);
            assert_eq!(a, b, "pool width {par} changed the plans");
        }
    }

    /// Cache-validation probes fan out too: the mixed hit/reject
    /// outcome (head 0 rejects the warm candidate, head 1 adopts it)
    /// and the DecisionStats are identical at any pool width.
    #[test]
    fn worker_pool_preserves_cache_decisions() {
        use crate::exec::WorkerPool;
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let run = |workers: usize| {
            let mask = BlockMask::from_pairs(
                nb, [(0, 0), (1, 1), (2, 2), (3, 1), (3, 2), (3, 3)]);
            let cache = seeded_cache(seq, mask, 0.6);
            let sp = SharePrefill::new(0.2, 1.01, 0.9, 1, 2,
                                       Some(vec![Some(0), Some(0)]))
                .with_cache(Some(cache))
                .with_pool(Rc::new(WorkerPool::new(workers)));
            let mut st = sp.begin_request(seq);
            let mut probes = FakeProbes::structured(2, seq);
            let plans = sp.plan_layer(st.as_mut(), 0, seq, 2, &mut probes)
                .unwrap();
            let sig: Vec<_> = plans.iter()
                .map(|p| (p.label, p.cache, p.publish, p.mask.clone()))
                .collect();
            (sig, stats_of(st.as_ref()).clone())
        };
        let serial = run(1);
        assert_eq!(serial.0[0].1, CacheDecision::Rejected);
        assert_eq!(serial.0[1].1, CacheDecision::Hit);
        assert_eq!(serial, run(4), "pool width changed cache decisions");
    }

    /// Golden regression for SharePrefill decisions: the per-layer
    /// (dense, shared, vslash) counts on the canonical fake-probe
    /// inputs.  If pattern quality drifts (probe pooling, JS distance,
    /// thresholds), this fails loudly with the full per-layer picture.
    #[test]
    fn decision_stats_golden_snapshot() {
        let seq = 4 * BLOCK_SIZE;
        let nb = 4;
        let layers = 3;
        let heads = 2;

        fn per_layer(sp: &SharePrefill, probes: &mut FakeProbes,
                     layers: usize, seq: usize, nb: usize, heads: usize)
                     -> Vec<(usize, usize, usize)> {
            let mut st = sp.begin_request(seq);
            let mut out = Vec::new();
            let mut prev = DecisionStats::default();
            for layer in 0..layers {
                let plans = sp.plan_layer(st.as_mut(), layer, seq, heads,
                                          probes).unwrap();
                for (h, p) in plans.iter().enumerate() {
                    if p.publish {
                        sp.publish_abar(st.as_mut(), layer, h, nb,
                                        &uniform_abar(nb));
                    }
                }
                let s = stats_of(st.as_ref()).clone();
                out.push((s.dense - prev.dense, s.shared - prev.shared,
                          s.vslash - prev.vslash));
                prev = s;
            }
            out
        }

        // consistent probes, both heads in one cluster: the first layer
        // bootstraps dense on every head (the pivot lands only after
        // the layer's maps publish), every later layer shares it
        let sp = SharePrefill::new(0.2, 0.3, 0.9, layers, heads,
                                   Some(vec![Some(0); layers * heads]));
        let mut flat = FakeProbes::consistent(heads, seq);
        assert_eq!(per_layer(&sp, &mut flat, layers, seq, nb, heads),
                   vec![(2, 0, 0), (0, 2, 0), (0, 2, 0)],
                   "consistent-probe decision snapshot drifted");

        // structured probes (stripes, Rng seed 42): every head is
        // highly sparse (d_sparse ≈ 0.50 / 0.36 ≥ δ = 0.3), so the
        // exclusion rule sends all heads to vertical-slash everywhere
        let sp2 = SharePrefill::new(0.2, 0.3, 0.9, layers, heads,
                                    Some(vec![Some(0), Some(1),
                                              Some(0), Some(1),
                                              Some(0), Some(1)]));
        let mut structured = FakeProbes::structured(heads, seq);
        assert_eq!(per_layer(&sp2, &mut structured, layers, seq, nb, heads),
                   vec![(0, 0, 2); 3],
                   "structured-probe decision snapshot drifted");
    }
}
