//! **Cross-request pattern cache** — amortizing the pivotal bootstrap
//! across prompts.
//!
//! The paper's second key observation (Section 4) is that inter-head
//! pattern similarity "remains remarkably consistent across diverse
//! inputs".  Within one request SharePrefill already exploits this by
//! sharing each cluster's pivotal pattern across its heads; this module
//! extends the amortization *across requests*: when a prefill
//! completes, its per-cluster pivotal entries (ã, M) are distilled into
//! a length-bucketed cache owned by the engine, and later requests at
//! the same seq bucket start with those entries as *warm candidates*.
//!
//! A warm candidate is never trusted blindly — patterns are
//! input-dependent, so each head that would bootstrap dense first runs
//! a cheap probe-based validation ([`probe_recall`]): the fraction of
//! the head's observed last-row-block attention mass (the â probe the
//! strategy computes anyway) covered by the cached mask's last row.
//! Only above `serve.pattern_cache.validation` is the cached pattern
//! adopted; otherwise the head falls back to the exact dense-bootstrap
//! path, and the fresh pattern it constructs refreshes the cache at
//! publish time.  A stale pattern can cost a validation miss, never a
//! silently-wrong mask.
//!
//! Eviction is two-tier: entries unrefreshed for `max_age` publishes
//! are dropped on lookup (staleness), and the total entry count is
//! bounded by `capacity` with least-recently-refreshed-first eviction.
//!
//! Single-threaded by design: the engine and its strategies live on one
//! worker thread (see `serving/server.rs`), so the cache is shared via
//! `Rc<RefCell<_>>` like the calibration collector in `cli_main`.

use std::collections::HashMap;
use std::rc::Rc;

use crate::attention::{BlockMask, PivotalDict, PivotalEntry};
use crate::config::PatternCacheConfig;

/// Lifetime counters of one cache instance (inserts / refreshes happen
/// at publish; expirations and evictions at lookup / publish).  Per-head
/// hit / miss / validation-failure counts live in the per-request
/// `DecisionStats` and aggregate into the serving metrics.
#[derive(Debug, Default, Clone)]
pub struct PatternCacheStats {
    /// Entries inserted for a (bucket, cluster) not previously cached.
    pub inserts: u64,
    /// Entries overwritten with a fresher pattern.
    pub refreshes: u64,
    /// Entries dropped because they out-aged `max_age` publishes.
    pub expired: u64,
    /// Entries dropped to respect `capacity`.
    pub evicted: u64,
    /// `lookup` calls (one per SharePrefill request when enabled).
    pub lookups: u64,
    /// Lookups that returned at least one warm candidate.
    pub warm_lookups: u64,
    /// Entries absorbed from peer shards' broadcasts (fleet mode).
    pub absorbed: u64,
}

/// One cached pattern plus its freshness stamp.  Entries are immutable
/// once published, so lookups hand out `Rc` clones — a warm request's
/// candidate snapshot costs a refcount bump per cluster, not a deep
/// copy of every mask at the bucket.
#[derive(Debug, Clone)]
struct CacheSlot {
    entry: Rc<PivotalEntry>,
    /// Publish generation at which this entry was last (re)written.
    refreshed_at: u64,
    /// `Some(shard)` when the entry was absorbed from a peer shard's
    /// broadcast, `None` for locally published entries.
    origin: Option<usize>,
}

/// The cross-request pivotal-pattern cache: seq bucket → cluster id →
/// cached entry.  Owned engine-side, shared into the SharePrefill
/// strategy; populated by [`PatternCache::publish`] when a prefill
/// completes and consulted by [`PatternCache::lookup`] at
/// `begin_request`.  Because candidates are snapshotted per request at
/// `begin_request` and publishes happen only at prefill completion,
/// interleaved prefills never observe each other's half-built patterns.
#[derive(Debug)]
pub struct PatternCache {
    cfg: PatternCacheConfig,
    buckets: HashMap<usize, HashMap<usize, CacheSlot>>,
    /// Monotone publish counter (the staleness clock).
    generation: u64,
    /// Locally published entries awaiting a broadcast drain (deep
    /// copies — the fleet ships them across threads).  Bounded at
    /// `capacity` entries between drains, oldest dropped first, so a
    /// single-engine deployment that never drains pays O(capacity)
    /// memory, not O(traffic).
    pending: Vec<(usize, usize, PivotalEntry)>,
    pub stats: PatternCacheStats,
}

impl PatternCache {
    pub fn new(cfg: PatternCacheConfig) -> PatternCache {
        PatternCache {
            cfg,
            buckets: HashMap::new(),
            generation: 0,
            pending: Vec::new(),
            stats: PatternCacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Per-head probe-recall threshold warm candidates must pass.
    pub fn validation(&self) -> f64 {
        self.cfg.validation
    }

    /// Cached entries across all length buckets.
    pub fn len(&self) -> usize {
        self.buckets.values().map(HashMap::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Warm candidates for a request running at seq bucket `seq`
    /// (cluster id → shared entry).  Prunes entries that out-aged
    /// `max_age` publishes; empty when the cache is disabled or cold.
    pub fn lookup(&mut self, seq: usize)
                  -> HashMap<usize, Rc<PivotalEntry>> {
        if !self.cfg.enabled {
            return HashMap::new();
        }
        self.stats.lookups += 1;
        if let Some(bucket) = self.buckets.get_mut(&seq) {
            let (gen, max_age) = (self.generation, self.cfg.max_age);
            let before = bucket.len();
            bucket.retain(|_, s| gen - s.refreshed_at <= max_age);
            self.stats.expired += (before - bucket.len()) as u64;
        }
        let out: HashMap<usize, Rc<PivotalEntry>> = self.buckets.get(&seq)
            .map(|b| b.iter().map(|(c, s)| (*c, s.entry.clone())).collect())
            .unwrap_or_default();
        if !out.is_empty() {
            self.stats.warm_lookups += 1;
        }
        out
    }

    /// Distill a finished request's pivotal dictionary into the cache:
    /// every (cluster → entry) the request constructed or adopted is
    /// inserted (or refreshed) under its seq bucket, then capacity is
    /// enforced by evicting the least-recently-refreshed entries.
    pub fn publish(&mut self, seq: usize, dict: &PivotalDict) {
        self.publish_request(seq, dict, &HashMap::new());
    }

    /// [`PatternCache::publish`] that additionally knows which clusters
    /// the request adopted *verbatim* from the cache: those get their
    /// freshness stamp bumped by re-sharing the existing immutable
    /// entry (a refcount bump), only genuinely new or re-derived
    /// entries pay the deep copy.
    pub fn publish_request(&mut self, seq: usize, dict: &PivotalDict,
                           adopted: &HashMap<usize, Rc<PivotalEntry>>) {
        if !self.cfg.enabled || dict.is_empty() || self.cfg.capacity == 0 {
            return;
        }
        self.generation += 1;
        let gen = self.generation;
        let bucket = self.buckets.entry(seq).or_default();
        for (&cluster, entry) in dict {
            let slot = CacheSlot {
                entry: match adopted.get(&cluster) {
                    Some(rc) => rc.clone(),
                    None => Rc::new(entry.clone()),
                },
                refreshed_at: gen,
                origin: None,
            };
            // queue the broadcast copy (deep clone: the export crosses
            // threads, so it cannot share this cache's Rc)
            self.pending.push((seq, cluster, slot.entry.as_ref().clone()));
            match bucket.insert(cluster, slot) {
                Some(_) => self.stats.refreshes += 1,
                None => self.stats.inserts += 1,
            }
        }
        if self.pending.len() > self.cfg.capacity {
            let drop_n = self.pending.len() - self.cfg.capacity;
            self.pending.drain(..drop_n);
        }
        self.enforce_capacity();
    }

    /// Drain the locally published entries queued for the fleet's
    /// cross-shard broadcast, sorted by (bucket, cluster) so the
    /// broadcast order is deterministic regardless of dict iteration
    /// order.  Empty when the cache is disabled (nothing ever queues).
    pub fn take_broadcast(&mut self) -> Vec<(usize, usize, PivotalEntry)> {
        let mut out = std::mem::take(&mut self.pending);
        out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        out
    }

    /// Absorb a peer shard's broadcast entry as a warm candidate tagged
    /// with its origin.  Three rules keep this safe: (1) local entries
    /// always win — a remote pattern never overwrites one this engine
    /// derived itself; (2) an absorbed entry is never re-broadcast, so
    /// gifts cannot loop between shards; (3) adoption stays
    /// validation-gated at lookup time ([`probe_recall`]), so a
    /// broadcast can offer a candidate but never change a mask by
    /// itself.
    pub fn absorb_remote(&mut self, seq: usize, cluster: usize,
                         entry: PivotalEntry, origin: usize) {
        if !self.cfg.enabled || self.cfg.capacity == 0 {
            return;
        }
        let gen = self.generation;
        let bucket = self.buckets.entry(seq).or_default();
        if bucket.contains_key(&cluster) {
            return; // rule 1: the local entry wins
        }
        bucket.insert(cluster, CacheSlot {
            entry: Rc::new(entry),
            refreshed_at: gen,
            origin: Some(origin),
        });
        self.stats.absorbed += 1;
        self.enforce_capacity();
    }

    /// Origin tag of a cached entry: `Some(None)` = published locally,
    /// `Some(Some(shard))` = absorbed from that shard's broadcast,
    /// `None` = not cached.
    pub fn origin_of(&self, seq: usize, cluster: usize)
                     -> Option<Option<usize>> {
        self.buckets
            .get(&seq)
            .and_then(|b| b.get(&cluster))
            .map(|s| s.origin)
    }

    /// Drop least-recently-refreshed entries until within capacity
    /// (deterministic: ties break by (bucket, cluster) key order).
    fn enforce_capacity(&mut self) {
        let excess = self.len().saturating_sub(self.cfg.capacity);
        if excess == 0 {
            return;
        }
        let mut all: Vec<(u64, usize, usize)> = self.buckets.iter()
            .flat_map(|(&seq, b)| {
                b.iter().map(move |(&c, s)| (s.refreshed_at, seq, c))
            })
            .collect();
        all.sort_unstable();
        for &(_, seq, cluster) in all.iter().take(excess) {
            if let Some(b) = self.buckets.get_mut(&seq) {
                b.remove(&cluster);
                self.stats.evicted += 1;
            }
        }
        self.buckets.retain(|_, b| !b.is_empty());
    }
}

/// Probe-based validation score for a cached mask: the fraction of the
/// request's observed last-row-block attention mass (â, a distribution
/// over kv blocks) that the mask's last row covers.  This is the
/// recall the head would get on the blocks the probe says matter —
/// cheap (the â probe is computed anyway) and conservative (a pattern
/// from a differently-shaped prompt scores low and is rejected).
pub fn probe_recall(ahat: &[f32], mask: &BlockMask) -> f64 {
    if mask.nb == 0 || ahat.len() != mask.nb {
        return 0.0;
    }
    mask.row(mask.nb - 1).iter()
        .map(|&j| ahat[j as usize] as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(nb: usize, tag: usize) -> PivotalEntry {
        PivotalEntry {
            ahat_last: vec![1.0 / nb as f32; nb],
            mask: BlockMask::dense(nb),
            source: (tag, 0),
        }
    }

    fn dict_of(pairs: &[(usize, usize)]) -> PivotalDict {
        // (cluster, nb) pairs
        pairs.iter()
            .map(|&(c, nb)| (c, entry(nb, c)))
            .collect()
    }

    fn on(capacity: usize, max_age: u64) -> PatternCacheConfig {
        PatternCacheConfig {
            enabled: true,
            capacity,
            validation: 0.75,
            max_age,
        }
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut c = PatternCache::new(PatternCacheConfig::default());
        assert!(!c.enabled());
        c.publish(256, &dict_of(&[(0, 4)]));
        assert!(c.is_empty());
        assert!(c.lookup(256).is_empty());
        assert_eq!(c.stats.lookups, 0, "disabled lookups are not counted");
    }

    #[test]
    fn publish_then_lookup_same_bucket() {
        let mut c = PatternCache::new(on(16, 8));
        c.publish(256, &dict_of(&[(0, 4), (1, 4)]));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats.inserts, 2);
        let warm = c.lookup(256);
        assert_eq!(warm.len(), 2);
        assert_eq!(warm[&0].mask.nb, 4);
        assert_eq!(c.stats.warm_lookups, 1);
        // a different length bucket is cold
        assert!(c.lookup(512).is_empty());
        assert_eq!(c.stats.lookups, 2);
        assert_eq!(c.stats.warm_lookups, 1);
    }

    #[test]
    fn republish_refreshes_not_duplicates() {
        let mut c = PatternCache::new(on(16, 8));
        c.publish(256, &dict_of(&[(0, 4)]));
        c.publish(256, &dict_of(&[(0, 4)]));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats.inserts, 1);
        assert_eq!(c.stats.refreshes, 1);
    }

    #[test]
    fn stale_entries_expire_on_lookup() {
        let mut c = PatternCache::new(on(16, 2));
        c.publish(256, &dict_of(&[(0, 4)]));
        // two more publishes age the entry to exactly max_age: still live
        c.publish(512, &dict_of(&[(1, 8)]));
        c.publish(512, &dict_of(&[(2, 8)]));
        assert_eq!(c.lookup(256).len(), 1);
        // one more publish pushes it past max_age: expired on lookup
        c.publish(512, &dict_of(&[(3, 8)]));
        assert!(c.lookup(256).is_empty());
        assert_eq!(c.stats.expired, 1);
        // refreshing resurrects the bucket
        c.publish(256, &dict_of(&[(0, 4)]));
        assert_eq!(c.lookup(256).len(), 1);
    }

    #[test]
    fn capacity_evicts_least_recently_refreshed() {
        let mut c = PatternCache::new(on(2, 1000));
        c.publish(256, &dict_of(&[(0, 4)]));
        c.publish(512, &dict_of(&[(1, 8)]));
        c.publish(1024, &dict_of(&[(2, 16)]));
        assert_eq!(c.len(), 2, "capacity must be enforced");
        assert_eq!(c.stats.evicted, 1);
        // the oldest publish (bucket 256) was the victim
        assert!(c.lookup(256).is_empty());
        assert_eq!(c.lookup(512).len(), 1);
        assert_eq!(c.lookup(1024).len(), 1);
    }

    #[test]
    fn refresh_protects_from_eviction() {
        let mut c = PatternCache::new(on(2, 1000));
        c.publish(256, &dict_of(&[(0, 4)]));
        c.publish(512, &dict_of(&[(1, 8)]));
        c.publish(256, &dict_of(&[(0, 4)])); // refresh 256
        c.publish(1024, &dict_of(&[(2, 16)]));
        // 512 is now the least recently refreshed → evicted
        assert!(c.lookup(512).is_empty());
        assert_eq!(c.lookup(256).len(), 1);
    }

    #[test]
    fn publish_request_reuses_adopted_entries() {
        let mut c = PatternCache::new(on(16, 8));
        c.publish(256, &dict_of(&[(0, 4)]));
        let rc = c.lookup(256)[&0].clone();
        // a request that adopted cluster 0 verbatim (its dict holds an
        // owned copy) must refresh by sharing, not re-cloning
        let dict: PivotalDict =
            [(0usize, (*rc).clone())].into_iter().collect();
        let adopted: HashMap<usize, Rc<PivotalEntry>> =
            [(0usize, rc.clone())].into_iter().collect();
        c.publish_request(256, &dict, &adopted);
        assert_eq!(c.stats.refreshes, 1);
        assert!(Rc::ptr_eq(&c.lookup(256)[&0], &rc),
                "adopted entry must be shared, not deep-copied");
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = PatternCache::new(on(0, 8));
        c.publish(256, &dict_of(&[(0, 4)]));
        assert!(c.is_empty());
        assert!(c.take_broadcast().is_empty());
        c.absorb_remote(256, 0, entry(4, 0), 1);
        assert!(c.is_empty());
    }

    #[test]
    fn publishes_queue_for_broadcast_in_key_order() {
        let mut c = PatternCache::new(on(16, 8));
        c.publish(512, &dict_of(&[(1, 8), (0, 8)]));
        c.publish(256, &dict_of(&[(0, 4)]));
        let out = c.take_broadcast();
        let keys: Vec<(usize, usize)> =
            out.iter().map(|(s, cl, _)| (*s, *cl)).collect();
        assert_eq!(keys, vec![(256, 0), (512, 0), (512, 1)]);
        assert!(c.take_broadcast().is_empty(), "drain is one-shot");
        // disabled cache never queues
        let mut off = PatternCache::new(PatternCacheConfig::default());
        off.publish(256, &dict_of(&[(0, 4)]));
        assert!(off.take_broadcast().is_empty());
    }

    #[test]
    fn pending_broadcast_is_bounded_by_capacity() {
        let mut c = PatternCache::new(on(2, 1000));
        c.publish(256, &dict_of(&[(0, 4)]));
        c.publish(512, &dict_of(&[(1, 8)]));
        c.publish(1024, &dict_of(&[(2, 16)]));
        let out = c.take_broadcast();
        assert_eq!(out.len(), 2, "pending must not outgrow capacity");
        // oldest queued entry (bucket 256) was the one dropped
        assert!(out.iter().all(|(s, _, _)| *s != 256));
    }

    #[test]
    fn absorb_remote_tags_origin_and_local_wins() {
        let mut c = PatternCache::new(on(16, 8));
        c.absorb_remote(256, 0, entry(4, 7), 3);
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats.absorbed, 1);
        assert_eq!(c.origin_of(256, 0), Some(Some(3)));
        assert_eq!(c.origin_of(256, 9), None);
        // the absorbed entry is a warm candidate …
        assert_eq!(c.lookup(256).len(), 1);
        // … but was never queued for re-broadcast (no gift loops)
        assert!(c.take_broadcast().is_empty());
        // a local publish overwrites it and clears the origin tag
        c.publish(256, &dict_of(&[(0, 4)]));
        assert_eq!(c.origin_of(256, 0), Some(None));
        // and a remote gift never overwrites a local entry
        c.absorb_remote(256, 0, entry(4, 9), 5);
        assert_eq!(c.origin_of(256, 0), Some(None));
        assert_eq!(c.stats.absorbed, 1);
    }

    #[test]
    fn disabled_cache_never_absorbs() {
        let mut c = PatternCache::new(PatternCacheConfig::default());
        c.absorb_remote(256, 0, entry(4, 0), 1);
        assert!(c.is_empty());
        assert_eq!(c.stats.absorbed, 0);
    }

    #[test]
    fn probe_recall_scores_last_row_coverage() {
        let nb = 4;
        let ahat = [0.4f32, 0.3, 0.2, 0.1];
        // dense mask covers everything
        assert!((probe_recall(&ahat, &BlockMask::dense(nb)) - 1.0).abs()
                < 1e-6);
        // last row covering blocks {0, 3} → 0.4 + 0.1
        let m = BlockMask::from_pairs(nb, [(3, 0), (3, 3), (0, 0)]);
        assert!((probe_recall(&ahat, &m) - 0.5).abs() < 1e-6);
        // length mismatch is an automatic fail, never a panic
        assert_eq!(probe_recall(&ahat, &BlockMask::dense(8)), 0.0);
        assert_eq!(probe_recall(&ahat, &BlockMask::empty(4)), 0.0);
    }
}
