//! Weight store: loads `artifacts/weights-{model}.bin` (tenstore) into
//! per-layer [`Tensor`]s with shapes validated against the model spec.

use anyhow::{bail, Result};
use std::path::Path;

use crate::runtime::registry::ModelSpec;
use crate::runtime::Tensor;
use crate::substrate::tenstore::TenStore;

/// One transformer layer's weights (names match `python/compile/model.py`).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln1: Tensor,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub ln2: Tensor,
    pub w_gate: Tensor,
    pub w_up: Tensor,
    pub w_down: Tensor,
}

/// All model weights.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub embed: Tensor,
    pub layers: Vec<LayerWeights>,
    pub ln_f: Tensor,
    pub w_out: Tensor,
}

impl ModelWeights {
    pub fn load(dir: &Path, spec: &ModelSpec) -> Result<ModelWeights> {
        let store = TenStore::load(dir.join(&spec.weights_file))?;
        let get = |name: &str, shape: Vec<usize>| -> Result<Tensor> {
            let t = store.get(name)?;
            if t.shape != shape {
                bail!("weight '{name}': stored shape {:?} != expected {:?}",
                      t.shape, shape);
            }
            Ok(Tensor::f32(t.shape.clone(), t.data.clone()))
        };
        let (h, hkv, d, dm, f, v) =
            (spec.num_heads, spec.num_kv_heads, spec.head_dim, spec.hidden,
             spec.ffn, spec.vocab);
        let mut layers = Vec::with_capacity(spec.num_layers);
        for i in 0..spec.num_layers {
            let p = |field: &str| format!("layer{i}.{field}");
            layers.push(LayerWeights {
                ln1: get(&p("ln1"), vec![dm])?,
                wq: get(&p("wq"), vec![dm, h * d])?,
                wk: get(&p("wk"), vec![dm, hkv * d])?,
                wv: get(&p("wv"), vec![dm, hkv * d])?,
                wo: get(&p("wo"), vec![h * d, dm])?,
                ln2: get(&p("ln2"), vec![dm])?,
                w_gate: get(&p("w_gate"), vec![dm, f])?,
                w_up: get(&p("w_up"), vec![dm, f])?,
                w_down: get(&p("w_down"), vec![f, dm])?,
            });
        }
        Ok(ModelWeights {
            embed: get("embed", vec![v, dm])?,
            layers,
            ln_f: get("ln_f", vec![dm])?,
            w_out: get("w_out", vec![dm, v])?,
        })
    }
}
