//! Model layer: weight store (tenstore → typed per-layer tensors) and the
//! typed stage executor that drives the L2 artifacts.

pub mod stages;
pub mod weights;

pub use stages::Stages;
pub use weights::ModelWeights;
