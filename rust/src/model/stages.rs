//! Typed stage executor: wraps the artifact registry with the model's
//! stage signatures (embed / qkv / attn / post_attn / lm_head / probes /
//! decode) so the serving engine reads like the paper's Algorithm 1.
//!
//! All heavy compute happens inside the compiled HLO; this layer only
//! shuffles host tensors (per-head slicing, GQA repeat, cache updates).

use anyhow::Result;
use std::path::Path;
use std::rc::Rc;

use crate::runtime::registry::ModelSpec;
use crate::runtime::{Registry, Tensor};
use crate::util::timer::StageProfiler;

use super::weights::{LayerWeights, ModelWeights};

/// Stage executor bound to one model.
pub struct Stages {
    pub spec: ModelSpec,
    pub weights: ModelWeights,
    registry: Rc<Registry>,
}

/// Output of the qkv stage, per layer.
pub struct QkvOut {
    /// `[H, S, D]` roped queries.
    pub q: Tensor,
    /// `[Hkv, S, D]` roped keys (cache layout).
    pub k: Tensor,
    /// `[Hkv, S, D]` values.
    pub v: Tensor,
}

impl Stages {
    pub fn new(registry: Rc<Registry>, model: &str) -> Result<Stages> {
        let spec = registry.model(model)?.clone();
        let weights =
            ModelWeights::load(Path::new(&registry.dir), &spec)?;
        Ok(Stages { spec, weights, registry })
    }

    pub fn registry(&self) -> &Rc<Registry> {
        &self.registry
    }

    fn art(&self, stage: &str, seq: usize) -> String {
        format!("{}_{stage}_s{seq}", self.spec.prefix)
    }

    /// tokens `[S]` → hidden `[S, Dm]`.
    pub fn embed(&self, tokens: &[i32], seq: usize, prof: &mut StageProfiler)
                 -> Result<Tensor> {
        debug_assert_eq!(tokens.len(), seq);
        let name = self.art("embed", seq);
        let t = Tensor::i32(vec![seq], tokens.to_vec());
        let out = prof.time("embed", || {
            self.registry.execute(&name, &[t, self.weights.embed.clone()])
        })?;
        Ok(out.into_iter().next().unwrap())
    }

    /// hidden `[S, Dm]` → (q `[H,S,D]`, k `[Hkv,S,D]`, v `[Hkv,S,D]`).
    pub fn qkv(&self, layer: usize, x: &Tensor, seq: usize,
               prof: &mut StageProfiler) -> Result<QkvOut> {
        let lw = &self.weights.layers[layer];
        let name = self.art("qkv", seq);
        let mut out = prof.time("qkv", || {
            self.registry.execute(&name, &[
                x.clone(), lw.ln1.clone(), lw.wq.clone(), lw.wk.clone(),
                lw.wv.clone(),
            ])
        })?;
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let q = out.pop().unwrap();
        Ok(QkvOut { q, k, v })
    }

    /// Per-head sparse attention through the budgeted L1 kernel.
    /// `q/k/v` are `[S, D]` single-head tensors; `idx/valid` are the packed
    /// pattern at the artifact's budget.  Returns `(o [S,D], abar [NB,B])`.
    pub fn attn_head(&self, seq: usize, budget: usize, q: Tensor, k: Tensor,
                     v: Tensor, idx: Tensor, valid: Tensor,
                     prof: &mut StageProfiler)
                     -> Result<(Tensor, Tensor)> {
        let name = format!("{}_attn_s{seq}_b{budget}", self.spec.prefix);
        let mut out = prof.time("attn", || {
            self.registry.execute(&name, &[q, k, v, idx, valid])
        })?;
        let abar = out.pop().unwrap();
        let o = out.pop().unwrap();
        Ok((o, abar))
    }

    /// attn outputs `[H, S, D]` + residual `[S, Dm]` → hidden `[S, Dm]`.
    pub fn post_attn(&self, layer: usize, attn_out: Tensor, resid: &Tensor,
                     seq: usize, prof: &mut StageProfiler) -> Result<Tensor> {
        let lw = &self.weights.layers[layer];
        let name = self.art("postattn", seq);
        let out = prof.time("post_attn", || {
            self.registry.execute(&name, &[
                attn_out, resid.clone(), lw.wo.clone(), lw.ln2.clone(),
                lw.w_gate.clone(), lw.w_up.clone(), lw.w_down.clone(),
            ])
        })?;
        Ok(out.into_iter().next().unwrap())
    }

    /// hidden `[S, Dm]` → logits `[S, V]` (or `[1, V]` via seq = 1).
    pub fn lm_head(&self, x: &Tensor, seq: usize, prof: &mut StageProfiler)
                   -> Result<Tensor> {
        let name = self.art("lmhead", seq);
        let out = prof.time("lm_head", || {
            self.registry.execute(&name, &[
                x.clone(), self.weights.ln_f.clone(),
                self.weights.w_out.clone(),
            ])
        })?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Pattern probe: (q̂ `[H,BS,D]`, k(repeated) `[H,S,D]`) → â `[H,NB]`.
    pub fn pattern_probe(&self, qh: Tensor, k: Tensor, seq: usize,
                         prof: &mut StageProfiler) -> Result<Tensor> {
        let name = self.art("patternprobe", seq);
        let out = prof.time("probe", || {
            self.registry.execute(&name, &[qh, k])
        })?;
        Ok(out.into_iter().next().unwrap())
    }

    /// VSlash probe: → Â `[H, BS, S]` (softmaxed last-block attention).
    pub fn vslash_probe(&self, qh: Tensor, k: Tensor, seq: usize,
                        prof: &mut StageProfiler) -> Result<Tensor> {
        let name = self.art("vslashprobe", seq);
        let out = prof.time("probe", || {
            self.registry.execute(&name, &[qh, k])
        })?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Flex probe: (q `[H,S,D]`, k `[H,S,D]`) → pooled map `[H,NB,NB]`.
    pub fn flex_probe(&self, q: Tensor, k: Tensor, seq: usize,
                      prof: &mut StageProfiler) -> Result<Tensor> {
        let name = self.art("flexprobe", seq);
        let out = prof.time("probe", || {
            self.registry.execute(&name, &[q, k])
        })?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Fused decode layer over the KV cache. `x` is `[1, Dm]`; caches are
    /// `[Hkv, Smax, D]`; `pos` is the new token's index. Returns
    /// `(x_out, k_new [Hkv,D], v_new [Hkv,D])`.
    pub fn decode_layer(&self, layer: usize, x: &Tensor, kcache: &Tensor,
                        vcache: &Tensor, pos: i32,
                        prof: &mut StageProfiler)
                        -> Result<(Tensor, Tensor, Tensor)> {
        let lw: &LayerWeights = &self.weights.layers[layer];
        let name = format!("{}_decode", self.spec.prefix);
        let mut out = prof.time("decode", || {
            self.registry.execute(&name, &[
                x.clone(), lw.ln1.clone(), lw.wq.clone(), lw.wk.clone(),
                lw.wv.clone(), lw.wo.clone(), lw.ln2.clone(),
                lw.w_gate.clone(), lw.w_up.clone(), lw.w_down.clone(),
                kcache.clone(), vcache.clone(), Tensor::scalar_i32(pos),
            ])
        })?;
        let v_new = out.pop().unwrap();
        let k_new = out.pop().unwrap();
        let x_out = out.pop().unwrap();
        Ok((x_out, k_new, v_new))
    }

    /// Extract head `h`'s `[S, D]` q slice from `[H, S, D]`.
    pub fn head_q(&self, q: &Tensor, h: usize) -> Result<Tensor> {
        q.index_axis0(h)
    }

    /// Extract the kv slice serving query head `h` (GQA mapping).
    pub fn head_kv(&self, kv: &Tensor, h: usize) -> Result<Tensor> {
        kv.index_axis0(h / self.spec.group())
    }

    /// Repeat kv `[Hkv, S, D]` to `[H, S, D]` (probe inputs).
    pub fn repeat_kv(&self, kv: &Tensor) -> Result<Tensor> {
        let shape = kv.shape().to_vec();
        let (hkv, s, d) = (shape[0], shape[1], shape[2]);
        let h = self.spec.num_heads;
        let g = self.spec.group();
        let src = kv.as_f32()?;
        let mut out = vec![0f32; h * s * d];
        for qh in 0..h {
            let kvh = qh / g;
            out[qh * s * d..(qh + 1) * s * d]
                .copy_from_slice(&src[kvh * s * d..(kvh + 1) * s * d]);
        }
        debug_assert_eq!(hkv, self.spec.num_kv_heads);
        Ok(Tensor::f32(vec![h, s, d], out))
    }

    /// Last row-block of q: `[H, S, D]` → `[H, BS, D]` (probe input).
    pub fn last_block_q(&self, q: &Tensor, seq: usize) -> Result<Tensor> {
        let bs = crate::BLOCK_SIZE;
        let shape = q.shape().to_vec();
        let (h, s, d) = (shape[0], shape[1], shape[2]);
        debug_assert_eq!(s, seq);
        let src = q.as_f32()?;
        let mut out = vec![0f32; h * bs * d];
        for hh in 0..h {
            let base = hh * s * d + (s - bs) * d;
            out[hh * bs * d..(hh + 1) * bs * d]
                .copy_from_slice(&src[base..base + bs * d]);
        }
        Ok(Tensor::f32(vec![h, bs, d], out))
    }
}
