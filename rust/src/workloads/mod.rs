//! Synthetic workloads mirroring the paper's evaluation suites:
//! InfiniteBench-style tasks (Table 1), a PG19-style language-modeling
//! corpus (Figure 4), and the MInference-style length-adjustable latency
//! prompts (Figures 1 & 5).  All byte-level, deterministic from a seed,
//! generated with the same archetype mix as the training corpus
//! (`python/compile/corpus.py`) so the trained models are in-distribution.

pub mod corpus;
pub mod scoring;
pub mod tasks;

pub use corpus::TextGen;
pub use tasks::{latency_prompt, pg19_sample, task_samples, Task, TaskSample,
                TASK_NAMES};
