//! Scoring: exact-match for retrieval tasks, generation fidelity vs. the
//! dense reference for open-ended tasks, token-level perplexity.

use super::corpus::detokenize;

/// Exact-match score (0/100): does the generation start with the answer?
pub fn exact_match(generated: &[i32], answer: &str) -> f64 {
    let text = detokenize(generated);
    if text.trim_start().starts_with(answer) {
        100.0
    } else {
        0.0
    }
}

/// Generation fidelity (0..100): fraction of positions where the method's
/// greedy generation agrees with the dense reference's.
pub fn fidelity(generated: &[i32], reference: &[i32]) -> f64 {
    if reference.is_empty() {
        return 100.0;
    }
    let n = generated.len().min(reference.len());
    let agree = (0..n).filter(|&i| generated[i] == reference[i]).count();
    100.0 * agree as f64 / reference.len() as f64
}

/// Perplexity from next-token log-probs: logits `[S, V]` row-major over
/// the *bucket*, targets are `tokens[1..real_len]`.
pub fn perplexity(logits: &[f32], vocab: usize, tokens: &[i32],
                  real_len: usize) -> f64 {
    let mut nll = 0f64;
    let mut count = 0usize;
    for pos in 0..real_len.saturating_sub(1) {
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let target = tokens[pos + 1] as usize;
        // stable log-softmax
        let m = row.iter().copied().fold(f32::MIN, f32::max);
        let lse: f32 = row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln()
            + m;
        nll += (lse - row[target]) as f64;
        count += 1;
    }
    (nll / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_prefix() {
        let gen: Vec<i32> = "123456 and more".bytes()
            .map(|b| b as i32).collect();
        assert_eq!(exact_match(&gen, "123456"), 100.0);
        assert_eq!(exact_match(&gen, "999999"), 0.0);
    }

    #[test]
    fn exact_match_ignores_leading_space() {
        let gen: Vec<i32> = " 42x".bytes().map(|b| b as i32).collect();
        assert_eq!(exact_match(&gen, "42"), 100.0);
    }

    #[test]
    fn fidelity_partial() {
        assert_eq!(fidelity(&[1, 2, 3, 4], &[1, 2, 9, 9]), 50.0);
        assert_eq!(fidelity(&[1, 2], &[1, 2]), 100.0);
        assert_eq!(fidelity(&[], &[1, 2]), 0.0);
        assert_eq!(fidelity(&[1], &[]), 100.0);
    }

    #[test]
    fn perplexity_uniform_logits() {
        // uniform logits over V=4 -> ppl == 4 regardless of targets
        let v = 4;
        let logits = vec![0f32; 3 * v];
        let tokens = vec![0, 1, 2];
        let ppl = perplexity(&logits, v, &tokens, 3);
        assert!((ppl - 4.0).abs() < 1e-6);
    }

    #[test]
    fn perplexity_confident_model() {
        // logits strongly favoring the true next token -> ppl ≈ 1
        let v = 4;
        let tokens = vec![0, 1, 2, 3];
        let mut logits = vec![0f32; 4 * v];
        for pos in 0..3 {
            logits[pos * v + tokens[pos + 1] as usize] = 50.0;
        }
        let ppl = perplexity(&logits, v, &tokens, 4);
        assert!(ppl < 1.001);
    }
}
