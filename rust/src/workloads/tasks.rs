//! InfiniteBench-sim: ten tasks with the same names and attention
//! archetypes as the paper's Table 1 suite, at simulator scale
//! (bucket-exact prompts, byte-level).  See DESIGN.md "Substitutions".
//!
//! Scoring: retrieval-style tasks (Retr.*, Math.Find) have exact-match
//! answers; the open-ended tasks (En.*, Zh.QA, Code.Debug) are scored by
//! *generation fidelity* against the dense FlashAttention reference —
//! the accuracy-preservation quantity the paper's Table 1 tracks.

use crate::util::rng::Rng;

use super::corpus::{tokenize, TextGen};

/// The ten Table-1 tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    EnSum,
    EnQA,
    EnMC,
    EnDia,
    ZhQA,
    CodeDebug,
    MathFind,
    RetrPassKey,
    RetrNumber,
    RetrKV,
}

pub const TASK_NAMES: [(Task, &str); 10] = [
    (Task::EnSum, "En.Sum"),
    (Task::EnQA, "En.QA"),
    (Task::EnMC, "En.MC"),
    (Task::EnDia, "En.Dia"),
    (Task::ZhQA, "Zh.QA"),
    (Task::CodeDebug, "Code.Debug"),
    (Task::MathFind, "Math.Find"),
    (Task::RetrPassKey, "Retr.PassKey"),
    (Task::RetrNumber, "Retr.Number"),
    (Task::RetrKV, "Retr.KV"),
];

impl Task {
    pub fn name(&self) -> &'static str {
        TASK_NAMES.iter().find(|(t, _)| t == self).unwrap().1
    }

    pub fn by_name(name: &str) -> Option<Task> {
        TASK_NAMES.iter().find(|(_, n)| *n == name).map(|(t, _)| *t)
    }

    /// Exact-match tasks; the rest are fidelity-scored.
    pub fn has_exact_answer(&self) -> bool {
        matches!(self, Task::RetrPassKey | Task::RetrNumber | Task::RetrKV
                 | Task::MathFind)
    }
}

/// One evaluation sample.
#[derive(Debug, Clone)]
pub struct TaskSample {
    pub task: Task,
    /// Bucket-exact prompt.
    pub prompt: Vec<i32>,
    /// Exact answer string (None → fidelity-scored).
    pub answer: Option<String>,
    pub gen_tokens: usize,
}

/// Compose a prompt of exactly `target` bytes: `body` + filler + `cue`.
fn compose(g: &mut TextGen, body: &str, cue: &str, target: usize) -> String {
    let need = target.saturating_sub(body.len() + cue.len());
    let mut s = String::with_capacity(target);
    s.push_str(body);
    s.push_str(&g.filler(need));
    s.push_str(cue);
    // exact length: trim the middle if slightly over
    if s.len() > target {
        let cut = s.len() - target;
        let cue_start = s.len() - cue.len();
        s.replace_range(cue_start - cut..cue_start, "");
    }
    debug_assert_eq!(s.len(), target);
    s
}

/// Generate one sample of `task` with a bucket-exact `target_len` prompt.
pub fn sample(task: Task, seed: u64, target_len: usize) -> TaskSample {
    let mut g = TextGen::new(seed ^ 0x5eed_0000);
    let mut rng = Rng::new(seed ^ 0xface);
    match task {
        Task::RetrPassKey | Task::RetrNumber => {
            let (_, val) = g.kv_pair();
            let noun = if task == Task::RetrPassKey {
                "pass key"
            } else {
                "magic number"
            };
            // plant the fact somewhere in the first 60% of the context
            let head_len = target_len * rng.range(20, 60) / 100;
            let fact = format!("\nthe {noun} is {val}. remember {val}.\n");
            let head = g.filler(head_len.saturating_sub(fact.len()));
            let body = format!("{head}{fact}");
            let cue = format!("\nwhat is the {noun}? the {noun} is ");
            let prompt = compose(&mut g, &body, &cue, target_len);
            TaskSample { task, prompt: tokenize(&prompt),
                         answer: Some(val), gen_tokens: 6 }
        }
        Task::RetrKV => {
            // exactly the training corpus's <KEY:..>/<GET:..> structure
            let n = rng.range(2, 5);
            let pairs: Vec<(String, String)> =
                (0..n).map(|_| g.kv_pair()).collect();
            let mut body = String::new();
            for (k, v) in &pairs {
                body.push_str(&format!("<KEY:{k}={v}>\n"));
            }
            let (qk, qv) = pairs[rng.below(n)].clone();
            let cue = format!("<GET:{qk}>");
            let prompt = compose(&mut g, &body, &cue, target_len);
            TaskSample { task, prompt: tokenize(&prompt),
                         answer: Some(qv), gen_tokens: 6 }
        }
        Task::MathFind => {
            let count = rng.range(8, 20);
            let mut vals: Vec<u32> =
                (0..count).map(|_| rng.range(100, 999) as u32).collect();
            let mx = *vals.iter().max().unwrap();
            let mut body = String::from("values:");
            for v in vals.drain(..) {
                body.push_str(&format!(" {v}"));
            }
            body.push('\n');
            let cue = "\nthe largest value in the list is ";
            let prompt = compose(&mut g, &body, cue, target_len);
            TaskSample { task, prompt: tokenize(&prompt),
                         answer: Some(mx.to_string()), gen_tokens: 3 }
        }
        Task::EnDia => {
            let body = g.dialogue(30);
            let prompt = compose(&mut g, &body, "\nann: ", target_len);
            TaskSample { task, prompt: tokenize(&prompt), answer: None,
                         gen_tokens: 12 }
        }
        Task::CodeDebug => {
            let body = g.codeish(60);
            let prompt = compose(&mut g, &body, "\nlet ", target_len);
            TaskSample { task, prompt: tokenize(&prompt), answer: None,
                         gen_tokens: 12 }
        }
        Task::EnSum | Task::EnQA | Task::EnMC | Task::ZhQA => {
            let body = g.prose(200);
            let cue = match task {
                Task::EnSum => "\nin summary, the ",
                Task::EnQA => "\nquestion: who said it? answer: ",
                Task::EnMC => "\nthe best choice is ",
                _ => "\nanswer: ",
            };
            let prompt = compose(&mut g, &body, cue, target_len);
            TaskSample { task, prompt: tokenize(&prompt), answer: None,
                         gen_tokens: 12 }
        }
    }
}

/// `n` samples of a task at a context length.
pub fn task_samples(task: Task, n: usize, target_len: usize)
                    -> Vec<TaskSample> {
    (0..n).map(|i| sample(task, 1000 + i as u64 * 37, target_len)).collect()
}

/// PG19-sim: a long "book-like" byte stream for perplexity (Figure 4).
pub fn pg19_sample(seed: u64, len: usize) -> Vec<i32> {
    tokenize(&TextGen::new(0x9619 ^ seed).filler(len))
}

/// MInference-style length-adjustable latency prompt (Figures 1 & 5).
pub fn latency_prompt(len: usize) -> Vec<i32> {
    tokenize(&TextGen::new(0x1a7e).filler(len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_are_bucket_exact() {
        for (task, _) in TASK_NAMES {
            let s = sample(task, 5, 1024);
            assert_eq!(s.prompt.len(), 1024, "{:?}", task);
        }
    }

    #[test]
    fn retrieval_answer_is_planted() {
        let s = sample(Task::RetrPassKey, 9, 2048);
        let text = super::super::corpus::detokenize(&s.prompt);
        let ans = s.answer.unwrap();
        assert!(text.contains(&format!("pass key is {ans}")));
        assert!(text.ends_with("the pass key is "));
    }

    #[test]
    fn retr_kv_query_matches_a_key() {
        let s = sample(Task::RetrKV, 11, 1024);
        let text = super::super::corpus::detokenize(&s.prompt);
        let ans = s.answer.unwrap();
        assert!(text.contains(&format!("={ans}>")));
        assert!(text.contains("<GET:"));
    }

    #[test]
    fn mathfind_answer_is_max() {
        let s = sample(Task::MathFind, 3, 512);
        let text = super::super::corpus::detokenize(&s.prompt);
        let ans: u32 = s.answer.unwrap().parse().unwrap();
        // every listed value <= answer
        let vals: Vec<u32> = text
            .lines()
            .find(|l| l.starts_with("values:"))
            .unwrap()
            .split_whitespace()
            .skip(1)
            .map(|v| v.parse().unwrap())
            .collect();
        assert_eq!(vals.iter().copied().max().unwrap(), ans);
    }

    #[test]
    fn deterministic_samples() {
        let a = sample(Task::RetrKV, 42, 512);
        let b = sample(Task::RetrKV, 42, 512);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }

    #[test]
    fn latency_prompt_lengths() {
        for len in [512usize, 1024, 4096] {
            assert_eq!(latency_prompt(len).len(), len);
        }
    }
}
