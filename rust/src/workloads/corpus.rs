//! Deterministic text generator — the rust mirror of
//! `python/compile/corpus.py` (same archetypes: prose, key-value
//! retrieval, dialogue, code-ish), used to build evaluation prompts that
//! are in-distribution for the trained models.

use crate::util::rng::Rng;

pub const WORDS: &[&str] = &[
    "the", "of", "and", "to", "in", "is", "was", "he", "for", "it", "with",
    "as", "his", "on", "be", "at", "by", "had", "not", "are", "but", "from",
    "or", "have", "an", "they", "which", "one", "you", "were", "all", "her",
    "she", "there", "would", "their", "we", "him", "been", "has", "when",
    "who", "will", "no", "more", "if", "out", "so", "up", "said", "what",
    "its", "about", "than", "into", "them", "can", "only", "other", "time",
    "new", "some", "could", "these", "two", "may", "first", "then", "do",
    "any", "like", "my", "now", "over", "such", "our", "man", "me", "even",
    "most", "made", "after", "also", "did", "many", "off", "before", "must",
    "well", "back", "through", "years", "where", "much", "your", "way",
    "down", "should", "because", "each", "just", "those", "people", "how",
    "too", "good",
];

pub const NAMES: &[&str] = &[
    "alder", "birch", "cedar", "dahlia", "elm", "fern", "gingko", "hazel",
    "iris", "juniper", "kale", "lotus", "maple", "nettle", "oak", "poplar",
    "quince", "rowan", "sage", "tulip",
];

/// Stateful text generator.
pub struct TextGen {
    pub rng: Rng,
}

impl TextGen {
    pub fn new(seed: u64) -> TextGen {
        TextGen { rng: Rng::new(seed) }
    }

    pub fn prose(&mut self, n_words: usize) -> String {
        let mut out = String::new();
        let mut line = 0usize;
        for i in 0..n_words {
            let w = self.rng.choose(WORDS);
            if i > 0 {
                out.push(if line > 70 { '\n' } else { ' ' });
                if line > 70 {
                    line = 0;
                }
            }
            out.push_str(w);
            line += w.len() + 1;
            if self.rng.bool(0.08) {
                out.push('.');
            }
        }
        out
    }

    /// A key-value pair: (name, 6-digit value).
    pub fn kv_pair(&mut self) -> (String, String) {
        let name = format!("{}{}", self.rng.choose(NAMES),
                           self.rng.range(10, 99));
        let val: String = (0..6)
            .map(|_| char::from(b'0' + self.rng.below(10) as u8))
            .collect();
        (name, val)
    }

    pub fn dialogue(&mut self, turns: usize) -> String {
        const SPK: &[&str] = &["ann", "bob", "eve", "dan"];
        let mut out = String::new();
        for _ in 0..turns {
            let s = self.rng.choose(SPK);
            let n = self.rng.range(4, 12);
            out.push_str(s);
            out.push_str(": ");
            out.push_str(&self.prose(n));
            out.push('\n');
        }
        out
    }

    pub fn codeish(&mut self, stmts: usize) -> String {
        let mut out = String::new();
        let mut depth = 0usize;
        for _ in 0..stmts {
            let r = self.rng.f64();
            if r < 0.2 && depth < 3 {
                out.push_str(&"  ".repeat(depth));
                out.push_str(&format!("fn {}() {{\n", self.rng.choose(NAMES)));
                depth += 1;
            } else if r < 0.3 && depth > 0 {
                depth -= 1;
                out.push_str(&"  ".repeat(depth));
                out.push_str("}\n");
            } else {
                out.push_str(&"  ".repeat(depth));
                out.push_str(&format!("let {} = {} + {};\n",
                                      self.rng.choose(NAMES),
                                      self.rng.choose(NAMES),
                                      self.rng.choose(NAMES)));
            }
        }
        for d in (0..depth).rev() {
            out.push_str(&"  ".repeat(d));
            out.push_str("}\n");
        }
        out
    }

    /// Mixed filler text of roughly `n` bytes.
    pub fn filler(&mut self, n: usize) -> String {
        let mut out = String::new();
        while out.len() < n {
            let r = self.rng.f64();
            if r < 0.5 {
                let w = self.rng.range(30, 90);
                out.push_str(&self.prose(w));
            } else if r < 0.75 {
                let t = self.rng.range(3, 8);
                out.push_str(&self.dialogue(t));
            } else {
                let s = self.rng.range(8, 24);
                out.push_str(&self.codeish(s));
            }
            out.push('\n');
        }
        out.truncate(n);
        out
    }
}

/// Byte-level tokenization (the models are byte LMs with a 512 vocab).
pub fn tokenize(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

pub fn detokenize(tokens: &[i32]) -> String {
    tokens.iter()
        .map(|&t| if (0..256).contains(&t) { t as u8 as char } else { '?' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = TextGen::new(3).filler(500);
        let b = TextGen::new(3).filler(500);
        assert_eq!(a, b);
        assert_eq!(a.len(), 500);
    }

    #[test]
    fn tokenize_roundtrip_ascii() {
        let s = "hello, world";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn kv_pair_format() {
        let mut g = TextGen::new(1);
        let (name, val) = g.kv_pair();
        assert!(name.len() >= 5);
        assert_eq!(val.len(), 6);
        assert!(val.bytes().all(|b| b.is_ascii_digit()));
    }

    #[test]
    fn tokens_in_byte_range() {
        let toks = tokenize(&TextGen::new(9).filler(2000));
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
    }
}
