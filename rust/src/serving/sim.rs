//! Deterministic artifact-free engine for scheduler/server tests and
//! coordinator benches.
//!
//! [`SimEngine`] implements [`EngineCore`] with pure bookkeeping: a
//! prefill "layer" is a counter increment and a decode step emits
//! `prompt_len + step` as the token.  That is enough to exercise every
//! scheduling property — chunk interleaving, KV admission/re-queueing,
//! cancellation, shutdown draining — in CI, where the compiled HLO
//! artifacts (and the PJRT runtime) are unavailable.

use anyhow::{bail, Result};

use super::engine::{EngineCore, PrefillStats};
use crate::BLOCK_SIZE;

pub struct SimEngine {
    layers: usize,
    /// Prompts longer than this fail `begin_prefill`, mimicking the real
    /// engine's "exceeds max seq bucket" rejection path.
    max_prompt: usize,
    /// Simulated compute: busy-wait this many nanoseconds per prompt
    /// token per layer inside `prefill_chunk` (0 = instant).  Lets the
    /// coordinator benches measure realistic wall-clock TTFT ordering
    /// (e.g. short prompts overtaking a long prefill) without artifacts.
    ns_per_token_layer: u64,
}

pub struct SimPrefill {
    prompt_len: usize,
    layers_done: usize,
    layers_total: usize,
}

pub struct SimDecode {
    prompt_len: usize,
    produced: usize,
    max_new: usize,
    tokens: Vec<i32>,
    decode_us: u64,
}

impl SimEngine {
    pub fn new(layers: usize) -> SimEngine {
        SimEngine {
            layers: layers.max(1),
            max_prompt: usize::MAX,
            ns_per_token_layer: 0,
        }
    }

    pub fn with_max_prompt(mut self, max_prompt: usize) -> SimEngine {
        self.max_prompt = max_prompt;
        self
    }

    /// Attach simulated prefill compute (ns per prompt token per layer).
    pub fn with_work(mut self, ns_per_token_layer: u64) -> SimEngine {
        self.ns_per_token_layer = ns_per_token_layer;
        self
    }
}

impl EngineCore for SimEngine {
    type Prefill = SimPrefill;
    type Decode = SimDecode;

    fn layers_total(&self) -> usize {
        self.layers
    }

    fn begin_prefill(&mut self, tokens: &[i32]) -> Result<SimPrefill> {
        if tokens.len() > self.max_prompt {
            bail!("prompt of {} tokens exceeds max bucket {}",
                  tokens.len(), self.max_prompt);
        }
        Ok(SimPrefill {
            prompt_len: tokens.len(),
            layers_done: 0,
            layers_total: self.layers,
        })
    }

    fn prefill_chunk(&mut self, t: &mut SimPrefill, max_layers: usize)
                     -> Result<bool> {
        let before = t.layers_done;
        t.layers_done =
            (t.layers_done + max_layers.max(1)).min(t.layers_total);
        if self.ns_per_token_layer > 0 {
            let advanced = (t.layers_done - before) as u64;
            let ns = advanced * t.prompt_len as u64
                * self.ns_per_token_layer;
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < ns {
                std::hint::spin_loop();
            }
        }
        Ok(t.layers_done >= t.layers_total)
    }

    fn prefill_progress(&self, t: &SimPrefill) -> (usize, usize) {
        (t.layers_done, t.layers_total)
    }

    fn start_decode(&mut self, t: SimPrefill, max_new: usize)
                    -> Result<(SimDecode, PrefillStats)> {
        let nb = t.prompt_len.div_ceil(BLOCK_SIZE).max(1);
        let causal = nb * (nb + 1) / 2 * t.layers_total;
        let stats = PrefillStats {
            latency_us: 1,
            blocks_computed: causal.div_ceil(2),
            blocks_total: causal,
            shared: t.layers_total,
            ..Default::default()
        };
        Ok((SimDecode {
            prompt_len: t.prompt_len,
            produced: 0,
            max_new,
            tokens: Vec::new(),
            decode_us: 0,
        }, stats))
    }

    fn decode_step(&mut self, d: &mut SimDecode) -> Result<Option<i32>> {
        if d.produced >= d.max_new {
            return Ok(None);
        }
        let tok = (d.prompt_len + d.produced) as i32;
        d.produced += 1;
        d.tokens.push(tok);
        d.decode_us += 1;
        Ok(Some(tok))
    }

    fn generated<'a>(&self, d: &'a SimDecode) -> &'a [i32] {
        &d.tokens
    }

    fn decode_elapsed_us(&self, d: &SimDecode) -> u64 {
        d.decode_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_progress_and_decode() {
        let mut e = SimEngine::new(4);
        let mut t = e.begin_prefill(&[1, 2, 3]).unwrap();
        assert!(!e.prefill_chunk(&mut t, 1).unwrap());
        assert_eq!(e.prefill_progress(&t), (1, 4));
        assert!(e.prefill_chunk(&mut t, 3).unwrap());
        let (mut d, stats) = e.start_decode(t, 2).unwrap();
        assert!(stats.blocks_total > 0);
        assert_eq!(e.decode_step(&mut d).unwrap(), Some(3));
        assert_eq!(e.decode_step(&mut d).unwrap(), Some(4));
        assert_eq!(e.decode_step(&mut d).unwrap(), None);
        assert_eq!(e.generated(&d), &[3, 4]);
    }

    #[test]
    fn oversized_prompt_fails_begin() {
        let mut e = SimEngine::new(2).with_max_prompt(4);
        assert!(e.begin_prefill(&[0; 8]).is_err());
    }

    #[test]
    fn simulated_work_takes_proportional_time() {
        let mut e = SimEngine::new(2).with_work(1_000); // 1µs/token/layer
        let mut t = e.begin_prefill(&[1; 100]).unwrap();
        let t0 = std::time::Instant::now();
        assert!(!e.prefill_chunk(&mut t, 1).unwrap());
        // 1 layer × 100 tokens × 1µs = 100µs minimum
        assert!(t0.elapsed().as_micros() >= 100);
        assert!(e.prefill_chunk(&mut t, 1).unwrap());
    }
}
