//! Deterministic artifact-free engine for scheduler/server tests and
//! coordinator benches.
//!
//! [`SimEngine`] implements [`EngineCore`] with pure bookkeeping: a
//! prefill "layer" is a counter increment and a decode step emits
//! `prompt_len + step` as the token.  That is enough to exercise every
//! scheduling property — chunk interleaving, KV admission/re-queueing,
//! cancellation, shutdown draining — in CI, where the compiled HLO
//! artifacts (and the PJRT runtime) are unavailable.

use anyhow::{bail, Result};
use std::collections::HashSet;

use super::engine::{EngineCore, PatternExport, PrefillStats};
use crate::BLOCK_SIZE;

/// Fraction (percent) of the cold per-chunk compute a warm-cache
/// prefill pays in the simulation: reusing cached pivotal patterns
/// skips the dense bootstrap heads, the dominant prefill cost.
const SIM_WARM_COST_PCT: u64 = 40;

/// Serial fraction (percent) of a simulated prefill chunk — the
/// qkv/post-attn stages and kernel dispatch that stay on the engine
/// thread in the real engine.  The remaining fraction is per-head work
/// that scales with `workers` (Amdahl), so simulated prefill time
/// strictly decreases as workers grow while outputs stay identical.
const SIM_SERIAL_PCT: u64 = 20;

/// Heads the simulated engine "shards" per layer (pool accounting
/// only; SimEngine has no real heads).
const SIM_HEADS: usize = 8;

/// Fraction (percent) of normal per-chunk compute a prefill planned
/// under overload pressure pays: the scheduler's degradation ladder
/// tightens the sparse budget γ FlexPrefill-style, so pressured
/// prefills select (and compute) fewer blocks.  Snapshotted at
/// `begin_prefill`, like γ in the real engine.
const SIM_DEGRADED_COST_PCT: u64 = 60;

pub struct SimEngine {
    layers: usize,
    /// Prompts longer than this fail `begin_prefill`, mimicking the real
    /// engine's "exceeds max seq bucket" rejection path.
    max_prompt: usize,
    /// Simulated compute: busy-wait this many nanoseconds per prompt
    /// token per layer inside `prefill_chunk` (0 = instant).  Lets the
    /// coordinator benches measure realistic wall-clock TTFT ordering
    /// (e.g. short prompts overtaking a long prefill) without artifacts.
    ns_per_token_layer: u64,
    /// Simulated cross-request pattern cache: the seq buckets whose
    /// patterns a *completed* prefill already published (`None` = cache
    /// off).  Mirrors the real cache's contract: warmth is snapshotted
    /// at `begin_prefill`, publication happens only at completion, so
    /// interleaved prefills never observe half-built state and
    /// cancelled prefills never publish.
    warm_buckets: Option<HashSet<usize>>,
    /// Simulated head-parallel worker pool width.  Mirrors the real
    /// pool's contract: tokens, events and block accounting are
    /// bit-identical at every width — only the simulated per-chunk
    /// compute shrinks (Amdahl over the per-head fraction).
    workers: u64,
    /// Buckets newly warmed since the last [`EngineCore::
    /// take_pattern_exports`] drain — the fleet's cross-shard broadcast
    /// feed.  Bounded by the number of distinct buckets even if never
    /// drained; always empty with the cache off.
    fresh_buckets: Vec<usize>,
    /// Overload signal from the scheduler's degradation ladder
    /// ([`EngineCore::set_pressure`]); false outside degraded rounds.
    pressured: bool,
}

pub struct SimPrefill {
    prompt_len: usize,
    /// Prompt tokens already covered by shared prefix-cache KV blocks
    /// ([`EngineCore::begin_prefill_at`]); the simulated per-chunk cost
    /// only charges for the suffix past this point.  0 = cold.
    start: usize,
    layers_done: usize,
    layers_total: usize,
    /// Snapshotted at `begin_prefill`: this bucket was already served.
    warm: bool,
    /// Snapshotted at `begin_prefill`: planned under overload pressure
    /// (tightened γ — cheaper chunks, fewer blocks computed).
    degraded: bool,
    /// Wall-clock µs actually spent spinning in `prefill_chunk`.
    spent_us: u64,
}

pub struct SimDecode {
    prompt_len: usize,
    produced: usize,
    max_new: usize,
    tokens: Vec<i32>,
    decode_us: u64,
}

impl SimEngine {
    pub fn new(layers: usize) -> SimEngine {
        SimEngine {
            layers: layers.max(1),
            max_prompt: usize::MAX,
            ns_per_token_layer: 0,
            warm_buckets: None,
            workers: 1,
            fresh_buckets: Vec::new(),
            pressured: false,
        }
    }

    /// Simulate a head-parallel worker pool of width `n`: per-chunk
    /// compute drops to `serial + parallel/n` of the serial cost, and
    /// prefill stats report the pool usage — outputs are untouched.
    pub fn with_workers(mut self, n: usize) -> SimEngine {
        self.workers = n.max(1) as u64;
        self
    }

    pub fn with_max_prompt(mut self, max_prompt: usize) -> SimEngine {
        self.max_prompt = max_prompt;
        self
    }

    /// Attach simulated prefill compute (ns per prompt token per layer).
    pub fn with_work(mut self, ns_per_token_layer: u64) -> SimEngine {
        self.ns_per_token_layer = ns_per_token_layer;
        self
    }

    /// Enable the simulated cross-request pattern cache: repeat
    /// length-bucket traffic runs warm (reduced simulated compute,
    /// cache-hit stats), first-of-bucket requests run exactly as with
    /// the cache off.
    pub fn with_pattern_cache(mut self) -> SimEngine {
        self.warm_buckets = Some(HashSet::new());
        self
    }

    fn bucket_of(prompt_len: usize) -> usize {
        prompt_len.div_ceil(BLOCK_SIZE).max(1) * BLOCK_SIZE
    }
}

impl EngineCore for SimEngine {
    type Prefill = SimPrefill;
    type Decode = SimDecode;

    fn layers_total(&self) -> usize {
        self.layers
    }

    fn begin_prefill(&mut self, tokens: &[i32]) -> Result<SimPrefill> {
        if tokens.len() > self.max_prompt {
            bail!("prompt of {} tokens exceeds max bucket {}",
                  tokens.len(), self.max_prompt);
        }
        let warm = self.warm_buckets.as_ref()
            .is_some_and(|w| w.contains(&Self::bucket_of(tokens.len())));
        Ok(SimPrefill {
            prompt_len: tokens.len(),
            start: 0,
            layers_done: 0,
            layers_total: self.layers,
            warm,
            degraded: self.pressured,
            spent_us: 0,
        })
    }

    fn begin_prefill_at(&mut self, tokens: &[i32], start_tokens: usize)
                        -> Result<SimPrefill> {
        let mut t = self.begin_prefill(tokens)?;
        // Warm-prefix entry: only the suffix past the shared blocks
        // costs simulated compute.  `start_tokens == 0` is bit-identical
        // to a plain `begin_prefill` (the knob-off discipline).
        t.start = start_tokens.min(t.prompt_len);
        Ok(t)
    }

    fn prefill_chunk(&mut self, t: &mut SimPrefill, max_layers: usize)
                     -> Result<bool> {
        let before = t.layers_done;
        t.layers_done =
            (t.layers_done + max_layers.max(1)).min(t.layers_total);
        if self.ns_per_token_layer > 0 {
            let advanced = (t.layers_done - before) as u64;
            let mut ns = advanced * (t.prompt_len - t.start) as u64
                * self.ns_per_token_layer;
            if t.warm {
                ns = ns * SIM_WARM_COST_PCT / 100;
            }
            if t.degraded {
                ns = ns * SIM_DEGRADED_COST_PCT / 100;
            }
            // Amdahl over the per-head fraction: workers shard the
            // parallel share, the serial share is untouched
            ns = ns
                * (SIM_SERIAL_PCT + (100 - SIM_SERIAL_PCT) / self.workers)
                / 100;
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < ns {
                std::hint::spin_loop();
            }
            t.spent_us += t0.elapsed().as_micros() as u64;
        }
        Ok(t.layers_done >= t.layers_total)
    }

    fn prefill_progress(&self, t: &SimPrefill) -> (usize, usize) {
        (t.layers_done, t.layers_total)
    }

    fn start_decode(&mut self, t: SimPrefill, max_new: usize)
                    -> Result<(SimDecode, PrefillStats)> {
        let nb = t.prompt_len.div_ceil(BLOCK_SIZE).max(1);
        let causal = nb * (nb + 1) / 2 * t.layers_total;
        let cache_on = self.warm_buckets.is_some();
        // PrefillDone is the publish point, exactly as in the real
        // engine: a cancelled prefill never warms the bucket.  A bucket
        // warmed for the first time also feeds the fleet broadcast.
        if let Some(w) = self.warm_buckets.as_mut() {
            let bucket = Self::bucket_of(t.prompt_len);
            if w.insert(bucket) {
                self.fresh_buckets.push(bucket);
            }
        }
        let workers = self.workers as usize;
        let base_computed = if t.warm {
            causal.div_ceil(4)
        } else {
            causal.div_ceil(2)
        };
        let stats = PrefillStats {
            latency_us: 1 + t.spent_us,
            // warm prefills skip the pivotal bootstrap heads, so fewer
            // causal blocks are computed than on the cold path; a
            // degraded (pressure-tightened γ) prefill selects fewer
            // blocks still
            blocks_computed: if t.degraded {
                (base_computed * 2).div_ceil(3)
            } else {
                base_computed
            },
            blocks_total: causal,
            shared: t.layers_total,
            cache_hits: if t.warm { t.layers_total } else { 0 },
            cache_misses: if cache_on && !t.warm {
                t.layers_total
            } else {
                0
            },
            // one simulated fan-out of SIM_HEADS per layer; span is
            // the busiest shard — accounting only, outputs untouched
            pool_rounds: t.layers_total,
            pool_items: t.layers_total * SIM_HEADS,
            pool_span_items: t.layers_total * SIM_HEADS.div_ceil(workers),
            pool_workers: workers,
            // the scheduler overwrites both prefix fields with its
            // authoritative block accounting; this is the engine-local
            // view for engines driven without a scheduler
            prefix_tokens_skipped: t.start,
            ..Default::default()
        };
        Ok((SimDecode {
            prompt_len: t.prompt_len,
            produced: 0,
            max_new,
            tokens: Vec::new(),
            decode_us: 0,
        }, stats))
    }

    fn decode_step(&mut self, d: &mut SimDecode) -> Result<Option<i32>> {
        if d.produced >= d.max_new {
            return Ok(None);
        }
        let tok = (d.prompt_len + d.produced) as i32;
        d.produced += 1;
        d.tokens.push(tok);
        d.decode_us += 1;
        Ok(Some(tok))
    }

    fn generated<'a>(&self, d: &'a SimDecode) -> &'a [i32] {
        &d.tokens
    }

    fn decode_elapsed_us(&self, d: &SimDecode) -> u64 {
        d.decode_us
    }

    fn take_pattern_exports(&mut self) -> Vec<PatternExport> {
        // bucket-granularity gifts: no pattern payload, just "this seq
        // bucket is warm now"
        self.fresh_buckets
            .drain(..)
            .map(|bucket| PatternExport {
                origin: 0,
                seq: bucket,
                cluster: 0,
                entry: None,
            })
            .collect()
    }

    fn absorb_pattern_export(&mut self, export: &PatternExport) {
        // warm the bucket only when the cache is on; an absorbed bucket
        // is deliberately NOT re-exported (no broadcast loops)
        if let Some(w) = self.warm_buckets.as_mut() {
            w.insert(export.seq);
        }
    }

    fn set_pressure(&mut self, pressured: bool) {
        self.pressured = pressured;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_progress_and_decode() {
        let mut e = SimEngine::new(4);
        let mut t = e.begin_prefill(&[1, 2, 3]).unwrap();
        assert!(!e.prefill_chunk(&mut t, 1).unwrap());
        assert_eq!(e.prefill_progress(&t), (1, 4));
        assert!(e.prefill_chunk(&mut t, 3).unwrap());
        let (mut d, stats) = e.start_decode(t, 2).unwrap();
        assert!(stats.blocks_total > 0);
        assert_eq!(e.decode_step(&mut d).unwrap(), Some(3));
        assert_eq!(e.decode_step(&mut d).unwrap(), Some(4));
        assert_eq!(e.decode_step(&mut d).unwrap(), None);
        assert_eq!(e.generated(&d), &[3, 4]);
    }

    #[test]
    fn oversized_prompt_fails_begin() {
        let mut e = SimEngine::new(2).with_max_prompt(4);
        assert!(e.begin_prefill(&[0; 8]).is_err());
    }

    #[test]
    fn simulated_work_takes_proportional_time() {
        let mut e = SimEngine::new(2).with_work(1_000); // 1µs/token/layer
        let mut t = e.begin_prefill(&[1; 100]).unwrap();
        let t0 = std::time::Instant::now();
        assert!(!e.prefill_chunk(&mut t, 1).unwrap());
        // 1 layer × 100 tokens × 1µs = 100µs minimum
        assert!(t0.elapsed().as_micros() >= 100);
        assert!(e.prefill_chunk(&mut t, 1).unwrap());
    }

    /// One prefill through completion; returns its stats.
    fn run_one(e: &mut SimEngine, len: usize) -> PrefillStats {
        let mut t = e.begin_prefill(&vec![1; len]).unwrap();
        while !e.prefill_chunk(&mut t, 1).unwrap() {}
        let (_, stats) = e.start_decode(t, 0).unwrap();
        stats
    }

    #[test]
    fn pattern_cache_warms_repeat_buckets_only() {
        let mut e = SimEngine::new(4).with_pattern_cache();
        let cold = run_one(&mut e, 256);
        assert_eq!(cold.cache_hits, 0);
        assert_eq!(cold.cache_misses, 4, "cold request misses per layer");
        let warm = run_one(&mut e, 256);
        assert_eq!(warm.cache_hits, 4, "repeat bucket must run warm");
        assert_eq!(warm.cache_misses, 0);
        assert!(warm.blocks_computed < cold.blocks_computed,
                "warm prefill must compute fewer blocks");
        // a different length bucket is still cold
        let other = run_one(&mut e, 512);
        assert_eq!(other.cache_hits, 0);
    }

    #[test]
    fn pattern_cache_off_is_bit_identical() {
        let mut off = SimEngine::new(4);
        let mut on = SimEngine::new(4).with_pattern_cache();
        let a = run_one(&mut off, 256);
        let b = run_one(&mut on, 256); // first of its bucket: cold
        assert_eq!(a.blocks_computed, b.blocks_computed);
        assert_eq!(a.blocks_total, b.blocks_total);
        assert_eq!(a.latency_us, b.latency_us);
        assert_eq!((a.dense, a.shared, a.vslash),
                   (b.dense, b.shared, b.vslash));
        assert_eq!(b.cache_hits, 0);
    }

    #[test]
    fn cancelled_prefill_never_publishes() {
        let mut e = SimEngine::new(4).with_pattern_cache();
        // a prefill advanced but dropped before start_decode (cancel)
        let mut t = e.begin_prefill(&[1; 256]).unwrap();
        let _ = e.prefill_chunk(&mut t, 2).unwrap();
        drop(t);
        let next = run_one(&mut e, 256);
        assert_eq!(next.cache_hits, 0,
                   "cancelled prefill must not warm its bucket");
    }

    #[test]
    fn workers_change_no_output_only_accounting() {
        let mut w1 = SimEngine::new(4);
        let mut w4 = SimEngine::new(4).with_workers(4);
        // identical tokens at both widths
        let mut t1 = w1.begin_prefill(&[1; 256]).unwrap();
        while !w1.prefill_chunk(&mut t1, 1).unwrap() {}
        let (mut d1, a) = w1.start_decode(t1, 3).unwrap();
        while w1.decode_step(&mut d1).unwrap().is_some() {}
        let mut t4 = w4.begin_prefill(&[1; 256]).unwrap();
        while !w4.prefill_chunk(&mut t4, 1).unwrap() {}
        let (mut d4, b) = w4.start_decode(t4, 3).unwrap();
        while w4.decode_step(&mut d4).unwrap().is_some() {}
        assert_eq!(w1.generated(&d1), w4.generated(&d4),
                   "worker count changed decoded tokens");
        assert_eq!(a.blocks_computed, b.blocks_computed);
        assert_eq!(a.blocks_total, b.blocks_total);
        assert_eq!(a.latency_us, b.latency_us, "no simulated work: equal");
        // only the pool accounting differs
        assert_eq!(b.pool_workers, 4);
        assert_eq!(a.pool_items, b.pool_items);
        assert!(b.pool_span_items < a.pool_span_items,
                "more workers must shorten the critical path");
    }

    #[test]
    fn more_workers_spend_less_simulated_compute() {
        let mut prev = u64::MAX;
        for w in [1usize, 2, 4] {
            let mut e = SimEngine::new(4).with_work(2_000).with_workers(w);
            let s = run_one(&mut e, 256);
            assert!(s.latency_us < prev,
                    "workers {w}: {} not < {prev}", s.latency_us);
            prev = s.latency_us;
        }
    }

    #[test]
    fn exports_drain_fresh_buckets_once() {
        let mut e = SimEngine::new(4).with_pattern_cache();
        run_one(&mut e, 256);
        run_one(&mut e, 256); // repeat bucket: nothing new to export
        run_one(&mut e, 512);
        let exports = e.take_pattern_exports();
        let buckets: Vec<usize> = exports.iter().map(|x| x.seq).collect();
        assert_eq!(buckets, vec![SimEngine::bucket_of(256),
                                 SimEngine::bucket_of(512)]);
        assert!(exports.iter().all(|x| x.entry.is_none()));
        assert!(e.take_pattern_exports().is_empty(), "drain is one-shot");
        // cache off: nothing is ever exported
        let mut off = SimEngine::new(4);
        run_one(&mut off, 256);
        assert!(off.take_pattern_exports().is_empty());
    }

    #[test]
    fn absorbed_bucket_runs_warm_but_is_not_reexported() {
        let mut e = SimEngine::new(4).with_pattern_cache();
        e.absorb_pattern_export(&PatternExport {
            origin: 1,
            seq: SimEngine::bucket_of(256),
            cluster: 0,
            entry: None,
        });
        let s = run_one(&mut e, 256);
        assert_eq!(s.cache_hits, 4, "absorbed bucket must run warm");
        assert!(e.take_pattern_exports().is_empty(),
                "absorbed warmth must not broadcast again");
        // cache off: absorb is inert
        let mut off = SimEngine::new(4);
        off.absorb_pattern_export(&PatternExport {
            origin: 1, seq: 256, cluster: 0, entry: None,
        });
        let cold = off.take_pattern_exports();
        assert!(cold.is_empty());
    }

    #[test]
    fn pressure_snapshot_degrades_cost_and_blocks() {
        // pressure is snapshotted at begin_prefill (like γ in the real
        // engine): a prefill planned under pressure computes fewer
        // blocks and spends less simulated compute; releasing pressure
        // restores the exact baseline behavior
        let mut e = SimEngine::new(4).with_work(2_000);
        let normal = run_one(&mut e, 256);
        e.set_pressure(true);
        let degraded = run_one(&mut e, 256);
        assert!(degraded.blocks_computed < normal.blocks_computed,
                "tightened γ must select fewer blocks");
        assert_eq!(degraded.blocks_total, normal.blocks_total);
        assert!(degraded.latency_us < normal.latency_us,
                "degraded {} !< normal {}",
                degraded.latency_us, normal.latency_us);
        e.set_pressure(false);
        let after = run_one(&mut e, 256);
        assert_eq!(after.blocks_computed, normal.blocks_computed,
                   "pressure released: exact behavior restored");
    }

    #[test]
    fn warm_prefix_charges_only_the_suffix() {
        // same prompt, half its tokens covered by shared prefix blocks:
        // strictly cheaper simulated prefill, same decode tokens
        let mut e = SimEngine::new(4).with_work(2_000);
        let prompt = vec![7; 256];
        let mut cold = e.begin_prefill(&prompt).unwrap();
        while !e.prefill_chunk(&mut cold, 1).unwrap() {}
        let (mut dc, sc) = e.start_decode(cold, 2).unwrap();
        while e.decode_step(&mut dc).unwrap().is_some() {}
        let mut warm = e.begin_prefill_at(&prompt, 128).unwrap();
        while !e.prefill_chunk(&mut warm, 1).unwrap() {}
        let (mut dw, sw) = e.start_decode(warm, 2).unwrap();
        while e.decode_step(&mut dw).unwrap().is_some() {}
        assert!(sw.latency_us < sc.latency_us,
                "warm-prefix {} !< cold {}", sw.latency_us, sc.latency_us);
        assert_eq!(sw.prefix_tokens_skipped, 128);
        assert_eq!(sc.prefix_tokens_skipped, 0);
        assert_eq!(e.generated(&dc), e.generated(&dw),
                   "prefix reuse changed decoded tokens");
        assert_eq!(sc.blocks_computed, sw.blocks_computed,
                   "block accounting is prefix-independent");
    }

    #[test]
    fn begin_prefill_at_zero_is_bit_identical() {
        let mut a = SimEngine::new(3);
        let mut b = SimEngine::new(3);
        let ta = a.begin_prefill(&[1; 200]).unwrap();
        let tb = b.begin_prefill_at(&[1; 200], 0).unwrap();
        let (mut da, sa) = {
            let mut t = ta;
            while !a.prefill_chunk(&mut t, 1).unwrap() {}
            a.start_decode(t, 3).unwrap()
        };
        let (mut db, sb) = {
            let mut t = tb;
            while !b.prefill_chunk(&mut t, 1).unwrap() {}
            b.start_decode(t, 3).unwrap()
        };
        while a.decode_step(&mut da).unwrap().is_some() {}
        while b.decode_step(&mut db).unwrap().is_some() {}
        assert_eq!(a.generated(&da), b.generated(&db));
        assert_eq!(sa.blocks_computed, sb.blocks_computed);
        assert_eq!(sa.latency_us, sb.latency_us);
        assert_eq!(sa.prefix_tokens_skipped, sb.prefix_tokens_skipped);
    }

    #[test]
    fn warm_prefill_spends_less_simulated_compute() {
        let mut e = SimEngine::new(2).with_work(2_000).with_pattern_cache();
        let cold = run_one(&mut e, 128);
        let warm = run_one(&mut e, 128);
        assert!(warm.latency_us < cold.latency_us,
                "warm {} !< cold {}", warm.latency_us, cold.latency_us);
    }
}
