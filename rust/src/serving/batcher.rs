//! Admission queue: FIFO under a capacity bound, with token-budget batch
//! formation.  Prefill on this substrate is sequential per request (one
//! core, one PJRT stream), so "batching" groups work into scheduling
//! rounds — the unit of admission control and of the throughput metrics,
//! exactly the role continuous-batching plays in GPU servers.
//!
//! Generic over the queued item so the scheduler can queue whole
//! sessions (request + event sink + engine state) while the classic
//! request-only tests keep working.

use std::collections::VecDeque;

use super::request::Request;

/// Anything admitted under a token budget.
pub trait BatchItem {
    /// Cost in budget tokens (prompt length for requests/sessions).
    fn cost(&self) -> usize;
}

impl BatchItem for Request {
    fn cost(&self) -> usize {
        self.prompt_len()
    }
}

#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<T>,
    pub max_batch_tokens: usize,
    pub max_batch_requests: usize,
    capacity: usize,
}

impl<T: BatchItem> Batcher<T> {
    pub fn new(max_batch_tokens: usize, max_batch_requests: usize,
               capacity: usize) -> Batcher<T> {
        Batcher {
            queue: VecDeque::new(),
            max_batch_tokens,
            max_batch_requests,
            capacity,
        }
    }

    /// Enqueue; hands the item back when the queue is full so the caller
    /// can emit a terminal `Rejected` event for it.
    pub fn push(&mut self, r: T) -> Result<(), T> {
        if self.queue.len() >= self.capacity {
            return Err(r);
        }
        self.queue.push_back(r);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn front(&self) -> Option<&T> {
        self.queue.front()
    }

    pub fn front_mut(&mut self) -> Option<&mut T> {
        self.queue.front_mut()
    }

    pub fn pop_front(&mut self) -> Option<T> {
        self.queue.pop_front()
    }

    /// Remove and return the first queued item matching `pred`
    /// (cancellation of a not-yet-admitted session).
    pub fn remove_by(&mut self, pred: impl Fn(&T) -> bool) -> Option<T> {
        let idx = self.queue.iter().position(pred)?;
        self.queue.remove(idx)
    }

    /// Queued items in FIFO order (admission headroom accounting and
    /// class-priority candidate selection read the whole queue).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.queue.iter()
    }

    /// The queued item at `i` (0 = front), mutable — admission retry
    /// counters live on queued sessions.
    pub fn get_mut(&mut self, i: usize) -> Option<&mut T> {
        self.queue.get_mut(i)
    }

    /// Remove and return the item at `i` (0 = front), preserving the
    /// FIFO order of everything else — class-priority admission pulls
    /// an interactive session out of the middle of the queue.
    pub fn remove_at(&mut self, i: usize) -> Option<T> {
        self.queue.remove(i)
    }

    /// Form the next batch: FIFO order, stop at the token budget or the
    /// request cap.  The head item is always admitted even if it alone
    /// exceeds the budget (otherwise it would starve).
    pub fn next_batch(&mut self) -> Vec<T> {
        let mut batch = Vec::new();
        let mut tokens = 0usize;
        loop {
            let Some(front) = self.queue.front() else { break };
            let t = front.cost();
            let fits = batch.is_empty()
                || (tokens + t <= self.max_batch_tokens
                    && batch.len() < self.max_batch_requests);
            if !fits {
                break;
            }
            let Some(item) = self.queue.pop_front() else { break };
            tokens += t;
            batch.push(item);
            if batch.len() >= self.max_batch_requests {
                break;
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![0; len], 0)
    }

    #[test]
    fn fifo_under_budget() {
        let mut b = Batcher::new(100, 8, 16);
        for i in 0..4 {
            assert!(b.push(req(i, 40)).is_ok());
        }
        let batch = b.next_batch();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn oversized_head_still_admitted() {
        let mut b = Batcher::new(100, 8, 16);
        let _ = b.push(req(0, 500));
        let _ = b.push(req(1, 10));
        let batch = b.next_batch();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 0);
    }

    #[test]
    fn request_cap() {
        let mut b = Batcher::new(10_000, 2, 16);
        for i in 0..5 {
            let _ = b.push(req(i, 10));
        }
        assert_eq!(b.next_batch().len(), 2);
    }

    #[test]
    fn rejects_when_full_and_returns_item() {
        let mut b = Batcher::new(100, 8, 2);
        assert!(b.push(req(0, 1)).is_ok());
        assert!(b.push(req(1, 1)).is_ok());
        let back = b.push(req(2, 1));
        assert!(back.is_err());
        assert_eq!(back.unwrap_err().id, 2);
    }

    #[test]
    fn remove_by_id() {
        let mut b = Batcher::new(100, 8, 8);
        for i in 0..3 {
            let _ = b.push(req(i, 1));
        }
        let removed = b.remove_by(|r| r.id == 1).unwrap();
        assert_eq!(removed.id, 1);
        assert_eq!(b.len(), 2);
        assert!(b.remove_by(|r| r.id == 42).is_none());
    }

    #[test]
    fn indexed_access_preserves_fifo() {
        let mut b = Batcher::new(100, 8, 8);
        for i in 0..4 {
            let _ = b.push(req(i, 1));
        }
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 1, 2, 3]);
        assert_eq!(b.get_mut(2).unwrap().id, 2);
        assert!(b.get_mut(9).is_none());
        // pulling from the middle keeps everyone else in order
        let pulled = b.remove_at(1).unwrap();
        assert_eq!(pulled.id, 1);
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 2, 3]);
        assert!(b.remove_at(3).is_none());
    }

    #[test]
    fn prop_batches_respect_budget_and_fifo() {
        property("batcher budget+fifo", 100, |g: &mut Gen| {
            let budget = g.usize_in(50..400);
            let mut b = Batcher::new(budget, 8, 64);
            let n = g.usize_in(1..30);
            for i in 0..n {
                let len = g.usize_in(1..200);
                let _ = b.push(req(i as u64, len));
            }
            let mut last_id = None;
            while !b.is_empty() {
                let batch = b.next_batch();
                assert!(!batch.is_empty(), "progress guaranteed");
                let tokens: usize =
                    batch.iter().map(|r| r.prompt_len()).sum();
                if batch.len() > 1 {
                    assert!(tokens <= budget,
                            "multi-request batch over budget");
                }
                for r in &batch {
                    if let Some(l) = last_id {
                        assert!(r.id > l, "FIFO violated");
                    }
                    last_id = Some(r.id);
                }
            }
        });
    }
}
