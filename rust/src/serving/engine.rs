//! The prefill/decode engine — the executor of the paper's Algorithm 1.
//!
//! Per layer: run the qkv artifact, let the strategy decide a per-head
//! plan from lazily-computed probes, pack each head's mask into the
//! smallest budget bucket, run the budgeted L1 attention kernel per head,
//! feed dense heads' block-averaged QK maps back to the strategy (pivotal
//! construction), and finish the layer with the post-attn artifact.
//!
//! The engine also owns decode (dense attention over the KV cache via the
//! fused decode artifact) — all baselines share it, as in the paper.

use anyhow::Result;
use std::rc::Rc;

use crate::attention::pivotal::scatter_abar;
use crate::attention::BlockMask;
use crate::methods::{PatternLabel, PatternStrategy, Probes};
use crate::model::Stages;
use crate::runtime::{Registry, Tensor};
use crate::util::timer::{StageProfiler, Timer};
use crate::BLOCK_SIZE;

/// Padding token used to right-pad prompts to the seq bucket (newline in
/// the byte-level vocab — innocuous filler; evals generate bucket-exact
/// prompts so padding never affects reported scores).
pub const PAD_TOKEN: i32 = 10;

/// Outcome of one prefill.
pub struct PrefillResult {
    /// Final hidden states `[S, Dm]` (bucket-padded).
    pub hidden: Tensor,
    /// Per-layer KV caches `[Hkv, S, D]` (bucket-padded, pre-repeat).
    pub kv: Vec<(Tensor, Tensor)>,
    /// Bucket the prompt ran at.
    pub seq: usize,
    /// Real prompt length (<= seq).
    pub real_len: usize,
    pub stats: PrefillStats,
}

/// Prefill accounting (drives Figures 5/6 and the latency benches).
#[derive(Debug, Default, Clone)]
pub struct PrefillStats {
    pub latency_us: u64,
    /// Causal blocks computed vs. total across all layers/heads.
    pub blocks_computed: usize,
    pub blocks_total: usize,
    /// Pattern label counts across all layers/heads.
    pub dense: usize,
    pub shared: usize,
    pub vslash: usize,
    pub query_aware: usize,
    pub profiler: StageProfiler,
}

impl PrefillStats {
    pub fn density(&self) -> f64 {
        if self.blocks_total == 0 {
            1.0
        } else {
            self.blocks_computed as f64 / self.blocks_total as f64
        }
    }
}

/// Lazy probe provider for one layer (computes each probe at most once).
struct LayerProbes<'a> {
    stages: &'a Stages,
    seq: usize,
    q: &'a Tensor,
    k_rep: &'a Tensor,
    prof: &'a mut StageProfiler,
    ahat: Option<Tensor>,
    vslash: Option<Tensor>,
    flex: Option<Tensor>,
}

impl<'a> Probes for LayerProbes<'a> {
    fn ahat(&mut self) -> Result<&Tensor> {
        if self.ahat.is_none() {
            let qh = self.stages.last_block_q(self.q, self.seq)?;
            self.ahat = Some(self.stages.pattern_probe(
                qh, self.k_rep.clone(), self.seq, self.prof)?);
        }
        Ok(self.ahat.as_ref().unwrap())
    }

    fn vslash_map(&mut self) -> Result<&Tensor> {
        if self.vslash.is_none() {
            let qh = self.stages.last_block_q(self.q, self.seq)?;
            self.vslash = Some(self.stages.vslash_probe(
                qh, self.k_rep.clone(), self.seq, self.prof)?);
        }
        Ok(self.vslash.as_ref().unwrap())
    }

    fn flex_map(&mut self) -> Result<&Tensor> {
        if self.flex.is_none() {
            self.flex = Some(self.stages.flex_probe(
                self.q.clone(), self.k_rep.clone(), self.seq, self.prof)?);
        }
        Ok(self.flex.as_ref().unwrap())
    }
}

/// The engine: one model + one strategy.
pub struct Engine {
    pub stages: Stages,
    pub strategy: Box<dyn PatternStrategy>,
}

impl Engine {
    pub fn new(registry: Rc<Registry>, model: &str,
               strategy: Box<dyn PatternStrategy>) -> Result<Engine> {
        Ok(Engine { stages: Stages::new(registry, model)?, strategy })
    }

    /// Run prefill on a prompt. Pads to the smallest seq bucket.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<PrefillResult> {
        let timer = Timer::start();
        let spec = self.stages.spec.clone();
        let seq = spec.seq_bucket_for(tokens.len())?;
        let mut padded = tokens.to_vec();
        padded.resize(seq, PAD_TOKEN);
        let nb = seq / BLOCK_SIZE;
        let h = spec.num_heads;
        let mut stats = PrefillStats::default();
        let mut prof = StageProfiler::new();

        self.strategy.begin_request(seq);
        let mut x = self.stages.embed(&padded, seq, &mut prof)?;
        let mut kv = Vec::with_capacity(spec.num_layers);

        for layer in 0..spec.num_layers {
            let qkv = self.stages.qkv(layer, &x, seq, &mut prof)?;
            let k_rep = self.stages.repeat_kv(&qkv.k)?;
            let v_rep = self.stages.repeat_kv(&qkv.v)?;

            let plans = {
                let mut probes = LayerProbes {
                    stages: &self.stages,
                    seq,
                    q: &qkv.q,
                    k_rep: &k_rep,
                    prof: &mut prof,
                    ahat: None,
                    vslash: None,
                    flex: None,
                };
                self.strategy.plan_layer(layer, seq, h, &mut probes)?
            };
            debug_assert_eq!(plans.len(), h);

            // Per-head budgeted attention.
            let mut attn_out = vec![0f32; h * seq * spec.head_dim];
            for (head, plan) in plans.iter().enumerate() {
                let (mask_owned, budget, label) = match &plan.mask {
                    None => (BlockMask::dense(nb), nb, plan.label),
                    Some(m) => {
                        let b = spec.budget_bucket_for(seq, m.max_row());
                        (m.clone(), b, plan.label)
                    }
                };
                stats.blocks_computed += mask_owned
                    .count()
                    .min(nb * (nb + 1) / 2);
                stats.blocks_total += nb * (nb + 1) / 2;
                match label {
                    PatternLabel::Dense => stats.dense += 1,
                    PatternLabel::Shared => stats.shared += 1,
                    PatternLabel::VSlash => stats.vslash += 1,
                    PatternLabel::QueryAware => stats.query_aware += 1,
                }
                let (idx, valid) = mask_owned.pack(budget);
                let qh = self.stages.head_q(&qkv.q, head)?;
                let kh = k_rep.index_axis0(head)?;
                let vh = v_rep.index_axis0(head)?;
                let (o, abar) = self.stages.attn_head(
                    seq, budget, qh, kh, vh, idx.clone(), valid.clone(),
                    &mut prof)?;
                attn_out[head * seq * spec.head_dim
                         ..(head + 1) * seq * spec.head_dim]
                    .copy_from_slice(o.as_f32()?);
                if plan.publish {
                    let full = scatter_abar(
                        abar.as_f32()?, idx.as_i32()?, valid.as_f32()?, nb,
                        budget);
                    self.strategy.publish_abar(layer, head, nb, &full);
                }
            }
            let attn_t = Tensor::f32(vec![h, seq, spec.head_dim], attn_out);
            x = self.stages.post_attn(layer, attn_t, &x, seq, &mut prof)?;
            kv.push((qkv.k, qkv.v));
        }

        stats.latency_us = timer.elapsed_us();
        stats.profiler = prof;
        Ok(PrefillResult {
            hidden: x,
            kv,
            seq,
            real_len: tokens.len(),
            stats,
        })
    }

    /// Logits for every (bucket) position: `[S, V]`.
    pub fn logits_full(&self, pre: &PrefillResult) -> Result<Tensor> {
        let mut prof = StageProfiler::new();
        self.stages.lm_head(&pre.hidden, pre.seq, &mut prof)
    }

    /// Logits at the last *real* position: `[V]`.
    pub fn logits_last(&self, pre: &PrefillResult) -> Result<Vec<f32>> {
        let mut prof = StageProfiler::new();
        let dm = self.stages.spec.hidden;
        let hid = pre.hidden.as_f32()?;
        let row =
            &hid[(pre.real_len - 1) * dm..pre.real_len * dm];
        let x = Tensor::f32(vec![1, dm], row.to_vec());
        let out = self.stages.lm_head(&x, 1, &mut prof)?;
        Ok(out.into_f32()?)
    }

    /// Greedy decode `n` tokens after a prefill.  Dense attention over the
    /// KV cache via the fused decode artifact (all methods share this
    /// phase, as in the paper's setup).
    pub fn decode(&mut self, pre: &PrefillResult, n: usize)
                  -> Result<(Vec<i32>, u64)> {
        let timer = Timer::start();
        let spec = self.stages.spec.clone();
        let mut prof = StageProfiler::new();
        let smax = spec.max_seq;
        let (hkv, d) = (spec.num_kv_heads, spec.head_dim);
        // materialize padded caches
        let mut kcaches = Vec::new();
        let mut vcaches = Vec::new();
        for (k, v) in &pre.kv {
            let mut kc = vec![0f32; hkv * smax * d];
            let mut vc = vec![0f32; hkv * smax * d];
            let ks = k.as_f32()?;
            let vs = v.as_f32()?;
            let s = pre.seq;
            for hh in 0..hkv {
                // only the real prefix is live
                let live = pre.real_len * d;
                kc[hh * smax * d..hh * smax * d + live]
                    .copy_from_slice(&ks[hh * s * d..hh * s * d + live]);
                vc[hh * smax * d..hh * smax * d + live]
                    .copy_from_slice(&vs[hh * s * d..hh * s * d + live]);
            }
            kcaches.push(kc);
            vcaches.push(vc);
        }
        let mut out = Vec::with_capacity(n);
        let mut last = argmax(&self.logits_last(pre)?) as i32;
        out.push(last);
        let embed = self.stages.weights.embed.as_f32()?.to_vec();
        let dm = spec.hidden;
        for step in 1..n {
            let pos = (pre.real_len + step - 1) as i32;
            if pos as usize >= smax {
                break;
            }
            // embed the last token in-rust (row gather)
            let row = &embed[last as usize * dm..(last as usize + 1) * dm];
            let mut x = Tensor::f32(vec![1, dm], row.to_vec());
            for layer in 0..spec.num_layers {
                let kc = Tensor::f32(vec![hkv, smax, d],
                                     kcaches[layer].clone());
                let vc = Tensor::f32(vec![hkv, smax, d],
                                     vcaches[layer].clone());
                let (x2, k_new, v_new) = self.stages.decode_layer(
                    layer, &x, &kc, &vc, pos, &mut prof)?;
                x = x2;
                // write new kv rows into the host caches at `pos`
                let kn = k_new.as_f32()?;
                let vn = v_new.as_f32()?;
                for hh in 0..hkv {
                    let dst = hh * smax * d + pos as usize * d;
                    kcaches[layer][dst..dst + d]
                        .copy_from_slice(&kn[hh * d..(hh + 1) * d]);
                    vcaches[layer][dst..dst + d]
                        .copy_from_slice(&vn[hh * d..(hh + 1) * d]);
                }
            }
            let logits = self.stages.lm_head(&x, 1, &mut prof)?;
            last = argmax(logits.as_f32()?) as i32;
            out.push(last);
        }
        Ok((out, timer.elapsed_us()))
    }
}

/// Index of the max element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0); // first wins ties
    }

    #[test]
    fn stats_density() {
        let mut s = PrefillStats::default();
        assert_eq!(s.density(), 1.0);
        s.blocks_total = 100;
        s.blocks_computed = 25;
        assert!((s.density() - 0.25).abs() < 1e-12);
    }
}
