//! The prefill/decode engine — the executor of the paper's Algorithm 1.
//!
//! Per layer: run the qkv artifact, let the strategy decide a per-head
//! plan from lazily-computed probes, pack each head's mask into the
//! smallest budget bucket, run the budgeted L1 attention kernel per head,
//! feed dense heads' block-averaged QK maps back to the strategy (pivotal
//! construction), and finish the layer with the post-attn artifact.
//!
//! Prefill is *resumable*: [`EngineCore::begin_prefill`] returns a
//! [`PrefillTask`] that [`EngineCore::prefill_chunk`] advances layer-chunk
//! by layer-chunk, so the scheduler can interleave decode steps — and
//! prefill chunks of *other prompts* — between chunks of a long prompt
//! (continuous batching).  The
//! one-shot [`Engine::prefill`] is a thin wrapper that drains the task in
//! a single chunk — both paths execute the identical per-layer body
//! ([`Engine::prefill_layer`]), so chunked and monolithic prefill are
//! bit-identical (asserted by the integration tests).
//!
//! Decode is likewise incremental: [`Engine::begin_decode`] materializes
//! the padded KV caches once and [`EngineCore::decode_step`] emits one
//! token per call (dense attention via the fused decode artifact — all
//! baselines share this phase, as in the paper).
//!
//! Any number of prefills may be in flight per engine: strategies are
//! stateless planners, and each [`PrefillTask`] owns its request's
//! [`PatternState`] (SharePrefill's evolving pivotal dictionary), minted
//! by `begin_request` and dropped with the task.  Chunks of concurrent
//! prompts interleave without crosstalk; decode sessions carry no
//! strategy state at all.
//!
//! [`PatternState`]: crate::methods::PatternState

use anyhow::{bail, Result};
use std::cell::RefCell;
use std::rc::Rc;

use crate::attention::{pack_heads, scatter_abar_heads, BlockMask,
                       PivotalEntry};
use crate::config::{MethodConfig, MethodKind, PatternCacheConfig};
use crate::exec::WorkerPool;
use crate::methods::{build_strategy, CacheDecision, PatternCache,
                     PatternLabel, PatternState, PatternStrategy, Probes};
use crate::model::Stages;
use crate::runtime::{Registry, Tensor};
use crate::util::timer::{StageProfiler, Timer};
use crate::BLOCK_SIZE;

/// Padding token used to right-pad prompts to the seq bucket (newline in
/// the byte-level vocab — innocuous filler; evals generate bucket-exact
/// prompts so padding never affects reported scores).
pub const PAD_TOKEN: i32 = 10;

/// Outcome of one prefill.
pub struct PrefillResult {
    /// Final hidden states `[S, Dm]` (bucket-padded).
    pub hidden: Tensor,
    /// Per-layer KV caches `[Hkv, S, D]` (bucket-padded, pre-repeat).
    pub kv: Vec<(Tensor, Tensor)>,
    /// Bucket the prompt ran at.
    pub seq: usize,
    /// Real prompt length (<= seq).
    pub real_len: usize,
    pub stats: PrefillStats,
}

/// Prefill accounting (drives Figures 5/6 and the latency benches).
#[derive(Debug, Default, Clone)]
pub struct PrefillStats {
    pub latency_us: u64,
    /// Causal blocks computed vs. total across all layers/heads.
    pub blocks_computed: usize,
    pub blocks_total: usize,
    /// Pattern label counts across all layers/heads.
    pub dense: usize,
    pub shared: usize,
    pub vslash: usize,
    pub query_aware: usize,
    /// Cross-request pattern cache involvement per head (all zero when
    /// the cache is disabled): validated reuses, cold misses, and
    /// validation failures that fell back to the exact path.
    pub cache_hits: usize,
    pub cache_misses: usize,
    pub cache_rejected: usize,
    /// Prefix-cache involvement (zero when `serve.prefix_cache` is
    /// off): KV blocks adopted from the shared prefix index instead of
    /// being recomputed, and the prompt tokens those blocks covered —
    /// prefill started at the first divergent chunk.  Stamped by the
    /// scheduler at admission time, carried through `PrefillDone`.
    pub prefix_blocks_reused: usize,
    pub prefix_tokens_skipped: usize,
    /// Worker-pool usage during this prefill: fan-out rounds, items
    /// sharded across workers, and the summed busiest-shard item count
    /// per round (the critical path — `pool_items / (pool_span_items ×
    /// pool_workers)` is the count-based worker occupancy).  The
    /// counts are deterministic for a given worker count; only the
    /// span shrinks as workers grow — outputs never change.
    pub pool_rounds: usize,
    pub pool_items: usize,
    pub pool_span_items: usize,
    /// Pool width the prefill ran at (0 until the first layer runs).
    pub pool_workers: usize,
    pub profiler: StageProfiler,
}

impl PrefillStats {
    pub fn density(&self) -> f64 {
        if self.blocks_total == 0 {
            1.0
        } else {
            self.blocks_computed as f64 / self.blocks_total as f64
        }
    }
}

/// Resumable prefill state: the hidden activations, accumulated KV and
/// stats of a request part-way through its layer stack.  Advance it with
/// [`EngineCore::prefill_chunk`]; consume it with
/// [`Engine::finish_prefill`] or [`EngineCore::start_decode`].
pub struct PrefillTask {
    seq: usize,
    real_len: usize,
    layers_total: usize,
    layers_done: usize,
    x: Tensor,
    kv: Vec<(Tensor, Tensor)>,
    stats: PrefillStats,
    prof: StageProfiler,
    /// First prompt token whose KV is *not* already covered by shared
    /// prefix-cache blocks (0 = cold start).  Advisory for this
    /// artifact-backed engine — it recomputes the full stack and the
    /// scheduler keeps the retained blocks authoritative — but carried
    /// so stats and sims agree on what was skipped.
    start_offset: usize,
    /// This request's pattern state (SharePrefill's pivotal dictionary
    /// et al.) — request-scoped, so tasks of concurrent prompts can
    /// interleave on one engine without sharing patterns.
    pattern: Box<dyn PatternState>,
}

impl PrefillTask {
    /// `(layers_done, layers_total)`.
    pub fn progress(&self) -> (usize, usize) {
        (self.layers_done, self.layers_total)
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn is_done(&self) -> bool {
        self.layers_done >= self.layers_total
    }

    /// First token position whose KV must actually be computed — 0 for
    /// a cold prompt, a multiple of [`crate::BLOCK_SIZE`] when the
    /// leading chunks were adopted from the prefix cache.
    pub fn start_offset(&self) -> usize {
        self.start_offset
    }
}

/// Incremental decode state: padded per-layer KV caches plus the token
/// cursor.  One [`EngineCore::decode_step`] call emits one token; the
/// first token comes from the prefill's last-position logits (so TTFT is
/// observable the moment prefill completes).
pub struct DecodeSession {
    kcaches: Vec<Vec<f32>>,
    vcaches: Vec<Vec<f32>>,
    /// Hidden state at the last real prompt position, `[1, Dm]`
    /// (`None` for an empty prompt — the session then yields no tokens).
    last_row: Option<Tensor>,
    real_len: usize,
    max_new: usize,
    produced: usize,
    last: i32,
    tokens: Vec<i32>,
    decode_us: u64,
}

impl DecodeSession {
    pub fn generated(&self) -> &[i32] {
        &self.tokens
    }

    pub fn elapsed_us(&self) -> u64 {
        self.decode_us
    }

    pub fn is_done(&self) -> bool {
        self.produced >= self.max_new
    }
}

/// One cross-shard pattern-cache gift: a pivotal entry published by a
/// completed prefill on shard `origin`, rebroadcast by the fleet front
/// door into every peer engine's cache (see `serving::fleet`).  `entry`
/// is `None` for engines that model the cache at bucket granularity
/// (the `SimEngine`'s warm-bucket gifts carry no pattern payload).
#[derive(Debug, Clone)]
pub struct PatternExport {
    /// Shard the entry was published on (stamped by the shard loop; 0
    /// until then).
    pub origin: usize,
    /// Sequence-length bucket the entry belongs to.
    pub seq: usize,
    /// Cluster id within the bucket.
    pub cluster: usize,
    pub entry: Option<PivotalEntry>,
}

/// The engine interface the scheduler drives.  [`Engine`] is the real
/// artifact-backed implementation; [`super::sim::SimEngine`] is a
/// deterministic stand-in so scheduler/server tests and benches run
/// without compiled artifacts.
pub trait EngineCore {
    type Prefill;
    type Decode;

    /// Transformer depth (drives KV admission and chunk accounting).
    fn layers_total(&self) -> usize;

    /// Start a prefill.  The returned task owns all of its request's
    /// state (including the strategy's pattern state), so any number of
    /// tasks may be live and advanced in any interleaving.
    fn begin_prefill(&mut self, tokens: &[i32]) -> Result<Self::Prefill>;

    /// Advance up to `max_layers` layers; true when the stack is done.
    fn prefill_chunk(&mut self, t: &mut Self::Prefill, max_layers: usize)
                     -> Result<bool>;

    /// `(layers_done, layers_total)` of a task.
    fn prefill_progress(&self, t: &Self::Prefill) -> (usize, usize);

    /// Consume a finished prefill into a decode session (capped at
    /// `max_new` tokens) plus the prefill's accounting.
    fn start_decode(&mut self, t: Self::Prefill, max_new: usize)
                    -> Result<(Self::Decode, PrefillStats)>;

    /// Emit the next token; `None` when the session is exhausted.
    fn decode_step(&mut self, d: &mut Self::Decode) -> Result<Option<i32>>;

    /// Tokens generated so far.
    fn generated<'a>(&self, d: &'a Self::Decode) -> &'a [i32];

    /// Accumulated decode compute time.
    fn decode_elapsed_us(&self, d: &Self::Decode) -> u64;

    /// Drain pattern-cache entries published since the last call, for
    /// the fleet's cross-shard broadcast (`origin` is left 0 — the shard
    /// loop stamps it).  Engines without a shareable cache return
    /// nothing; the default keeps single-engine deployments zero-cost.
    fn take_pattern_exports(&mut self) -> Vec<PatternExport> {
        Vec::new()
    }

    /// Absorb a peer shard's broadcast entry into this engine's cache.
    /// Must be a no-op when the cache is off, and must never bypass
    /// validation-gated adoption: an absorbed entry is only ever a warm
    /// *candidate* — it cannot change a mask by itself.
    fn absorb_pattern_export(&mut self, export: &PatternExport) {
        let _ = export;
    }

    /// Overload signal from the scheduler's degradation ladder: `true`
    /// while the admission queue is past its pressure threshold, `false`
    /// once it drains.  Engines may trade accuracy for speed while
    /// pressured (FlexPrefill-style: tighten the sparse budget γ so
    /// prefills compute fewer blocks); the default ignores it, so
    /// engines whose γ is baked into compiled strategies stay exact.
    fn set_pressure(&mut self, pressured: bool) {
        let _ = pressured;
    }

    /// Start a prefill whose first `start_tokens` prompt tokens are
    /// already covered by retained prefix-cache KV blocks (always a
    /// multiple of the block size; the scheduler owns the block
    /// accounting).  Engines that can skip the warm prefix override
    /// this to start at the divergence point; the default ignores the
    /// offset and recomputes everything — correct, just not faster,
    /// because the shared blocks stay valid either way.
    fn begin_prefill_at(&mut self, tokens: &[i32], start_tokens: usize)
                        -> Result<Self::Prefill> {
        let _ = start_tokens;
        self.begin_prefill(tokens)
    }
}

/// Lazy probe provider for one layer (computes each probe at most once).
struct LayerProbes<'a> {
    stages: &'a Stages,
    seq: usize,
    q: &'a Tensor,
    k_rep: &'a Tensor,
    prof: &'a mut StageProfiler,
    ahat: Option<Tensor>,
    vslash: Option<Tensor>,
    flex: Option<Tensor>,
}

impl<'a> Probes for LayerProbes<'a> {
    fn ahat(&mut self) -> Result<&Tensor> {
        if self.ahat.is_none() {
            let qh = self.stages.last_block_q(self.q, self.seq)?;
            self.ahat = Some(self.stages.pattern_probe(
                qh, self.k_rep.clone(), self.seq, self.prof)?);
        }
        Ok(self.ahat.as_ref().expect("invariant: probe computed above"))
    }

    fn vslash_map(&mut self) -> Result<&Tensor> {
        if self.vslash.is_none() {
            let qh = self.stages.last_block_q(self.q, self.seq)?;
            self.vslash = Some(self.stages.vslash_probe(
                qh, self.k_rep.clone(), self.seq, self.prof)?);
        }
        Ok(self.vslash.as_ref().expect("invariant: probe computed above"))
    }

    fn flex_map(&mut self) -> Result<&Tensor> {
        if self.flex.is_none() {
            self.flex = Some(self.stages.flex_probe(
                self.q.clone(), self.k_rep.clone(), self.seq, self.prof)?);
        }
        Ok(self.flex.as_ref().expect("invariant: probe computed above"))
    }
}

/// The engine: one model + one strategy (+ the optional engine-owned
/// cross-request pattern cache the strategy shares, + the worker pool
/// per-head host work fans out on).
pub struct Engine {
    pub stages: Stages,
    pub strategy: Box<dyn PatternStrategy>,
    /// Cross-request pattern cache (None = disabled).  Lives with the
    /// engine so it spans requests; the SharePrefill strategy holds the
    /// other `Rc` and does the actual lookup/publish.  Exposed for
    /// observability (hit/eviction stats in tests and tools).
    pub pattern_cache: Option<Rc<RefCell<PatternCache>>>,
    /// Head-parallel worker pool (`serve.workers`; serial by default).
    /// The strategy holds the other `Rc` for its planning fan-outs;
    /// kernel dispatch itself stays on the engine thread (PJRT handles
    /// are not `Send`), so the pool shards only pure host-side
    /// per-head work — packing, scatter, searches, validation probes —
    /// with head-indexed slots: any width is bit-identical to serial.
    pub pool: Rc<WorkerPool>,
}

impl Engine {
    pub fn new(registry: Rc<Registry>, model: &str,
               strategy: Box<dyn PatternStrategy>) -> Result<Engine> {
        Ok(Engine {
            stages: Stages::new(registry, model)?,
            strategy,
            pattern_cache: None,
            pool: Rc::new(WorkerPool::serial()),
        })
    }

    /// Run one layer of a prefill task (the shared body of chunked and
    /// monolithic prefill).
    fn prefill_layer(&mut self, t: &mut PrefillTask) -> Result<()> {
        let layer = t.layers_done;
        let seq = t.seq;
        let spec = self.stages.spec.clone();
        let nb = seq / BLOCK_SIZE;
        let h = spec.num_heads;
        // snapshot before planning: the strategy's fan-outs (vslash
        // searches, cache-validation probes) run on the same shared
        // pool and must land in this layer's accounting too
        let pool_before = self.pool.stats();

        let qkv = self.stages.qkv(layer, &t.x, seq, &mut t.prof)?;
        let k_rep = self.stages.repeat_kv(&qkv.k)?;
        let v_rep = self.stages.repeat_kv(&qkv.v)?;

        let plans = {
            let mut probes = LayerProbes {
                stages: &self.stages,
                seq,
                q: &qkv.q,
                k_rep: &k_rep,
                prof: &mut t.prof,
                ahat: None,
                vslash: None,
                flex: None,
            };
            self.strategy.plan_layer(&mut *t.pattern, layer, seq, h,
                                     &mut probes)?
        };
        debug_assert_eq!(plans.len(), h);

        // Resolve each head's (mask, budget) and account the plan
        // stats (serial — cheap integer work whose order is part of
        // the stats contract), then pack every head's (idx, valid)
        // kernel tensors head-parallel with head-indexed slots.
        let mut resolved: Vec<(BlockMask, usize)> = Vec::with_capacity(h);
        for plan in &plans {
            let (mask, budget) = match &plan.mask {
                None => (BlockMask::dense(nb), nb),
                Some(m) => {
                    (m.clone(), spec.budget_bucket_for(seq, m.max_row()))
                }
            };
            t.stats.blocks_computed += mask.count().min(nb * (nb + 1) / 2);
            t.stats.blocks_total += nb * (nb + 1) / 2;
            match plan.label {
                PatternLabel::Dense => t.stats.dense += 1,
                PatternLabel::Shared => t.stats.shared += 1,
                PatternLabel::VSlash => t.stats.vslash += 1,
                PatternLabel::QueryAware => t.stats.query_aware += 1,
            }
            match plan.cache {
                CacheDecision::Off => {}
                CacheDecision::Hit => t.stats.cache_hits += 1,
                CacheDecision::Miss => t.stats.cache_misses += 1,
                CacheDecision::Rejected => t.stats.cache_rejected += 1,
            }
            resolved.push((mask, budget));
        }
        let pack_jobs: Vec<(&BlockMask, usize)> =
            resolved.iter().map(|(m, b)| (m, *b)).collect();
        let packed = pack_heads(&self.pool, &pack_jobs);

        // Budgeted per-head attention through the compiled kernel.
        // Dispatch stays on this thread — the PJRT handles are not
        // `Send` — while the host-side work around each call (packing
        // above, abar scatter below) is head-parallel.
        let mut attn_out = vec![0f32; h * seq * spec.head_dim];
        let mut publishes: Vec<(usize, Tensor, usize)> = Vec::new();
        for (head, plan) in plans.iter().enumerate() {
            let budget = resolved[head].1;
            let (idx, valid) = &packed[head];
            let qh = self.stages.head_q(&qkv.q, head)?;
            let kh = k_rep.index_axis0(head)?;
            let vh = v_rep.index_axis0(head)?;
            let (o, abar) = self.stages.attn_head(
                seq, budget, qh, kh, vh, idx.clone(), valid.clone(),
                &mut t.prof)?;
            attn_out[head * seq * spec.head_dim
                     ..(head + 1) * seq * spec.head_dim]
                .copy_from_slice(o.as_f32()?);
            if plan.publish {
                publishes.push((head, abar, budget));
            }
        }

        // Scatter the publishing (dense pivotal bootstrap) heads' abar
        // maps head-parallel, then hand them to the strategy serially
        // in head order — the pivotal dictionary's insertion order is
        // part of the determinism contract, so only the pure scatter
        // is sharded.
        if !publishes.is_empty() {
            let mut jobs: Vec<(&[f32], &[i32], &[f32], usize)> =
                Vec::with_capacity(publishes.len());
            for (head, abar, budget) in &publishes {
                let (idx, valid) = &packed[*head];
                jobs.push((abar.as_f32()?, idx.as_i32()?, valid.as_f32()?,
                           *budget));
            }
            let fulls = scatter_abar_heads(&self.pool, nb, &jobs);
            for ((head, _, _), full) in publishes.iter().zip(&fulls) {
                self.strategy.publish_abar(&mut *t.pattern, layer, *head,
                                           nb, full);
            }
        }

        let attn_t = Tensor::f32(vec![h, seq, spec.head_dim], attn_out);
        t.x = self.stages.post_attn(layer, attn_t, &t.x, seq, &mut t.prof)?;
        t.kv.push((qkv.k, qkv.v));
        t.layers_done += 1;
        let pool_after = self.pool.stats();
        t.stats.pool_rounds +=
            (pool_after.rounds - pool_before.rounds) as usize;
        t.stats.pool_items +=
            (pool_after.items - pool_before.items) as usize;
        t.stats.pool_span_items +=
            (pool_after.span_items - pool_before.span_items) as usize;
        t.stats.pool_workers = self.pool.workers();
        Ok(())
    }

    /// Run prefill on a prompt in one shot (drains a [`PrefillTask`]).
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<PrefillResult> {
        let mut t = self.begin_prefill(tokens)?;
        let total = t.layers_total.max(1);
        self.prefill_chunk(&mut t, total)?;
        self.finish_prefill(t)
    }

    /// Turn a completed (or to-be-completed) task into a [`PrefillResult`].
    pub fn finish_prefill(&mut self, mut t: PrefillTask)
                          -> Result<PrefillResult> {
        if t.layers_done < t.layers_total {
            let rest = t.layers_total - t.layers_done;
            self.prefill_chunk(&mut t, rest)?;
        }
        // PrefillDone: distill the request's pattern state into the
        // cross-request cache (exactly once per task — this method
        // consumes it).  A cancelled task is dropped without reaching
        // here, so only completed requests ever publish.
        self.strategy.end_request(&*t.pattern, t.seq);
        let mut stats = t.stats;
        stats.profiler = t.prof;
        Ok(PrefillResult {
            hidden: t.x,
            kv: t.kv,
            seq: t.seq,
            real_len: t.real_len,
            stats,
        })
    }

    /// Logits for every (bucket) position: `[S, V]`.
    pub fn logits_full(&self, pre: &PrefillResult) -> Result<Tensor> {
        let mut prof = StageProfiler::new();
        self.stages.lm_head(&pre.hidden, pre.seq, &mut prof)
    }

    /// Logits at the last *real* position: `[V]`.
    pub fn logits_last(&self, pre: &PrefillResult) -> Result<Vec<f32>> {
        if pre.real_len == 0 {
            bail!("logits_last on an empty prompt (real_len == 0)");
        }
        let mut prof = StageProfiler::new();
        let dm = self.stages.spec.hidden;
        let hid = pre.hidden.as_f32()?;
        let row =
            &hid[(pre.real_len - 1) * dm..pre.real_len * dm];
        let x = Tensor::f32(vec![1, dm], row.to_vec());
        let out = self.stages.lm_head(&x, 1, &mut prof)?;
        Ok(out.into_f32()?)
    }

    /// Materialize the padded KV caches of a finished prefill into an
    /// incremental decode session (capped at `max_new` tokens).
    pub fn begin_decode(&self, pre: &PrefillResult, max_new: usize)
                        -> Result<DecodeSession> {
        let spec = self.stages.spec.clone();
        let smax = spec.max_seq;
        let (hkv, d) = (spec.num_kv_heads, spec.head_dim);
        let mut kcaches = Vec::new();
        let mut vcaches = Vec::new();
        for (k, v) in &pre.kv {
            let mut kc = vec![0f32; hkv * smax * d];
            let mut vc = vec![0f32; hkv * smax * d];
            let ks = k.as_f32()?;
            let vs = v.as_f32()?;
            let s = pre.seq;
            for hh in 0..hkv {
                // only the real prefix is live
                let live = pre.real_len * d;
                kc[hh * smax * d..hh * smax * d + live]
                    .copy_from_slice(&ks[hh * s * d..hh * s * d + live]);
                vc[hh * smax * d..hh * smax * d + live]
                    .copy_from_slice(&vs[hh * s * d..hh * s * d + live]);
            }
            kcaches.push(kc);
            vcaches.push(vc);
        }
        let last_row = if pre.real_len == 0 {
            None
        } else {
            let dm = spec.hidden;
            let hid = pre.hidden.as_f32()?;
            let row = &hid[(pre.real_len - 1) * dm..pre.real_len * dm];
            Some(Tensor::f32(vec![1, dm], row.to_vec()))
        };
        Ok(DecodeSession {
            kcaches,
            vcaches,
            last_row,
            real_len: pre.real_len,
            max_new,
            produced: 0,
            last: 0,
            tokens: Vec::new(),
            decode_us: 0,
        })
    }

    /// Greedy decode `n` tokens after a prefill in one blocking call (the
    /// compatibility path evals use; drives [`EngineCore::decode_step`]).
    pub fn decode(&mut self, pre: &PrefillResult, n: usize)
                  -> Result<(Vec<i32>, u64)> {
        let mut d = self.begin_decode(pre, n)?;
        while self.decode_step(&mut d)?.is_some() {}
        Ok((d.tokens, d.decode_us))
    }
}

impl EngineCore for Engine {
    type Prefill = PrefillTask;
    type Decode = DecodeSession;

    fn layers_total(&self) -> usize {
        self.stages.spec.num_layers
    }

    fn begin_prefill(&mut self, tokens: &[i32]) -> Result<PrefillTask> {
        let timer = Timer::start();
        let spec = self.stages.spec.clone();
        let seq = spec.seq_bucket_for(tokens.len())?;
        let mut padded = tokens.to_vec();
        padded.resize(seq, PAD_TOKEN);
        let mut stats = PrefillStats::default();
        let mut prof = StageProfiler::new();
        let pattern = self.strategy.begin_request(seq);
        let x = self.stages.embed(&padded, seq, &mut prof)?;
        stats.latency_us = timer.elapsed_us();
        Ok(PrefillTask {
            seq,
            real_len: tokens.len(),
            layers_total: spec.num_layers,
            layers_done: 0,
            x,
            kv: Vec::with_capacity(spec.num_layers),
            stats,
            prof,
            start_offset: 0,
            pattern,
        })
    }

    fn begin_prefill_at(&mut self, tokens: &[i32], start_tokens: usize)
                        -> Result<PrefillTask> {
        let mut t = self.begin_prefill(tokens)?;
        // Advisory here: the artifact-backed stack recomputes the full
        // prompt (the retained shared blocks are already correct), but
        // the offset rides along so stats stay truthful.
        t.start_offset = start_tokens.min(t.real_len);
        t.stats.prefix_tokens_skipped = t.start_offset;
        Ok(t)
    }

    fn prefill_chunk(&mut self, t: &mut PrefillTask, max_layers: usize)
                     -> Result<bool> {
        let timer = Timer::start();
        let end = (t.layers_done + max_layers.max(1)).min(t.layers_total);
        while t.layers_done < end {
            self.prefill_layer(t)?;
        }
        t.stats.latency_us += timer.elapsed_us();
        Ok(t.layers_done >= t.layers_total)
    }

    fn prefill_progress(&self, t: &PrefillTask) -> (usize, usize) {
        t.progress()
    }

    fn start_decode(&mut self, t: PrefillTask, max_new: usize)
                    -> Result<(DecodeSession, PrefillStats)> {
        let pre = self.finish_prefill(t)?;
        let stats = pre.stats.clone();
        Ok((self.begin_decode(&pre, max_new)?, stats))
    }

    fn decode_step(&mut self, d: &mut DecodeSession) -> Result<Option<i32>> {
        if d.produced >= d.max_new {
            return Ok(None);
        }
        let timer = Timer::start();
        let spec = self.stages.spec.clone();
        let mut prof = StageProfiler::new();
        let tok = if d.produced == 0 {
            // First token: argmax over the prefill's last-position logits.
            let Some(row) = d.last_row.clone() else {
                return Ok(None); // empty prompt: nothing to condition on
            };
            let out = self.stages.lm_head(&row, 1, &mut prof)?;
            argmax(out.as_f32()?) as i32
        } else {
            let pos = (d.real_len + d.produced - 1) as i32;
            if pos as usize >= spec.max_seq {
                return Ok(None); // KV cache exhausted
            }
            let smax = spec.max_seq;
            let (hkv, hd) = (spec.num_kv_heads, spec.head_dim);
            let dm = spec.hidden;
            // embed the last token in-rust (row gather)
            let embed = self.stages.weights.embed.as_f32()?;
            let row =
                &embed[d.last as usize * dm..(d.last as usize + 1) * dm];
            let mut x = Tensor::f32(vec![1, dm], row.to_vec());
            for layer in 0..spec.num_layers {
                let kc = Tensor::f32(vec![hkv, smax, hd],
                                     d.kcaches[layer].clone());
                let vc = Tensor::f32(vec![hkv, smax, hd],
                                     d.vcaches[layer].clone());
                let (x2, k_new, v_new) = self.stages.decode_layer(
                    layer, &x, &kc, &vc, pos, &mut prof)?;
                x = x2;
                // write new kv rows into the host caches at `pos`
                let kn = k_new.as_f32()?;
                let vn = v_new.as_f32()?;
                for hh in 0..hkv {
                    let dst = hh * smax * hd + pos as usize * hd;
                    d.kcaches[layer][dst..dst + hd]
                        .copy_from_slice(&kn[hh * hd..(hh + 1) * hd]);
                    d.vcaches[layer][dst..dst + hd]
                        .copy_from_slice(&vn[hh * hd..(hh + 1) * hd]);
                }
            }
            let logits = self.stages.lm_head(&x, 1, &mut prof)?;
            argmax(logits.as_f32()?) as i32
        };
        d.last = tok;
        d.tokens.push(tok);
        d.produced += 1;
        d.decode_us += timer.elapsed_us();
        Ok(Some(tok))
    }

    fn generated<'a>(&self, d: &'a DecodeSession) -> &'a [i32] {
        &d.tokens
    }

    fn decode_elapsed_us(&self, d: &DecodeSession) -> u64 {
        d.decode_us
    }

    fn take_pattern_exports(&mut self) -> Vec<PatternExport> {
        let Some(cache) = &self.pattern_cache else {
            return Vec::new();
        };
        cache
            .borrow_mut()
            .take_broadcast()
            .into_iter()
            .map(|(seq, cluster, entry)| PatternExport {
                origin: 0,
                seq,
                cluster,
                entry: Some(entry),
            })
            .collect()
    }

    fn absorb_pattern_export(&mut self, export: &PatternExport) {
        if let (Some(cache), Some(entry)) =
            (&self.pattern_cache, &export.entry)
        {
            cache.borrow_mut().absorb_remote(
                export.seq, export.cluster, entry.clone(), export.origin);
        }
    }
}

/// Builder-style engine construction: the one typed entry point wiring
/// registry + model + method config (incl. the offline cluster table)
/// into an [`Engine`].  `eval::build_engine` and `ServerBuilder` both
/// funnel through here.
pub struct EngineBuilder {
    registry: Rc<Registry>,
    model: String,
    method: MethodConfig,
    pattern_cache: PatternCacheConfig,
    workers: usize,
}

impl EngineBuilder {
    pub fn new(registry: Rc<Registry>, model: &str) -> EngineBuilder {
        EngineBuilder {
            registry,
            model: model.to_string(),
            method: MethodConfig::default(),
            pattern_cache: PatternCacheConfig::default(),
            workers: 1,
        }
    }

    /// Replace the whole method config (τ, δ, γ, cluster path, kind).
    pub fn method_config(mut self, m: MethodConfig) -> EngineBuilder {
        self.method = m;
        self
    }

    /// Override just the method kind.
    pub fn method(mut self, kind: MethodKind) -> EngineBuilder {
        self.method.kind = kind;
        self
    }

    /// Cross-request pattern cache knobs (`serve.pattern_cache`);
    /// disabled by default, consumed only by SharePrefill.
    pub fn pattern_cache(mut self, cfg: PatternCacheConfig)
                         -> EngineBuilder {
        self.pattern_cache = cfg;
        self
    }

    /// Head-parallel worker count (`serve.workers`); 1 (the default)
    /// is the serial path, and any `N` is bit-identical to it.
    pub fn workers(mut self, n: usize) -> EngineBuilder {
        self.workers = n.max(1);
        self
    }

    pub fn build(self) -> Result<Engine> {
        let spec = self.registry.model(&self.model)?.clone();
        let clusters = if self.method.kind == MethodKind::SharePrefill {
            let path = match &self.method.clusters_file {
                Some(p) => p.clone(),
                None => self.registry.dir.join(
                    format!("head_clusters-{}.json", self.model)),
            };
            match crate::clustering::load_clusters(&path) {
                Ok(hc) => Some(hc.assignment),
                Err(_) => None, // fall back to positional clusters
            }
        } else {
            None
        };
        let cache = if self.method.kind == MethodKind::SharePrefill
            && self.pattern_cache.enabled {
            Some(Rc::new(RefCell::new(
                PatternCache::new(self.pattern_cache.clone()))))
        } else {
            None
        };
        let pool = Rc::new(WorkerPool::new(self.workers));
        let strategy = build_strategy(&self.method, spec.num_layers,
                                      spec.num_heads, clusters,
                                      cache.clone(), pool.clone());
        let mut engine = Engine::new(self.registry, &self.model, strategy)?;
        engine.pattern_cache = cache;
        engine.pool = pool;
        Ok(engine)
    }
}

/// Index of the max element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0); // first wins ties
    }

    #[test]
    fn stats_density() {
        let mut s = PrefillStats::default();
        assert_eq!(s.density(), 1.0);
        s.blocks_total = 100;
        s.blocks_computed = 25;
        assert!((s.density() - 0.25).abs() < 1e-12);
    }
}
