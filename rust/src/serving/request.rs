//! Request / response types flowing through the serving stack.
//! (Lifecycle state and streamed events live in [`super::session`].)

use std::time::Instant;

pub type RequestId = u64;

/// An inference request: prompt tokens + generation length.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: RequestId, tokens: Vec<i32>, max_new_tokens: usize)
               -> Request {
        Request { id, tokens, max_new_tokens, arrived: Instant::now() }
    }

    pub fn prompt_len(&self) -> usize {
        self.tokens.len()
    }
}

/// Summary of a completed request (also carried by `Event::Done`).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub generated: Vec<i32>,
    pub prefill_us: u64,
    pub decode_us: u64,
    /// Time spent queued before prefill started.
    pub queue_us: u64,
    /// Arrival → first token (the serving-latency headline metric).
    pub ttft_us: u64,
    /// Fraction of causal blocks actually computed during prefill.
    pub density: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_basics() {
        let r = Request::new(7, vec![1, 2, 3], 4);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt_len(), 3);
        assert_eq!(r.max_new_tokens, 4);
    }
}
