//! The serving coordinator: request/response types, dynamic batcher,
//! paged KV-cache accounting, the prefill/decode engine (the executor of
//! the paper's Algorithm 1), the scheduler gluing them together, metrics,
//! and the thread+channel server front-end.

pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use engine::{Engine, PrefillResult};
pub use request::{Request, RequestId, Response};
