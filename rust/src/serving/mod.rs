//! The serving coordinator: session/event types, admission queue, paged
//! KV-cache accounting, the chunk-resumable prefill/decode engine (the
//! executor of the paper's Algorithm 1), the continuous-batching
//! scheduler gluing them together, metrics, the thread+channel server
//! front-end with its streaming session API, and the sharded engine
//! fleet (router + supervision) that multiplexes N such engines behind
//! one front door.

pub mod batcher;
pub mod engine;
pub mod fleet;
pub mod kvcache;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod sim;

pub use engine::{DecodeSession, Engine, EngineBuilder, EngineCore,
                 PatternExport, PrefillResult, PrefillStats, PrefillTask};
pub use fleet::{spawn_fleet, FleetHandle, FleetRouter};
pub use kvcache::{BlockId, KvAllocator, PrefixIndex};
pub use request::{Request, RequestId, Response};
pub use scheduler::Scheduler;
pub use server::{ServerBuilder, ServerHandle};
pub use session::{Event, EventSink, RejectReason, SessionHandle,
                  SessionState};
