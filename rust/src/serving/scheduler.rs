//! Scheduler: continuous batching over sessions.
//!
//! Each [`Scheduler::run_round`] spends a shared token budget
//! (`serve.max_batch_tokens`) across the live sessions: every decoding
//! session advances one token per pass (a decode step costs 1 budget
//! token) and the single active prefill advances one layer-chunk (a
//! chunk costs its share of the prompt's tokens, `ceil(prompt / chunks)`)
//! — so a 32K prompt no longer stalls every decode in flight; decode
//! steps run *between* its prefill chunks.
//!
//! At most one prefill is in flight at a time because pattern strategies
//! keep per-request state (SharePrefill's pivotal dictionary, reset by
//! `begin_request`); decode sessions carry no strategy state and batch
//! freely.  The active prefill is guaranteed at least one chunk per
//! round even when the budget is smaller than its chunk cost (no
//! head-of-line starvation), mirroring the batcher's oversized-head rule.
//!
//! Admission is KV-first: a session needs its whole-lifetime block count
//! up front (vLLM-style).  When the allocator is exhausted the head of
//! the queue *waits* and retries next round (bounded by
//! `serve.admit_retries`); only after the retry budget is spent does it
//! get a terminal `Rejected` event — clients never hang.

use anyhow::Result;

use crate::config::ServeConfig;

use super::batcher::{BatchItem, Batcher};
use super::engine::{EngineCore, PrefillStats};
use super::kvcache::{BlockId, KvAllocator};
use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use super::session::{Event, EventSink, SessionState};

/// One in-flight request: the immutable submission, its event stream,
/// its KV reservation, and whichever engine state its phase carries.
struct Session<E: EngineCore> {
    req: Request,
    sink: EventSink,
    state: SessionState,
    blocks: Vec<BlockId>,
    admit_attempts: usize,
    prefill: Option<E::Prefill>,
    decode: Option<E::Decode>,
    stats: Option<PrefillStats>,
    queue_us: u64,
    ttft_us: Option<u64>,
    emitted: usize,
}

impl<E: EngineCore> BatchItem for Session<E> {
    fn cost(&self) -> usize {
        self.req.prompt_len()
    }
}

pub struct Scheduler<E: EngineCore> {
    queue: Batcher<Session<E>>,
    prefilling: Option<Session<E>>,
    decoding: Vec<Session<E>>,
    pub kv: KvAllocator,
    pub metrics: Metrics,
    decode_tokens: usize,
    chunk_layers: usize,
    round_budget: usize,
    max_active: usize,
    admit_retries: usize,
}

impl<E: EngineCore> Scheduler<E> {
    pub fn new(cfg: &ServeConfig) -> Scheduler<E> {
        Scheduler {
            queue: Batcher::new(cfg.max_batch_tokens,
                                cfg.max_batch_requests,
                                cfg.queue_capacity),
            prefilling: None,
            decoding: Vec::new(),
            kv: KvAllocator::new(cfg.kv_blocks),
            metrics: Metrics::new(),
            decode_tokens: cfg.decode_tokens,
            chunk_layers: cfg.chunk_layers.max(1),
            round_budget: cfg.max_batch_tokens.max(1),
            max_active: cfg.max_batch_requests.max(1),
            admit_retries: cfg.admit_retries,
        }
    }

    /// Submit a request with its event sink; false = queue full (the
    /// session still receives a terminal `Rejected` event).
    pub fn submit(&mut self, r: Request, sink: EventSink) -> bool {
        let s = Session {
            req: r,
            sink,
            state: SessionState::Queued,
            blocks: Vec::new(),
            admit_attempts: 0,
            prefill: None,
            decode: None,
            stats: None,
            queue_us: 0,
            ttft_us: None,
            emitted: 0,
        };
        match self.queue.push(s) {
            Ok(()) => true,
            Err(s) => {
                self.metrics.requests_rejected += 1;
                s.sink.send(Event::Rejected {
                    id: s.req.id,
                    reason: "queue full".to_string(),
                });
                false
            }
        }
    }

    /// Queued (not yet admitted) sessions.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admitted sessions currently prefilling or decoding.
    pub fn active(&self) -> usize {
        self.decoding.len() + usize::from(self.prefilling.is_some())
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.prefilling.is_some()
            || !self.decoding.is_empty()
    }

    /// Cancel a session in any non-terminal phase.  Frees its KV blocks
    /// and emits the terminal `Cancelled` event; false if unknown.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(s) = self.queue.remove_by(|s| s.req.id == id) {
            self.cancel_session(s);
            return true;
        }
        if self.prefilling.as_ref().map_or(false, |s| s.req.id == id) {
            let s = self.prefilling.take().unwrap();
            self.cancel_session(s);
            return true;
        }
        if let Some(i) = self.decoding.iter().position(|s| s.req.id == id) {
            let s = self.decoding.swap_remove(i);
            self.cancel_session(s);
            return true;
        }
        false
    }

    fn cancel_session(&mut self, mut s: Session<E>) {
        self.release_blocks(&mut s);
        s.state = SessionState::Cancelled;
        self.metrics.requests_cancelled += 1;
        s.sink.send(Event::Cancelled { id: s.req.id });
    }

    fn reject(&mut self, mut s: Session<E>, reason: &str) {
        self.release_blocks(&mut s);
        s.state = SessionState::Rejected;
        self.metrics.requests_rejected += 1;
        s.sink.send(Event::Rejected {
            id: s.req.id,
            reason: reason.to_string(),
        });
    }

    fn release_blocks(&mut self, s: &mut Session<E>) {
        if !s.blocks.is_empty() {
            // blocks are only ever handed out by this scheduler, so a
            // release can only fail on an internal invariant violation
            self.kv.release(&s.blocks).expect("kv release");
            s.blocks.clear();
        }
    }

    /// Terminal `Error` for one session the engine failed on (its KV
    /// reservation must not leak with it).
    fn fail_session(&mut self, mut s: Session<E>, message: &str) {
        self.release_blocks(&mut s);
        s.sink.send(Event::Error {
            id: s.req.id,
            message: message.to_string(),
        });
    }

    /// Fail every live session with a terminal `Error` event (engine
    /// died); the scheduler stays usable for accounting afterwards.
    pub fn fail_all(&mut self, message: &str) {
        let mut all: Vec<Session<E>> = Vec::new();
        while let Some(s) = self.queue.pop_front() {
            all.push(s);
        }
        if let Some(s) = self.prefilling.take() {
            all.push(s);
        }
        all.append(&mut self.decoding);
        for mut s in all {
            self.release_blocks(&mut s);
            s.sink.send(Event::Error {
                id: s.req.id,
                message: message.to_string(),
            });
        }
    }

    /// Try to start the next queued prefill(s).  `count_retry` marks the
    /// once-per-round admission attempt that burns a KV retry.
    fn admit(&mut self, engine: &mut E, count_retry: bool) -> Result<()> {
        while self.prefilling.is_none() {
            if self.active() >= self.max_active {
                return Ok(());
            }
            let Some(front) = self.queue.front() else { return Ok(()) };
            if front.req.prompt_len() == 0 {
                let s = self.queue.pop_front().unwrap();
                self.reject(s, "empty prompt");
                continue;
            }
            let need = KvAllocator::blocks_needed(
                front.req.prompt_len(), self.decode_tokens,
                engine.layers_total());
            if !self.kv.can_alloc(need) {
                if count_retry {
                    let f = self.queue.front_mut().unwrap();
                    f.admit_attempts += 1;
                    if f.admit_attempts > self.admit_retries {
                        let s = self.queue.pop_front().unwrap();
                        self.reject(s, &format!(
                            "kv cache exhausted: {need} blocks unavailable \
                             after {} rounds", self.admit_retries));
                        continue; // the next queued session may be smaller
                    }
                }
                return Ok(()); // head of line waits; FIFO preserved
            }
            let mut s = self.queue.pop_front().unwrap();
            match engine.begin_prefill(&s.req.tokens) {
                Ok(task) => {
                    s.blocks = self.kv.alloc(need)?;
                    s.queue_us = s.req.arrived.elapsed().as_micros() as u64;
                    s.state = SessionState::Prefilling;
                    s.prefill = Some(task);
                    self.prefilling = Some(s);
                }
                Err(e) => {
                    // per-request failure (e.g. prompt exceeds the max
                    // seq bucket) must not take the server down
                    self.reject(s, &format!("{e:#}"));
                }
            }
        }
        Ok(())
    }

    /// Budget cost of one prefill chunk: the prompt's tokens spread
    /// evenly over its chunks.
    fn chunk_cost(&self, engine: &E, s: &Session<E>) -> usize {
        let chunks = engine.layers_total().max(1)
            .div_ceil(self.chunk_layers);
        s.req.prompt_len().div_ceil(chunks.max(1)).max(1)
    }

    /// Run one scheduling round. Returns sessions completed this round.
    pub fn run_round(&mut self, engine: &mut E) -> Result<Vec<Response>> {
        let mut completed = Vec::new();
        self.admit(engine, true)?;
        let mut budget = self.round_budget;
        let mut prefill_ran = false;
        loop {
            let mut progressed = false;

            // Decode pass: one token per live session (latency first).
            let mut i = 0;
            while i < self.decoding.len() {
                if budget == 0 {
                    break;
                }
                let s = &mut self.decoding[i];
                match engine.decode_step(s.decode.as_mut().unwrap())? {
                    Some(tok) => {
                        budget -= 1;
                        if s.ttft_us.is_none() {
                            s.ttft_us = Some(
                                s.req.arrived.elapsed().as_micros() as u64);
                        }
                        let index = s.emitted;
                        s.emitted += 1;
                        s.sink.send(Event::Token {
                            id: s.req.id, token: tok, index,
                        });
                        progressed = true;
                        i += 1;
                    }
                    None => {
                        let s = self.decoding.swap_remove(i);
                        completed.push(self.finish(engine, s));
                        progressed = true;
                    }
                }
            }

            // One prefill chunk.  The active prefill always gets at
            // least one chunk per round, even over budget (no
            // starvation under a small budget).
            if let Some(mut s) = self.prefilling.take() {
                let cost = self.chunk_cost(engine, &s);
                if budget >= cost || !prefill_ran {
                    budget = budget.saturating_sub(cost);
                    prefill_ran = true;
                    progressed = true;
                    // engine errors here must not drop the taken session
                    // on the floor: its KV blocks and terminal event
                    // would leak with it (fail_all can't see it)
                    let step = engine.prefill_chunk(
                        s.prefill.as_mut().unwrap(), self.chunk_layers);
                    let done = match step {
                        Ok(d) => d,
                        Err(e) => {
                            self.fail_session(s, &format!("{e:#}"));
                            return Err(e);
                        }
                    };
                    let task = s.prefill.as_mut().unwrap();
                    let (ld, lt) = engine.prefill_progress(task);
                    s.sink.send(Event::PrefillProgress {
                        id: s.req.id,
                        layers_done: ld,
                        layers_total: lt,
                    });
                    if done {
                        let task = s.prefill.take().unwrap();
                        let max_new = s.req.max_new_tokens
                            .min(self.decode_tokens.max(1));
                        let (dec, stats) =
                            match engine.start_decode(task, max_new) {
                                Ok(x) => x,
                                Err(e) => {
                                    self.fail_session(s, &format!("{e:#}"));
                                    return Err(e);
                                }
                            };
                        self.metrics.record_prefill(&stats);
                        self.metrics.prompt_tokens +=
                            s.req.prompt_len() as u64;
                        s.sink.send(Event::PrefillDone {
                            id: s.req.id,
                            stats: stats.clone(),
                        });
                        s.stats = Some(stats);
                        s.state = SessionState::Decoding;
                        s.decode = Some(dec);
                        self.decoding.push(s);
                        // the engine is free: pull in the next prefill
                        self.admit(engine, false)?;
                    } else {
                        self.prefilling = Some(s);
                    }
                } else {
                    self.prefilling = Some(s);
                }
            }

            if !progressed || budget == 0 {
                break;
            }
        }
        Ok(completed)
    }

    /// Retire a decoded-out session: release KV, record metrics, emit
    /// the terminal `Done` event.
    fn finish(&mut self, engine: &E, mut s: Session<E>) -> Response {
        self.release_blocks(&mut s);
        let d = s.decode.take().unwrap();
        let generated = engine.generated(&d).to_vec();
        let decode_us = engine.decode_elapsed_us(&d);
        let stats = s.stats.take().unwrap_or_default();
        // no tokens requested → first "result" is prefill completion
        let ttft_us = s.ttft_us.unwrap_or_else(|| {
            s.req.arrived.elapsed().as_micros() as u64
        });
        self.metrics.decode_us.record_us(decode_us);
        self.metrics.queue_us.record_us(s.queue_us);
        self.metrics.ttft_us.record_us(ttft_us);
        self.metrics.generated_tokens += generated.len() as u64;
        self.metrics.requests_completed += 1;
        let response = Response {
            id: s.req.id,
            generated,
            prefill_us: stats.latency_us,
            decode_us,
            queue_us: s.queue_us,
            ttft_us,
            density: stats.density(),
        };
        s.state = SessionState::Done;
        s.sink.send(Event::Done {
            id: s.req.id,
            response: response.clone(),
        });
        response
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::serving::sim::SimEngine;

    #[test]
    fn submit_reject_accounting() {
        let cfg = ServeConfig { queue_capacity: 1, ..Default::default() };
        let mut s: Scheduler<SimEngine> = Scheduler::new(&cfg);
        assert!(s.submit(Request::new(0, vec![0; 8], 0),
                         EventSink::null()));
        assert!(!s.submit(Request::new(1, vec![0; 8], 0),
                          EventSink::null()));
        assert_eq!(s.metrics.requests_rejected, 1);
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn round_completes_sessions_and_frees_kv() {
        let cfg = ServeConfig::default();
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        for i in 0..3 {
            assert!(sched.submit(Request::new(i, vec![7; 64], 2),
                                 sink.clone()));
        }
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.run_round(&mut engine).unwrap());
        }
        drop(sink);
        assert_eq!(done.len(), 3);
        assert_eq!(sched.metrics.requests_completed, 3);
        assert_eq!(sched.kv.used(), 0, "all kv blocks released");
        for r in &done {
            assert_eq!(r.generated.len(), 2);
        }
        let events: Vec<Event> = rx.iter().collect();
        let dones = events.iter()
            .filter(|e| matches!(e, Event::Done { .. }))
            .count();
        assert_eq!(dones, 3);
    }

    #[test]
    fn cancel_unknown_id_is_false() {
        let cfg = ServeConfig::default();
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        assert!(!sched.cancel(99));
    }
}
