//! Scheduler: continuous batching over sessions.
//!
//! Each [`Scheduler::run_round`] spends a shared token budget
//! (`serve.max_batch_tokens`) across the live sessions: every decoding
//! session advances one token per pass (a decode step costs 1 budget
//! token) and up to `serve.max_concurrent_prefills` live prefills each
//! advance one layer-chunk per pass (a chunk costs its share of the
//! prompt's tokens, `ceil(prompt / chunks)`) — so a 32K prompt stalls
//! neither the decodes in flight nor the short prompts queued behind it.
//!
//! Multiple prefills can interleave because pattern strategies are
//! stateless planners: each `PrefillTask` owns its request's
//! [`PatternState`] (SharePrefill's pivotal dictionary), so chunks of
//! different prompts never share or clobber pattern state.
//!
//! **Fairness policy: shortest-remaining-work first.**  Within each
//! round's budgeted prefill pass, live prefills run in ascending order
//! of remaining budget cost (chunks left × per-chunk cost, ties by
//! submission id), so a freshly admitted short prompt overtakes a long
//! prompt mid-prefill and its TTFT stops paying for the 100k-token
//! request ahead of it.  A chunk that exceeds the *remaining* budget
//! never runs in that pass; instead, at round end one *budget-exempt*
//! chunk goes to the longest-starved prefill that got no budgeted
//! chunk.  This keeps a mega-chunk from crowding out everyone else's
//! within-budget work, prevents deterministic starvation (e.g. two
//! equal-cost chunks under a budget that fits only one — the SRF
//! tie-break would otherwise skip the same prompt every round), and
//! bounds any prefill's wait to (live skipped prefills − 1) rounds.
//! The cost: a round's prefill spend may overshoot `max_batch_tokens`
//! by at most that one chunk.  With `max_concurrent_prefills = 1` this
//! reproduces the old "active prefill always gets ≥ 1 chunk per round"
//! rule chunk-for-chunk (decode steps now additionally use budget the
//! old code discarded when the chunk overshot the round).
//!
//! Admission is KV-first: a session needs its whole-lifetime block count
//! up front (vLLM-style).  When the allocator is exhausted the head of
//! the queue *waits* and retries next round (bounded by
//! `serve.admit_retries`); only after the retry budget is spent does it
//! get a terminal `Rejected` event — clients never hang, and the
//! [`RejectReason`] tells them whether the condition was transient.
//!
//! The cross-request pattern cache needs nothing scheduler-specific to
//! stay safe under interleaved prefills: warm candidates are
//! snapshotted per request inside `begin_prefill` and publication
//! happens inside `start_decode` (the `PrefillDone` moment), both of
//! which this scheduler already serializes through the single engine.
//! Cancelled sessions drop their `PrefillTask` without reaching
//! `start_decode`, so a half-done prefill never publishes.  Per-head
//! cache outcomes ride `PrefillStats` into [`Metrics`]
//! (hit/miss/invalidation rates in the report).
//!
//! [`PatternState`]: crate::methods::PatternState

use anyhow::Result;

use crate::config::ServeConfig;

use super::batcher::{BatchItem, Batcher};
use super::engine::{EngineCore, PrefillStats};
use super::kvcache::{BlockId, KvAllocator};
use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use super::session::{Event, EventSink, RejectReason, SessionState};

/// One in-flight request: the immutable submission, its event stream,
/// its KV reservation, and whichever engine state its phase carries.
struct Session<E: EngineCore> {
    req: Request,
    sink: EventSink,
    state: SessionState,
    blocks: Vec<BlockId>,
    admit_attempts: usize,
    prefill: Option<E::Prefill>,
    decode: Option<E::Decode>,
    stats: Option<PrefillStats>,
    queue_us: u64,
    ttft_us: Option<u64>,
    emitted: usize,
    /// Rounds since this prefill last advanced a chunk (starvation
    /// counter feeding the budget-exempt chunk grant).
    rounds_starved: u64,
}

impl<E: EngineCore> BatchItem for Session<E> {
    fn cost(&self) -> usize {
        self.req.prompt_len()
    }
}

pub struct Scheduler<E: EngineCore> {
    queue: Batcher<Session<E>>,
    prefilling: Vec<Session<E>>,
    decoding: Vec<Session<E>>,
    pub kv: KvAllocator,
    pub metrics: Metrics,
    decode_tokens: usize,
    chunk_layers: usize,
    round_budget: usize,
    max_active: usize,
    max_prefills: usize,
    admit_retries: usize,
    /// When true, every id that receives its terminal event is logged to
    /// `retired` until drained — the fleet front door consumes this so
    /// its session registry (used to synthesize terminal `Error`s after
    /// a shard crash) never double-terminates a stream.  Off by default:
    /// the single-engine path pays nothing.
    track_retired: bool,
    retired: Vec<RequestId>,
}

impl<E: EngineCore> Scheduler<E> {
    pub fn new(cfg: &ServeConfig) -> Scheduler<E> {
        Scheduler {
            queue: Batcher::new(cfg.max_batch_tokens,
                                cfg.max_batch_requests,
                                cfg.queue_capacity),
            prefilling: Vec::new(),
            decoding: Vec::new(),
            kv: KvAllocator::new(cfg.kv_blocks),
            metrics: Metrics::new(),
            decode_tokens: cfg.decode_tokens,
            chunk_layers: cfg.chunk_layers.max(1),
            round_budget: cfg.max_batch_tokens.max(1),
            max_active: cfg.max_batch_requests.max(1),
            max_prefills: cfg.max_concurrent_prefills.max(1),
            admit_retries: cfg.admit_retries,
            track_retired: false,
            retired: Vec::new(),
        }
    }

    /// Enable the terminal-event log drained by [`Scheduler::take_retired`]
    /// (fleet supervision; see the `track_retired` field).
    pub fn track_retirements(&mut self) {
        self.track_retired = true;
    }

    /// Drain the ids that reached a terminal event since the last call.
    /// Empty unless [`Scheduler::track_retirements`] was enabled.
    pub fn take_retired(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.retired)
    }

    fn log_retired(&mut self, id: RequestId) {
        if self.track_retired {
            self.retired.push(id);
        }
    }

    /// Submit a request with its event sink; false = queue full (the
    /// session still receives a terminal `Rejected` event).
    pub fn submit(&mut self, r: Request, sink: EventSink) -> bool {
        let s = Session {
            req: r,
            sink,
            state: SessionState::Queued,
            blocks: Vec::new(),
            admit_attempts: 0,
            prefill: None,
            decode: None,
            stats: None,
            queue_us: 0,
            ttft_us: None,
            emitted: 0,
            rounds_starved: 0,
        };
        match self.queue.push(s) {
            Ok(()) => true,
            Err(s) => {
                self.metrics.requests_rejected += 1;
                s.sink.send(Event::Rejected {
                    id: s.req.id,
                    reason: RejectReason::QueueFull,
                });
                self.log_retired(s.req.id);
                false
            }
        }
    }

    /// Queued (not yet admitted) sessions.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admitted sessions currently prefilling or decoding.
    pub fn active(&self) -> usize {
        self.decoding.len() + self.prefilling.len()
    }

    /// Prefills currently in flight (≤ `serve.max_concurrent_prefills`).
    pub fn prefills_in_flight(&self) -> usize {
        self.prefilling.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.prefilling.is_empty()
            || !self.decoding.is_empty()
    }

    /// Cancel a session in any non-terminal phase.  Frees its KV blocks
    /// and emits the terminal `Cancelled` event; false if unknown.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(s) = self.queue.remove_by(|s| s.req.id == id) {
            self.cancel_session(s);
            return true;
        }
        if let Some(i) = self.prefilling.iter().position(|s| s.req.id == id) {
            let s = self.prefilling.swap_remove(i);
            self.cancel_session(s);
            return true;
        }
        if let Some(i) = self.decoding.iter().position(|s| s.req.id == id) {
            let s = self.decoding.swap_remove(i);
            self.cancel_session(s);
            return true;
        }
        false
    }

    fn cancel_session(&mut self, mut s: Session<E>) {
        self.release_blocks(&mut s);
        s.state = SessionState::Cancelled;
        self.metrics.requests_cancelled += 1;
        s.sink.send(Event::Cancelled { id: s.req.id });
        self.log_retired(s.req.id);
    }

    fn reject(&mut self, mut s: Session<E>, reason: RejectReason) {
        self.release_blocks(&mut s);
        s.state = SessionState::Rejected;
        self.metrics.requests_rejected += 1;
        s.sink.send(Event::Rejected { id: s.req.id, reason });
        self.log_retired(s.req.id);
    }

    fn release_blocks(&mut self, s: &mut Session<E>) {
        if !s.blocks.is_empty() {
            self.kv.release(&s.blocks).expect(
                "invariant: released blocks were handed out by this \
                 scheduler");
            s.blocks.clear();
        }
    }

    /// Terminal `Error` for one session the engine failed on (its KV
    /// reservation must not leak with it).
    fn fail_session(&mut self, mut s: Session<E>, message: &str) {
        self.release_blocks(&mut s);
        s.sink.send(Event::Error {
            id: s.req.id,
            message: message.to_string(),
        });
        self.log_retired(s.req.id);
    }

    /// Fail every live session with a terminal `Error` event (engine
    /// died); the scheduler stays usable for accounting afterwards.
    pub fn fail_all(&mut self, message: &str) {
        let mut all: Vec<Session<E>> = Vec::new();
        while let Some(s) = self.queue.pop_front() {
            all.push(s);
        }
        all.append(&mut self.prefilling);
        all.append(&mut self.decoding);
        for mut s in all {
            self.release_blocks(&mut s);
            s.sink.send(Event::Error {
                id: s.req.id,
                message: message.to_string(),
            });
            self.log_retired(s.req.id);
        }
    }

    /// Fill free prefill slots from the queue head (FIFO).  `count_retry`
    /// marks the once-per-round admission attempt that burns a KV retry.
    fn admit(&mut self, engine: &mut E, count_retry: bool) -> Result<()> {
        while self.prefilling.len() < self.max_prefills {
            if self.active() >= self.max_active {
                return Ok(());
            }
            // Peek the queue head; the `let else` arms below that pop
            // it again can only see the same non-empty queue, so their
            // `return Ok(())` fallbacks are unreachable no-ops — they
            // exist so this path is panic-free (lint: panic-hygiene).
            let Some(front) = self.queue.front() else { return Ok(()) };
            let prompt_len = front.req.prompt_len();
            let need = KvAllocator::blocks_needed(
                prompt_len, self.decode_tokens, engine.layers_total());
            if prompt_len == 0 {
                let Some(s) = self.queue.pop_front() else {
                    return Ok(());
                };
                self.reject(s, RejectReason::EmptyPrompt);
                continue;
            }
            if !self.kv.can_alloc(need) {
                if count_retry {
                    let Some(f) = self.queue.front_mut() else {
                        return Ok(());
                    };
                    f.admit_attempts += 1;
                    if f.admit_attempts > self.admit_retries {
                        let Some(s) = self.queue.pop_front() else {
                            return Ok(());
                        };
                        self.reject(s, RejectReason::KvExhausted {
                            blocks_needed: need,
                            retries: self.admit_retries,
                        });
                        continue; // the next queued session may be smaller
                    }
                }
                return Ok(()); // head of line waits; FIFO preserved
            }
            let Some(mut s) = self.queue.pop_front() else {
                return Ok(());
            };
            match engine.begin_prefill(&s.req.tokens) {
                Ok(task) => {
                    s.blocks = self.kv.alloc(need)?;
                    s.queue_us = s.req.arrived.elapsed().as_micros() as u64;
                    s.state = SessionState::Prefilling;
                    s.prefill = Some(task);
                    self.prefilling.push(s);
                }
                Err(e) => {
                    // per-request failure (e.g. prompt exceeds the max
                    // seq bucket) must not take the server down
                    self.reject(s, RejectReason::EngineRefused {
                        message: format!("{e:#}"),
                    });
                }
            }
        }
        Ok(())
    }

    /// Budget cost of one prefill chunk: the prompt's tokens spread
    /// evenly over its chunks.
    fn chunk_cost(&self, engine: &E, s: &Session<E>) -> usize {
        let chunks = engine.layers_total().max(1)
            .div_ceil(self.chunk_layers);
        s.req.prompt_len().div_ceil(chunks.max(1)).max(1)
    }

    /// Remaining budget cost of a live prefill — the shortest-remaining-
    /// work sort key: chunks left × per-chunk cost.
    fn remaining_cost(&self, engine: &E, s: &Session<E>) -> usize {
        let (done, total) = engine.prefill_progress(
            s.prefill.as_ref().expect(
                "invariant: sessions in `prefilling` hold a prefill \
                 task"));
        let chunks_left =
            total.saturating_sub(done).div_ceil(self.chunk_layers);
        chunks_left * self.chunk_cost(engine, s)
    }

    /// Advance one chunk of the live prefill at `self.prefilling[i]`:
    /// run the engine, emit `PrefillProgress`, and on completion move
    /// the session to decoding and refill the freed prefill slot.
    /// Engine errors must not drop the session on the floor — its KV
    /// blocks and terminal event would leak (`fail_all` can't see a
    /// taken session) — so the failing session is failed here before
    /// the error propagates.
    fn advance_prefill(&mut self, engine: &mut E, i: usize) -> Result<()> {
        let id = self.prefilling[i].req.id;
        let step = engine.prefill_chunk(
            self.prefilling[i].prefill.as_mut().expect(
                "invariant: sessions in `prefilling` hold a prefill \
                 task"),
            self.chunk_layers);
        let done = match step {
            Ok(d) => d,
            Err(e) => {
                let s = self.prefilling.swap_remove(i);
                self.fail_session(s, &format!("{e:#}"));
                return Err(e);
            }
        };
        let s = &mut self.prefilling[i];
        let (ld, lt) = engine.prefill_progress(s.prefill.as_ref().expect(
            "invariant: sessions in `prefilling` hold a prefill task"));
        s.sink.send(Event::PrefillProgress {
            id,
            layers_done: ld,
            layers_total: lt,
        });
        if done {
            let mut s = self.prefilling.swap_remove(i);
            let task = s.prefill.take().expect(
                "invariant: sessions in `prefilling` hold a prefill \
                 task");
            let max_new = s.req.max_new_tokens
                .min(self.decode_tokens.max(1));
            let (dec, stats) = match engine.start_decode(task, max_new) {
                Ok(x) => x,
                Err(e) => {
                    self.fail_session(s, &format!("{e:#}"));
                    return Err(e);
                }
            };
            self.metrics.record_prefill(&stats);
            self.metrics.prompt_tokens += s.req.prompt_len() as u64;
            s.sink.send(Event::PrefillDone { id, stats: stats.clone() });
            s.stats = Some(stats);
            s.state = SessionState::Decoding;
            s.decode = Some(dec);
            self.decoding.push(s);
            // a prefill slot freed: pull in the next queued prompt
            self.admit(engine, false)?;
        }
        Ok(())
    }

    /// Run one scheduling round. Returns sessions completed this round.
    pub fn run_round(&mut self, engine: &mut E) -> Result<Vec<Response>> {
        let mut completed = Vec::new();
        self.admit(engine, true)?;
        let track_round = self.has_work();
        let mut budget = self.round_budget;
        let (mut spent_decode, mut spent_prefill) = (0usize, 0usize);
        let mut ran_ids: Vec<RequestId> = Vec::new();
        loop {
            let mut progressed = false;

            // Decode pass: one token per live session (latency first).
            let mut i = 0;
            while i < self.decoding.len() {
                if budget == 0 {
                    break;
                }
                let s = &mut self.decoding[i];
                match engine.decode_step(s.decode.as_mut().expect(
                    "invariant: sessions in `decoding` hold a decode \
                     session"))? {
                    Some(tok) => {
                        budget -= 1;
                        spent_decode += 1;
                        if s.ttft_us.is_none() {
                            s.ttft_us = Some(
                                s.req.arrived.elapsed().as_micros() as u64);
                        }
                        let index = s.emitted;
                        s.emitted += 1;
                        s.sink.send(Event::Token {
                            id: s.req.id, token: tok, index,
                        });
                        progressed = true;
                        i += 1;
                    }
                    None => {
                        let s = self.decoding.swap_remove(i);
                        completed.push(self.finish(engine, s));
                        progressed = true;
                    }
                }
            }

            // Budgeted prefill pass: one chunk per live prefill whose
            // chunk fits the remaining budget, shortest-remaining-work
            // first.  Over-budget prompts wait for the round-end exempt
            // grant so a mega-chunk cannot crowd out everyone else's
            // within-budget chunks and decode steps.
            let mut order: Vec<(usize, RequestId)> = self.prefilling.iter()
                .map(|s| (self.remaining_cost(engine, s), s.req.id))
                .collect();
            order.sort_unstable();
            for (_, id) in order {
                let Some(i) = self.prefilling.iter()
                    .position(|s| s.req.id == id) else { continue };
                let cost = self.chunk_cost(engine, &self.prefilling[i]);
                if budget < cost {
                    continue; // over budget: round-end grant at best
                }
                budget -= cost;
                spent_prefill += cost;
                progressed = true;
                if !ran_ids.contains(&id) {
                    ran_ids.push(id);
                }
                self.advance_prefill(engine, i)?;
            }

            if !progressed || budget == 0 {
                break;
            }
        }
        // One budget-exempt chunk per round for the longest-starved
        // prefill that got no budgeted chunk — its chunk exceeded what
        // was left of the budget (ties → earliest submission).  Running
        // it after the budgeted work keeps a big chunk from crowding
        // out everyone else's within-budget work, and the
        // `rounds_starved` rotation bounds any skipped prefill's wait
        // to (live skipped prefills − 1) rounds; the round's prefill
        // spend may overshoot the budget by at most this one chunk.
        // With max_concurrent_prefills = 1 this reproduces the old
        // guaranteed-chunk rule chunk-for-chunk.
        let mut spent_exempt = 0usize;
        let exempt = self.prefilling.iter().enumerate()
            .filter(|(_, s)| !ran_ids.contains(&s.req.id))
            .max_by_key(|(_, s)| (s.rounds_starved,
                                  std::cmp::Reverse(s.req.id)))
            .map(|(i, s)| (i, s.req.id));
        if let Some((i, id)) = exempt {
            spent_exempt = self.chunk_cost(engine, &self.prefilling[i]);
            ran_ids.push(id);
            self.advance_prefill(engine, i)?;
        }
        for s in &mut self.prefilling {
            if ran_ids.contains(&s.req.id) {
                s.rounds_starved = 0;
            } else {
                s.rounds_starved += 1;
            }
        }
        if track_round {
            self.metrics.record_round(spent_decode, spent_prefill,
                                      spent_exempt, self.round_budget);
        }
        Ok(completed)
    }

    /// Retire a decoded-out session: release KV, record metrics, emit
    /// the terminal `Done` event.
    fn finish(&mut self, engine: &E, mut s: Session<E>) -> Response {
        self.release_blocks(&mut s);
        let d = s.decode.take().expect(
            "invariant: sessions in `decoding` hold a decode session");
        let generated = engine.generated(&d).to_vec();
        let decode_us = engine.decode_elapsed_us(&d);
        let stats = s.stats.take().unwrap_or_default();
        // no tokens requested → first "result" is prefill completion
        let ttft_us = s.ttft_us.unwrap_or_else(|| {
            s.req.arrived.elapsed().as_micros() as u64
        });
        self.metrics.decode_us.record_us(decode_us);
        self.metrics.queue_us.record_us(s.queue_us);
        self.metrics.ttft_us.record_us(ttft_us);
        self.metrics.generated_tokens += generated.len() as u64;
        self.metrics.requests_completed += 1;
        let response = Response {
            id: s.req.id,
            generated,
            prefill_us: stats.latency_us,
            decode_us,
            queue_us: s.queue_us,
            ttft_us,
            density: stats.density(),
        };
        s.state = SessionState::Done;
        s.sink.send(Event::Done {
            id: s.req.id,
            response: response.clone(),
        });
        self.log_retired(s.req.id);
        response
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::serving::sim::SimEngine;

    #[test]
    fn submit_reject_accounting() {
        let cfg = ServeConfig { queue_capacity: 1, ..Default::default() };
        let mut s: Scheduler<SimEngine> = Scheduler::new(&cfg);
        assert!(s.submit(Request::new(0, vec![0; 8], 0),
                         EventSink::null()));
        assert!(!s.submit(Request::new(1, vec![0; 8], 0),
                          EventSink::null()));
        assert_eq!(s.metrics.requests_rejected, 1);
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn round_completes_sessions_and_frees_kv() {
        let cfg = ServeConfig::default();
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        for i in 0..3 {
            assert!(sched.submit(Request::new(i, vec![7; 64], 2),
                                 sink.clone()));
        }
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.run_round(&mut engine).unwrap());
        }
        drop(sink);
        assert_eq!(done.len(), 3);
        assert_eq!(sched.metrics.requests_completed, 3);
        assert_eq!(sched.kv.used(), 0, "all kv blocks released");
        for r in &done {
            assert_eq!(r.generated.len(), 2);
        }
        let events: Vec<Event> = rx.iter().collect();
        let dones = events.iter()
            .filter(|e| matches!(e, Event::Done { .. }))
            .count();
        assert_eq!(dones, 3);
    }

    #[test]
    fn repeat_workload_hits_pattern_cache_in_metrics() {
        // serial prefills: the second same-length request begins only
        // after the first published at PrefillDone, so it runs warm and
        // the hit/miss rates surface in the scheduler's metrics
        let cfg = ServeConfig {
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        let mut engine = SimEngine::new(4).with_pattern_cache();
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.submit(Request::new(0, vec![7; 256], 1), EventSink::null());
        sched.submit(Request::new(1, vec![7; 256], 1), EventSink::null());
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert_eq!(sched.metrics.requests_completed, 2);
        assert_eq!(sched.metrics.cache_miss_heads, 4, "first request cold");
        assert_eq!(sched.metrics.cache_hit_heads, 4, "second request warm");
        assert!(sched.metrics.cache_hit_rate() > 0.0);
        assert!(sched.metrics.report().contains("pattern cache:"));
        assert_eq!(sched.kv.used(), 0);
    }

    #[test]
    fn retirement_log_tracks_terminal_events() {
        let cfg = ServeConfig { queue_capacity: 1, ..Default::default() };
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.track_retirements();
        assert!(sched.submit(Request::new(0, vec![7; 16], 1),
                             EventSink::null()));
        // queue-full rejection is a terminal event too
        assert!(!sched.submit(Request::new(1, vec![7; 16], 1),
                              EventSink::null()));
        assert_eq!(sched.take_retired(), vec![1]);
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert_eq!(sched.take_retired(), vec![0]);
        assert!(sched.take_retired().is_empty());
        // off by default: nothing is logged
        let mut quiet: Scheduler<SimEngine> = Scheduler::new(&cfg);
        quiet.submit(Request::new(0, vec![7; 16], 1), EventSink::null());
        while quiet.has_work() {
            quiet.run_round(&mut engine).unwrap();
        }
        assert!(quiet.take_retired().is_empty());
    }

    #[test]
    fn cancel_unknown_id_is_false() {
        let cfg = ServeConfig::default();
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        assert!(!sched.cancel(99));
    }

    #[test]
    fn round_occupancy_is_recorded() {
        let cfg = ServeConfig::default();
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.submit(Request::new(0, vec![7; 64], 2), EventSink::null());
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert!(sched.metrics.rounds > 0);
        let spent = sched.metrics.decode_budget_tokens
            + sched.metrics.prefill_budget_tokens;
        assert!(spent > 0, "budget spend must be accounted");
        // idle rounds with no work at all are not recorded
        let rounds_before = sched.metrics.rounds;
        sched.run_round(&mut engine).unwrap();
        assert_eq!(sched.metrics.rounds, rounds_before);
    }
}
