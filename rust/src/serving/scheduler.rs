//! Scheduler: glues batcher + KV admission + engine into the serving loop.
//! Round-based: pull a batch, admit what the KV allocator can hold, run
//! prefill → decode per request, release blocks, record metrics.

use anyhow::Result;

use crate::config::ServeConfig;

use super::batcher::Batcher;
use super::engine::Engine;
use super::kvcache::KvAllocator;
use super::metrics::Metrics;
use super::request::{Request, Response};

pub struct Scheduler {
    pub batcher: Batcher,
    pub kv: KvAllocator,
    pub metrics: Metrics,
    decode_tokens: usize,
}

impl Scheduler {
    pub fn new(cfg: &ServeConfig) -> Scheduler {
        Scheduler {
            batcher: Batcher::new(cfg.max_batch_tokens,
                                  cfg.max_batch_requests,
                                  cfg.queue_capacity),
            kv: KvAllocator::new(cfg.kv_blocks),
            metrics: Metrics::new(),
            decode_tokens: cfg.decode_tokens,
        }
    }

    /// Submit a request; false = queue full (rejected).
    pub fn submit(&mut self, r: Request) -> bool {
        let ok = self.batcher.push(r);
        if !ok {
            self.metrics.requests_rejected += 1;
        }
        ok
    }

    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    /// Run one scheduling round on `engine`. Returns completed responses.
    pub fn run_round(&mut self, engine: &mut Engine)
                     -> Result<Vec<Response>> {
        let batch = self.batcher.next_batch();
        let mut responses = Vec::with_capacity(batch.len());
        for req in batch {
            let queue_us = req.arrived.elapsed().as_micros() as u64;
            let layers = engine.stages.spec.num_layers;
            let need = KvAllocator::blocks_needed(
                req.prompt_len(), self.decode_tokens, layers);
            let blocks = match self.kv.alloc(need) {
                Ok(b) => b,
                Err(_) => {
                    // out of cache: reject (a fuller system would re-queue)
                    self.metrics.requests_rejected += 1;
                    continue;
                }
            };
            let pre = engine.prefill(&req.tokens)?;
            self.metrics.record_prefill(&pre.stats);
            self.metrics.prompt_tokens += req.prompt_len() as u64;
            let n = req.max_new_tokens.min(self.decode_tokens.max(1));
            let (generated, decode_us) = if n > 0 {
                engine.decode(&pre, n)?
            } else {
                (Vec::new(), 0)
            };
            self.kv.release(&blocks)?;
            self.metrics.decode_us.record_us(decode_us);
            self.metrics.queue_us.record_us(queue_us);
            self.metrics.generated_tokens += generated.len() as u64;
            self.metrics.requests_completed += 1;
            responses.push(Response {
                id: req.id,
                generated,
                prefill_us: pre.stats.latency_us,
                decode_us,
                queue_us,
                density: pre.stats.density(),
            });
        }
        Ok(responses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    #[test]
    fn submit_reject_accounting() {
        let cfg = ServeConfig { queue_capacity: 1, ..Default::default() };
        let mut s = Scheduler::new(&cfg);
        assert!(s.submit(Request::new(0, vec![0; 8], 0)));
        assert!(!s.submit(Request::new(1, vec![0; 8], 0)));
        assert_eq!(s.metrics.requests_rejected, 1);
        assert_eq!(s.pending(), 1);
    }
}
