//! Scheduler: continuous batching over sessions.
//!
//! Each [`Scheduler::run_round`] spends a shared token budget
//! (`serve.max_batch_tokens`) across the live sessions: every decoding
//! session advances one token per pass (a decode step costs 1 budget
//! token) and up to `serve.max_concurrent_prefills` live prefills each
//! advance one layer-chunk per pass (a chunk costs its share of the
//! prompt's tokens, `ceil(prompt / chunks)`) — so a 32K prompt stalls
//! neither the decodes in flight nor the short prompts queued behind it.
//!
//! Multiple prefills can interleave because pattern strategies are
//! stateless planners: each `PrefillTask` owns its request's
//! [`PatternState`] (SharePrefill's pivotal dictionary), so chunks of
//! different prompts never share or clobber pattern state.
//!
//! **Fairness policy: shortest-remaining-work first.**  Within each
//! round's budgeted prefill pass, live prefills run in ascending order
//! of remaining budget cost (chunks left × per-chunk cost, ties by
//! submission id), so a freshly admitted short prompt overtakes a long
//! prompt mid-prefill and its TTFT stops paying for the 100k-token
//! request ahead of it.  A chunk that exceeds the *remaining* budget
//! never runs in that pass; instead, at round end one *budget-exempt*
//! chunk goes to the longest-starved prefill that got no budgeted
//! chunk.  This keeps a mega-chunk from crowding out everyone else's
//! within-budget work, prevents deterministic starvation (e.g. two
//! equal-cost chunks under a budget that fits only one — the SRF
//! tie-break would otherwise skip the same prompt every round), and
//! bounds any prefill's wait to (live skipped prefills − 1) rounds.
//! The cost: a round's prefill spend may overshoot `max_batch_tokens`
//! by at most that one chunk.  With `max_concurrent_prefills = 1` this
//! reproduces the old "active prefill always gets ≥ 1 chunk per round"
//! rule chunk-for-chunk (decode steps now additionally use budget the
//! old code discarded when the chunk overshot the round).
//!
//! Admission is KV-first: a session needs its whole-lifetime block count
//! up front (vLLM-style).  When the allocator is exhausted the head of
//! the queue *waits* and retries next round (bounded by
//! `serve.admit_retries`); only after the retry budget is spent does it
//! get a terminal `Rejected` event — clients never hang, and the
//! [`RejectReason`] tells them whether the condition was transient.
//!
//! **Overload discipline (`serve.admission.*`, off by default).**  Under
//! open-loop arrivals the queue can grow without bound; the admission
//! layer sheds load *early*, at submit, in a fixed decision order:
//! (1) queue-depth back-pressure (`QueueDepth`, interactive-class
//! requests exempt), (2) KV-headroom accounting over held + queued
//! demand (`KvHeadroom`), then (3) the hard `queue_capacity` wall
//! (`QueueFull`).  Queued sessions that outlive their round-denominated
//! deadline are shed with `DeadlineExceeded` at the top of each round.
//! Class priority (prompts ≤ `interactive_max_tokens`) lets short
//! interactive requests overtake queued batch work at admission, and a
//! degradation ladder (queue past `degrade_queue_depth`) shrinks the
//! round budget, caps concurrent prefills, and signals the engine via
//! [`EngineCore::set_pressure`] to tighten its sparse budget.  With
//! every knob at its default the entire layer is inert and event
//! streams are bit-identical to a build without it.
//!
//! The cross-request pattern cache needs nothing scheduler-specific to
//! stay safe under interleaved prefills: warm candidates are
//! snapshotted per request inside `begin_prefill` and publication
//! happens inside `start_decode` (the `PrefillDone` moment), both of
//! which this scheduler already serializes through the single engine.
//! Cancelled sessions drop their `PrefillTask` without reaching
//! `start_decode`, so a half-done prefill never publishes.  Per-head
//! cache outcomes ride `PrefillStats` into [`Metrics`]
//! (hit/miss/invalidation rates in the report).
//!
//! [`PatternState`]: crate::methods::PatternState

use anyhow::Result;

use crate::config::{AdmissionConfig, ServeConfig};

use super::batcher::{BatchItem, Batcher};
use super::engine::{EngineCore, PrefillStats};
use super::kvcache::{BlockId, KvAllocator, PrefixIndex};
use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use super::session::{Event, EventSink, RejectReason, SessionState};

/// One in-flight request: the immutable submission, its event stream,
/// its KV reservation, and whichever engine state its phase carries.
struct Session<E: EngineCore> {
    req: Request,
    sink: EventSink,
    state: SessionState,
    blocks: Vec<BlockId>,
    admit_attempts: usize,
    prefill: Option<E::Prefill>,
    decode: Option<E::Decode>,
    stats: Option<PrefillStats>,
    queue_us: u64,
    ttft_us: Option<u64>,
    emitted: usize,
    /// Rounds since this prefill last advanced a chunk (starvation
    /// counter feeding the budget-exempt chunk grant).
    rounds_starved: u64,
    /// Rounds spent waiting in the admission queue (deadline shedding:
    /// `serve.admission.max_queue_rounds`).
    queued_rounds: u64,
    /// Prefix-cache adoption at admission: shared KV blocks retained
    /// from the index and the prompt tokens they covered (both 0 on a
    /// cold admit or with `serve.prefix_cache` off).
    prefix_blocks: usize,
    prefix_tokens: usize,
}

impl<E: EngineCore> BatchItem for Session<E> {
    fn cost(&self) -> usize {
        self.req.prompt_len()
    }
}

pub struct Scheduler<E: EngineCore> {
    queue: Batcher<Session<E>>,
    prefilling: Vec<Session<E>>,
    decoding: Vec<Session<E>>,
    pub kv: KvAllocator,
    pub metrics: Metrics,
    decode_tokens: usize,
    chunk_layers: usize,
    round_budget: usize,
    max_active: usize,
    max_prefills: usize,
    /// Effective concurrent-prefill cap for the current round: equals
    /// `max_prefills` normally, the degraded cap while the degradation
    /// ladder is engaged.
    cur_max_prefills: usize,
    admit_retries: usize,
    admission: AdmissionConfig,
    /// Content-addressed prefix sharing (`serve.prefix_cache.*`): maps
    /// chained prompt-chunk hashes to retained KV block runs so a
    /// request whose prompt extends an already-served one adopts the
    /// shared blocks and prefills only its divergent suffix.  `None`
    /// with the knob off — every admission then takes the exact
    /// pre-existing cold path.
    prefix: Option<PrefixIndex>,
    /// When true, every id that receives its terminal event is logged to
    /// `retired` until drained — the fleet front door consumes this so
    /// its session registry (used to synthesize terminal `Error`s after
    /// a shard crash) never double-terminates a stream.  Off by default:
    /// the single-engine path pays nothing.
    track_retired: bool,
    retired: Vec<RequestId>,
}

impl<E: EngineCore> Scheduler<E> {
    pub fn new(cfg: &ServeConfig) -> Scheduler<E> {
        Scheduler {
            queue: Batcher::new(cfg.max_batch_tokens,
                                cfg.max_batch_requests,
                                cfg.queue_capacity),
            prefilling: Vec::new(),
            decoding: Vec::new(),
            kv: KvAllocator::new(cfg.kv_blocks),
            metrics: Metrics::new(),
            decode_tokens: cfg.decode_tokens,
            chunk_layers: cfg.chunk_layers.max(1),
            round_budget: cfg.max_batch_tokens.max(1),
            max_active: cfg.max_batch_requests.max(1),
            max_prefills: cfg.max_concurrent_prefills.max(1),
            cur_max_prefills: cfg.max_concurrent_prefills.max(1),
            admit_retries: cfg.admit_retries,
            admission: cfg.admission.clone(),
            prefix: cfg.prefix_cache.enabled.then(|| {
                PrefixIndex::new(cfg.prefix_cache.capacity)
            }),
            track_retired: false,
            retired: Vec::new(),
        }
    }

    /// Enable the terminal-event log drained by [`Scheduler::take_retired`]
    /// (fleet supervision; see the `track_retired` field).
    pub fn track_retirements(&mut self) {
        self.track_retired = true;
    }

    /// Drain the ids that reached a terminal event since the last call.
    /// Empty unless [`Scheduler::track_retirements`] was enabled.
    pub fn take_retired(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.retired)
    }

    /// KV blocks currently retained by the prefix index alone
    /// (0 with `serve.prefix_cache` off).  `kv.used()` converges to
    /// this once every session retires — the cache deliberately keeps
    /// prompt blocks alive for reuse.
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.block_count())
    }

    /// Drop every prefix-cache retention (shutdown / leak accounting):
    /// after the last session retires and this runs, `kv.used()` must
    /// be exactly 0 again.
    pub fn flush_prefix_cache(&mut self) {
        if let Some(p) = self.prefix.as_mut() {
            p.clear(&mut self.kv).expect(
                "invariant: the index only retains blocks this \
                 scheduler handed out");
        }
    }

    fn log_retired(&mut self, id: RequestId) {
        if self.track_retired {
            self.retired.push(id);
        }
    }

    /// Whole-lifetime KV block demand of a prompt on `engine`.
    fn blocks_for(&self, engine: &E, prompt_len: usize) -> usize {
        KvAllocator::blocks_needed(prompt_len, self.decode_tokens,
                                   engine.layers_total())
    }

    /// Interactive-class request under the admission config's class
    /// boundary (always false with classes off).
    fn is_interactive(&self, prompt_len: usize) -> bool {
        self.admission.enabled
            && self.admission.interactive_max_tokens > 0
            && prompt_len <= self.admission.interactive_max_tokens
    }

    /// Submit a request with its event sink; false = shed at admission
    /// (queue depth, KV headroom, or the hard queue-capacity wall — the
    /// session still receives a terminal `Rejected` event saying which).
    pub fn submit(&mut self, engine: &E, r: Request, sink: EventSink)
                  -> bool {
        let s = Session {
            req: r,
            sink,
            state: SessionState::Queued,
            blocks: Vec::new(),
            admit_attempts: 0,
            prefill: None,
            decode: None,
            stats: None,
            queue_us: 0,
            ttft_us: None,
            emitted: 0,
            rounds_starved: 0,
            queued_rounds: 0,
            prefix_blocks: 0,
            prefix_tokens: 0,
        };
        if self.admission.enabled {
            let prompt_len = s.req.prompt_len();
            // (1) queue-depth back-pressure: shed batch-class load well
            // before the hard capacity wall; interactive requests may
            // use the full queue.
            let (depth, limit) =
                (self.queue.len(), self.admission.max_queue_depth);
            if limit > 0 && depth >= limit
                && !self.is_interactive(prompt_len) {
                self.reject(s, RejectReason::QueueDepth { depth, limit });
                return false;
            }
            // (2) KV headroom: held blocks + queued demand + this
            // request must fit under the overcommit ceiling, otherwise
            // the queue is a promise the allocator cannot keep.
            if self.admission.kv_overcommit > 0.0 {
                let need = self.blocks_for(engine, prompt_len);
                let queued: usize = self.queue.iter()
                    .map(|q| self.blocks_for(engine, q.req.prompt_len()))
                    .sum();
                let committed = self.kv.used() + queued;
                let ceiling = (self.admission.kv_overcommit
                               * self.kv.capacity() as f64) as usize;
                if committed + need > ceiling {
                    self.reject(s, RejectReason::KvHeadroom {
                        blocks_needed: need,
                        committed,
                        capacity: ceiling,
                    });
                    return false;
                }
            }
        }
        // (3) the hard queue-capacity wall.
        match self.queue.push(s) {
            Ok(()) => true,
            Err(s) => {
                self.metrics.requests_rejected += 1;
                s.sink.send(Event::Rejected {
                    id: s.req.id,
                    reason: RejectReason::QueueFull,
                });
                self.log_retired(s.req.id);
                false
            }
        }
    }

    /// Queued (not yet admitted) sessions.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admitted sessions currently prefilling or decoding.
    pub fn active(&self) -> usize {
        self.decoding.len() + self.prefilling.len()
    }

    /// Prefills currently in flight (≤ `serve.max_concurrent_prefills`).
    pub fn prefills_in_flight(&self) -> usize {
        self.prefilling.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.prefilling.is_empty()
            || !self.decoding.is_empty()
    }

    /// Cancel a session in any non-terminal phase.  Frees its KV blocks
    /// and emits the terminal `Cancelled` event; false if unknown.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(s) = self.queue.remove_by(|s| s.req.id == id) {
            self.cancel_session(s);
            return true;
        }
        if let Some(i) = self.prefilling.iter().position(|s| s.req.id == id) {
            let s = self.prefilling.swap_remove(i);
            self.cancel_session(s);
            return true;
        }
        if let Some(i) = self.decoding.iter().position(|s| s.req.id == id) {
            let s = self.decoding.swap_remove(i);
            self.cancel_session(s);
            return true;
        }
        false
    }

    fn cancel_session(&mut self, mut s: Session<E>) {
        self.release_blocks(&mut s);
        s.state = SessionState::Cancelled;
        self.metrics.requests_cancelled += 1;
        s.sink.send(Event::Cancelled { id: s.req.id });
        self.log_retired(s.req.id);
    }

    fn reject(&mut self, mut s: Session<E>, reason: RejectReason) {
        self.release_blocks(&mut s);
        s.state = SessionState::Rejected;
        self.metrics.requests_rejected += 1;
        match &reason {
            RejectReason::QueueDepth { .. } => {
                self.metrics.shed_queue_depth += 1;
            }
            RejectReason::KvHeadroom { .. } => {
                self.metrics.shed_kv_headroom += 1;
            }
            RejectReason::DeadlineExceeded { .. } => {
                self.metrics.shed_deadline += 1;
            }
            _ => {}
        }
        s.sink.send(Event::Rejected { id: s.req.id, reason });
        self.log_retired(s.req.id);
    }

    fn release_blocks(&mut self, s: &mut Session<E>) {
        if !s.blocks.is_empty() {
            self.kv.release(&s.blocks).expect(
                "invariant: released blocks were handed out by this \
                 scheduler");
            s.blocks.clear();
        }
    }

    /// Terminal `Error` for one session the engine failed on: its KV
    /// reservation must not leak, its state must land on the terminal
    /// `Errored`, and the error must count — completed + rejected +
    /// cancelled + errored is the reconciliation the summary reports.
    fn fail_session(&mut self, mut s: Session<E>, message: &str) {
        self.release_blocks(&mut s);
        s.state = SessionState::Errored;
        self.metrics.requests_errored += 1;
        s.sink.send(Event::Error {
            id: s.req.id,
            message: message.to_string(),
        });
        self.log_retired(s.req.id);
    }

    /// Fail every live session with a terminal `Error` event (engine
    /// died); the scheduler stays usable for accounting afterwards.
    pub fn fail_all(&mut self, message: &str) {
        let mut all: Vec<Session<E>> = Vec::new();
        while let Some(s) = self.queue.pop_front() {
            all.push(s);
        }
        all.append(&mut self.prefilling);
        all.append(&mut self.decoding);
        for s in all {
            self.fail_session(s, message);
        }
    }

    /// Queue index of the next admission candidate: the first
    /// interactive-class session when class priority is on, the FIFO
    /// head otherwise (and always the head with admission disabled).
    fn candidate_index(&self) -> usize {
        if self.admission.enabled
            && self.admission.interactive_max_tokens > 0 {
            let imax = self.admission.interactive_max_tokens;
            self.queue.iter()
                .position(|s| s.req.prompt_len() <= imax)
                .unwrap_or(0)
        } else {
            0
        }
    }

    /// Fill free prefill slots from the queue (FIFO, except that class
    /// priority may pull an interactive session out of the middle).
    /// `count_retry` marks the once-per-round admission attempt that
    /// burns a KV retry.
    fn admit(&mut self, engine: &mut E, count_retry: bool) -> Result<()> {
        while self.prefilling.len() < self.cur_max_prefills {
            if self.active() >= self.max_active {
                return Ok(());
            }
            // Peek the candidate; the `let else` arms below that take
            // it again can only see the same non-empty queue, so their
            // `return Ok(())` fallbacks are unreachable no-ops — they
            // exist so this path is panic-free (lint: panic-hygiene).
            let ci = self.candidate_index();
            let Some(front) = self.queue.iter().nth(ci) else {
                return Ok(());
            };
            let prompt_len = front.req.prompt_len();
            // Prefix cache: leading chunks already indexed need no
            // fresh blocks — admission only has to find the divergent
            // suffix plus decode growth (the probe is read-only; the
            // retains happen in `acquire` once the session is popped).
            let layers = engine.layers_total();
            let matched = match self.prefix.as_ref() {
                Some(p) => p.probe(&front.req.tokens),
                None => 0,
            };
            let need = self.blocks_for(engine, prompt_len)
                .saturating_sub(matched * layers);
            if prompt_len == 0 {
                let Some(s) = self.queue.remove_at(ci) else {
                    return Ok(());
                };
                self.reject(s, RejectReason::EmptyPrompt);
                continue;
            }
            if !self.kv.can_alloc(need) {
                // Allocator pressure sheds the cache's own retains
                // before any request waits or is rejected: evict LRU
                // entries until the candidate fits, then re-evaluate it
                // from the top (eviction may have dropped the chunks
                // its `matched` counted on).  Terminates: each pass
                // shrinks the index, and an empty index evicts nothing.
                if let Some(p) = self.prefix.as_mut() {
                    let mut evicted = false;
                    while !self.kv.can_alloc(need) {
                        let more = p.evict_one(&mut self.kv).expect(
                            "invariant: the index only retains blocks \
                             this scheduler handed out");
                        if !more {
                            break;
                        }
                        evicted = true;
                    }
                    if evicted {
                        continue;
                    }
                }
                if count_retry {
                    let Some(f) = self.queue.get_mut(ci) else {
                        return Ok(());
                    };
                    f.admit_attempts += 1;
                    if f.admit_attempts > self.admit_retries {
                        let Some(s) = self.queue.remove_at(ci) else {
                            return Ok(());
                        };
                        self.reject(s, RejectReason::KvExhausted {
                            blocks_needed: need,
                            retries: self.admit_retries,
                        });
                        continue; // the next queued session may be smaller
                    }
                }
                return Ok(()); // the candidate waits; order preserved
            }
            let Some(mut s) = self.queue.remove_at(ci) else {
                return Ok(());
            };
            // Adopt the cached prefix first: matched chunks are
            // retained out of the index (shared, chunk-major) into
            // `s.blocks`, so every failure path below — which funnels
            // through `reject` → `release_blocks` — drops the retains
            // along with any fresh allocation.
            if let Some(p) = self.prefix.as_mut() {
                let (chunks, shared) = p
                    .acquire(&s.req.tokens, &mut self.kv)
                    .expect("invariant: indexed prefix blocks stay \
                             allocated until the index releases them");
                debug_assert_eq!(chunks, matched,
                                 "probe/acquire must agree within one \
                                  admission");
                s.prefix_tokens = chunks * crate::BLOCK_SIZE;
                s.prefix_blocks = shared.len();
                s.blocks = shared;
            }
            // KV first, engine second: once the session is out of the
            // queue every failure must end in a terminal event, so the
            // allocation error is a `Rejected` rather than a `?` that
            // would silently drop the session (and `reject` releases
            // the blocks the engine-refusal arm below holds).
            match self.kv.alloc(need) {
                Ok(blocks) => s.blocks.extend(blocks),
                Err(_) => {
                    self.reject(s, RejectReason::KvExhausted {
                        blocks_needed: need,
                        retries: self.admit_retries,
                    });
                    continue;
                }
            }
            match engine.begin_prefill_at(&s.req.tokens, s.prefix_tokens) {
                Ok(task) => {
                    s.queue_us = s.req.arrived.elapsed().as_micros() as u64;
                    s.state = SessionState::Prefilling;
                    s.prefill = Some(task);
                    self.prefilling.push(s);
                }
                Err(e) => {
                    // per-request failure (e.g. prompt exceeds the max
                    // seq bucket) must not take the server down
                    self.reject(s, RejectReason::EngineRefused {
                        message: format!("{e:#}"),
                    });
                }
            }
        }
        Ok(())
    }

    /// Budget cost of one prefill chunk: the prompt's tokens spread
    /// evenly over its chunks.
    fn chunk_cost(&self, engine: &E, s: &Session<E>) -> usize {
        let chunks = engine.layers_total().max(1)
            .div_ceil(self.chunk_layers);
        s.req.prompt_len().div_ceil(chunks.max(1)).max(1)
    }

    /// Remaining budget cost of a live prefill — the shortest-remaining-
    /// work sort key: chunks left × per-chunk cost.
    fn remaining_cost(&self, engine: &E, s: &Session<E>) -> usize {
        let (done, total) = engine.prefill_progress(
            s.prefill.as_ref().expect(
                "invariant: sessions in `prefilling` hold a prefill \
                 task"));
        let chunks_left =
            total.saturating_sub(done).div_ceil(self.chunk_layers);
        chunks_left * self.chunk_cost(engine, s)
    }

    /// Advance one chunk of the live prefill at `self.prefilling[i]`:
    /// run the engine, emit `PrefillProgress`, and on completion move
    /// the session to decoding and refill the freed prefill slot.
    /// Engine errors must not drop the session on the floor — its KV
    /// blocks and terminal event would leak (`fail_all` can't see a
    /// taken session) — so the failing session is failed here before
    /// the error propagates.
    fn advance_prefill(&mut self, engine: &mut E, i: usize) -> Result<()> {
        let id = self.prefilling[i].req.id;
        let step = engine.prefill_chunk(
            self.prefilling[i].prefill.as_mut().expect(
                "invariant: sessions in `prefilling` hold a prefill \
                 task"),
            self.chunk_layers);
        let done = match step {
            Ok(d) => d,
            Err(e) => {
                let s = self.prefilling.swap_remove(i);
                self.fail_session(s, &format!("{e:#}"));
                return Err(e);
            }
        };
        let s = &mut self.prefilling[i];
        let (ld, lt) = engine.prefill_progress(s.prefill.as_ref().expect(
            "invariant: sessions in `prefilling` hold a prefill task"));
        s.sink.send(Event::PrefillProgress {
            id,
            layers_done: ld,
            layers_total: lt,
        });
        if done {
            let mut s = self.prefilling.swap_remove(i);
            let task = s.prefill.take().expect(
                "invariant: sessions in `prefilling` hold a prefill \
                 task");
            let max_new = s.req.max_new_tokens
                .min(self.decode_tokens.max(1));
            let (dec, mut stats) = match engine.start_decode(task, max_new) {
                Ok(x) => x,
                Err(e) => {
                    self.fail_session(s, &format!("{e:#}"));
                    return Err(e);
                }
            };
            // The scheduler's block accounting is authoritative for the
            // prefix fields (engines only carry an advisory view).
            stats.prefix_blocks_reused = s.prefix_blocks;
            stats.prefix_tokens_skipped = s.prefix_tokens;
            // Publication point, mirroring the pattern cache: only a
            // *completed* prefill indexes its full prompt chunks (a
            // cancelled one never does).  `s.blocks` is chunk-major —
            // acquire returned the matched chunks in that layout and
            // the fresh suffix blocks extend it — and decode growth
            // lives past the full prompt chunks, so indexed blocks are
            // never written again (no copy-on-write needed on this
            // path; `KvAllocator::make_exclusive` covers engines that
            // do mutate shared tails).
            if let Some(p) = self.prefix.as_mut() {
                p.insert(&s.req.tokens, &s.blocks,
                         engine.layers_total(), &mut self.kv)
                    .expect("invariant: the index only retains blocks \
                             this scheduler handed out");
            }
            self.metrics.record_prefill(&stats);
            self.metrics.prompt_tokens += s.req.prompt_len() as u64;
            s.sink.send(Event::PrefillDone { id, stats: stats.clone() });
            s.stats = Some(stats);
            s.state = SessionState::Decoding;
            s.decode = Some(dec);
            self.decoding.push(s);
            // a prefill slot freed: pull in the next queued prompt
            self.admit(engine, false)?;
        }
        Ok(())
    }

    /// Age every queued session one round and shed the ones past the
    /// admission deadline (`serve.admission.max_queue_rounds`) with a
    /// terminal `DeadlineExceeded` — serving them would only burn
    /// budget on answers nobody is waiting for anymore.
    fn shed_expired(&mut self) {
        let mut i = 0;
        while let Some(s) = self.queue.get_mut(i) {
            s.queued_rounds += 1;
            i += 1;
        }
        if !self.admission.enabled || self.admission.max_queue_rounds == 0 {
            return;
        }
        let limit = self.admission.max_queue_rounds as u64;
        while let Some(s) =
            self.queue.remove_by(|s| s.queued_rounds > limit) {
            let waited = s.queued_rounds;
            self.reject(s, RejectReason::DeadlineExceeded {
                waited_rounds: waited,
                limit_rounds: limit,
            });
        }
    }

    /// Evaluate the degradation ladder for this round: returns the
    /// effective round budget, sets the effective concurrent-prefill
    /// cap, and signals the engine.  Inert (and signalling `false`)
    /// unless `serve.admission.degrade_queue_depth` is set and the
    /// queue is past it.
    fn apply_pressure(&mut self, engine: &mut E) -> usize {
        let pressured = self.admission.enabled
            && self.admission.degrade_queue_depth > 0
            && self.queue.len() >= self.admission.degrade_queue_depth;
        engine.set_pressure(pressured);
        self.cur_max_prefills = if pressured
            && self.admission.degraded_max_prefills > 0 {
            self.max_prefills.min(self.admission.degraded_max_prefills)
        } else {
            self.max_prefills
        };
        if pressured {
            self.metrics.degraded_rounds += 1;
            (self.round_budget
             * self.admission.degraded_budget_pct.min(100) / 100)
                .max(1)
        } else {
            self.round_budget
        }
    }

    /// Run one scheduling round. Returns sessions completed this round.
    pub fn run_round(&mut self, engine: &mut E) -> Result<Vec<Response>> {
        let mut completed = Vec::new();
        self.shed_expired();
        let round_budget = self.apply_pressure(engine);
        self.admit(engine, true)?;
        let track_round = self.has_work();
        let mut budget = round_budget;
        let (mut spent_decode, mut spent_prefill) = (0usize, 0usize);
        let mut ran_ids: Vec<RequestId> = Vec::new();
        loop {
            let mut progressed = false;

            // Decode pass: one token per live session (latency first).
            let mut i = 0;
            while i < self.decoding.len() {
                if budget == 0 {
                    break;
                }
                let s = &mut self.decoding[i];
                match engine.decode_step(s.decode.as_mut().expect(
                    "invariant: sessions in `decoding` hold a decode \
                     session"))? {
                    Some(tok) => {
                        budget -= 1;
                        spent_decode += 1;
                        if s.ttft_us.is_none() {
                            s.ttft_us = Some(
                                s.req.arrived.elapsed().as_micros() as u64);
                        }
                        let index = s.emitted;
                        s.emitted += 1;
                        s.sink.send(Event::Token {
                            id: s.req.id, token: tok, index,
                        });
                        progressed = true;
                        i += 1;
                    }
                    None => {
                        let s = self.decoding.swap_remove(i);
                        completed.push(self.finish(engine, s));
                        progressed = true;
                    }
                }
            }

            // Budgeted prefill pass: one chunk per live prefill whose
            // chunk fits the remaining budget, shortest-remaining-work
            // first.  Over-budget prompts wait for the round-end exempt
            // grant so a mega-chunk cannot crowd out everyone else's
            // within-budget chunks and decode steps.
            let mut order: Vec<(usize, RequestId)> = self.prefilling.iter()
                .map(|s| (self.remaining_cost(engine, s), s.req.id))
                .collect();
            order.sort_unstable();
            for (_, id) in order {
                let Some(i) = self.prefilling.iter()
                    .position(|s| s.req.id == id) else { continue };
                let cost = self.chunk_cost(engine, &self.prefilling[i]);
                if budget < cost {
                    continue; // over budget: round-end grant at best
                }
                budget -= cost;
                spent_prefill += cost;
                progressed = true;
                if !ran_ids.contains(&id) {
                    ran_ids.push(id);
                }
                self.advance_prefill(engine, i)?;
            }

            if !progressed || budget == 0 {
                break;
            }
        }
        // One budget-exempt chunk per round for the longest-starved
        // prefill that got no budgeted chunk — its chunk exceeded what
        // was left of the budget (ties → earliest submission).  Running
        // it after the budgeted work keeps a big chunk from crowding
        // out everyone else's within-budget work, and the
        // `rounds_starved` rotation bounds any skipped prefill's wait
        // to (live skipped prefills − 1) rounds; the round's prefill
        // spend may overshoot the budget by at most this one chunk.
        // With max_concurrent_prefills = 1 this reproduces the old
        // guaranteed-chunk rule chunk-for-chunk.
        let mut spent_exempt = 0usize;
        let exempt = self.prefilling.iter().enumerate()
            .filter(|(_, s)| !ran_ids.contains(&s.req.id))
            .max_by_key(|(_, s)| (s.rounds_starved,
                                  std::cmp::Reverse(s.req.id)))
            .map(|(i, s)| (i, s.req.id));
        if let Some((i, id)) = exempt {
            spent_exempt = self.chunk_cost(engine, &self.prefilling[i]);
            ran_ids.push(id);
            self.advance_prefill(engine, i)?;
        }
        for s in &mut self.prefilling {
            if ran_ids.contains(&s.req.id) {
                s.rounds_starved = 0;
            } else {
                s.rounds_starved += 1;
            }
        }
        if track_round {
            self.metrics.record_round(spent_decode, spent_prefill,
                                      spent_exempt, round_budget);
        }
        Ok(completed)
    }

    /// Retire a decoded-out session: release KV, record metrics, emit
    /// the terminal `Done` event.
    fn finish(&mut self, engine: &E, mut s: Session<E>) -> Response {
        self.release_blocks(&mut s);
        let d = s.decode.take().expect(
            "invariant: sessions in `decoding` hold a decode session");
        let generated = engine.generated(&d).to_vec();
        let decode_us = engine.decode_elapsed_us(&d);
        let stats = s.stats.take().unwrap_or_default();
        // no tokens requested → first "result" is prefill completion
        let ttft_us = s.ttft_us.unwrap_or_else(|| {
            s.req.arrived.elapsed().as_micros() as u64
        });
        self.metrics.decode_us.record_us(decode_us);
        self.metrics.queue_us.record_us(s.queue_us);
        self.metrics.ttft_us.record_us(ttft_us);
        if self.admission.enabled
            && self.admission.interactive_max_tokens > 0 {
            if self.is_interactive(s.req.prompt_len()) {
                self.metrics.interactive_ttft_us.record_us(ttft_us);
            } else {
                self.metrics.batch_ttft_us.record_us(ttft_us);
            }
        }
        self.metrics.generated_tokens += generated.len() as u64;
        self.metrics.requests_completed += 1;
        let response = Response {
            id: s.req.id,
            generated,
            prefill_us: stats.latency_us,
            decode_us,
            queue_us: s.queue_us,
            ttft_us,
            density: stats.density(),
        };
        s.state = SessionState::Done;
        s.sink.send(Event::Done {
            id: s.req.id,
            response: response.clone(),
        });
        self.log_retired(s.req.id);
        response
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::serving::sim::SimEngine;

    #[test]
    fn submit_reject_accounting() {
        let cfg = ServeConfig { queue_capacity: 1, ..Default::default() };
        let engine = SimEngine::new(4);
        let mut s: Scheduler<SimEngine> = Scheduler::new(&cfg);
        assert!(s.submit(&engine, Request::new(0, vec![0; 8], 0),
                         EventSink::null()));
        assert!(!s.submit(&engine, Request::new(1, vec![0; 8], 0),
                          EventSink::null()));
        assert_eq!(s.metrics.requests_rejected, 1);
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn round_completes_sessions_and_frees_kv() {
        let cfg = ServeConfig::default();
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        for i in 0..3 {
            assert!(sched.submit(&engine, Request::new(i, vec![7; 64], 2),
                                 sink.clone()));
        }
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.run_round(&mut engine).unwrap());
        }
        drop(sink);
        assert_eq!(done.len(), 3);
        assert_eq!(sched.metrics.requests_completed, 3);
        assert_eq!(sched.kv.used(), 0, "all kv blocks released");
        for r in &done {
            assert_eq!(r.generated.len(), 2);
        }
        let events: Vec<Event> = rx.iter().collect();
        let dones = events.iter()
            .filter(|e| matches!(e, Event::Done { .. }))
            .count();
        assert_eq!(dones, 3);
    }

    #[test]
    fn repeat_workload_hits_pattern_cache_in_metrics() {
        // serial prefills: the second same-length request begins only
        // after the first published at PrefillDone, so it runs warm and
        // the hit/miss rates surface in the scheduler's metrics
        let cfg = ServeConfig {
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        let mut engine = SimEngine::new(4).with_pattern_cache();
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.submit(&engine, Request::new(0, vec![7; 256], 1),
                     EventSink::null());
        sched.submit(&engine, Request::new(1, vec![7; 256], 1),
                     EventSink::null());
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert_eq!(sched.metrics.requests_completed, 2);
        assert_eq!(sched.metrics.cache_miss_heads, 4, "first request cold");
        assert_eq!(sched.metrics.cache_hit_heads, 4, "second request warm");
        assert!(sched.metrics.cache_hit_rate() > 0.0);
        assert!(sched.metrics.report().contains("pattern cache:"));
        assert_eq!(sched.kv.used(), 0);
    }

    #[test]
    fn prefix_cache_reuses_shared_prompt_blocks() {
        // serialized prefills: the second identical prompt admits only
        // after the first published its chunks, so it adopts both full
        // chunks (2 × 4 layers = 8 blocks) and skips 128 prompt tokens
        let mut cfg = ServeConfig {
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        cfg.prefix_cache.enabled = true;
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.submit(&engine, Request::new(0, vec![7; 128], 2),
                     EventSink::null());
        sched.submit(&engine, Request::new(1, vec![7; 128], 2),
                     EventSink::null());
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.run_round(&mut engine).unwrap());
        }
        assert_eq!(sched.metrics.requests_completed, 2);
        assert_eq!(sched.metrics.prefix_hits, 1, "second request warm");
        assert_eq!(sched.metrics.prefix_blocks_reused, 8);
        assert_eq!(sched.metrics.prefix_tokens_skipped, 128);
        assert!(sched.metrics.report().contains("prefix cache: 1 hits"));
        // prefix reuse must not change outputs
        assert_eq!(done[0].generated, done[1].generated);
        // the index deliberately keeps the prompt chunks alive...
        assert_eq!(sched.prefix_cached_blocks(), 8);
        assert_eq!(sched.kv.used(), 8);
        // ...until flushed, at which point nothing may leak
        sched.flush_prefix_cache();
        assert_eq!(sched.prefix_cached_blocks(), 0);
        assert_eq!(sched.kv.used(), 0, "prefix cache leaked kv blocks");
    }

    #[test]
    fn prefix_cache_off_streams_are_bit_identical() {
        // the knob-off discipline: enabling the cache must not change a
        // single token or terminal payload, only latency and stats
        fn run(enable: bool) -> Vec<String> {
            let mut cfg = ServeConfig {
                max_concurrent_prefills: 1,
                ..Default::default()
            };
            cfg.prefix_cache.enabled = enable;
            let mut engine = SimEngine::new(4);
            let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
            let (sink, rx) = EventSink::channel();
            sched.submit(&engine, Request::new(0, vec![7; 128], 2),
                         sink.clone());
            sched.submit(&engine, Request::new(1, vec![7; 128], 2),
                         sink.clone());
            sched.submit(&engine, Request::new(2, vec![9; 64], 1),
                         sink.clone());
            while sched.has_work() {
                sched.run_round(&mut engine).unwrap();
            }
            drop(sink);
            rx.iter().filter_map(|e| match e {
                Event::Token { id, token, index } => {
                    Some(format!("tok {id} {index} {token}"))
                }
                Event::Done { id, response } => {
                    Some(format!("done {id} {:?}", response.generated))
                }
                _ => None,
            }).collect()
        }
        let off = run(false);
        let on = run(true);
        assert!(!off.is_empty());
        assert_eq!(off, on, "prefix cache changed the output stream");
    }

    #[test]
    fn allocator_pressure_evicts_prefix_retains() {
        // the index holds every block after request 0 retires; a
        // different prompt needing the full allocator must evict the
        // cache's retains rather than wait or be rejected
        let mut cfg = ServeConfig {
            kv_blocks: 16,
            decode_tokens: 0,
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        cfg.prefix_cache.enabled = true;
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        // 256 tokens → 4 chunks × 4 layers = all 16 blocks
        sched.submit(&engine, Request::new(0, vec![7; 256], 0),
                     EventSink::null());
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert_eq!(sched.prefix_cached_blocks(), 16, "index holds all kv");
        sched.submit(&engine, Request::new(1, vec![9; 256], 0),
                     EventSink::null());
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert_eq!(sched.metrics.requests_completed, 2);
        assert_eq!(sched.metrics.requests_rejected, 0,
                   "pressure eviction must spare the admission");
        // the divergent prompt's own chunks are indexed now
        assert_eq!(sched.prefix_cached_blocks(), 16);
        sched.flush_prefix_cache();
        assert_eq!(sched.kv.used(), 0);
    }

    #[test]
    fn warm_prefix_prefill_beats_cold() {
        // with simulated compute attached, the fully-cached repeat
        // prompt must report a strictly cheaper prefill than its cold
        // predecessor (the tentpole's headline effect)
        let mut cfg = ServeConfig {
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        cfg.prefix_cache.enabled = true;
        let mut engine = SimEngine::new(4).with_work(2_000);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.submit(&engine, Request::new(0, vec![7; 256], 1),
                     EventSink::null());
        sched.submit(&engine, Request::new(1, vec![7; 256], 1),
                     EventSink::null());
        let mut done = Vec::new();
        while sched.has_work() {
            done.extend(sched.run_round(&mut engine).unwrap());
        }
        let cold = done.iter().find(|r| r.id == 0).unwrap();
        let warm = done.iter().find(|r| r.id == 1).unwrap();
        assert!(warm.prefill_us < cold.prefill_us,
                "warm {} !< cold {}", warm.prefill_us, cold.prefill_us);
        assert_eq!(sched.metrics.prefix_tokens_skipped, 256);
        sched.flush_prefix_cache();
        assert_eq!(sched.kv.used(), 0);
    }

    #[test]
    fn retirement_log_tracks_terminal_events() {
        let cfg = ServeConfig { queue_capacity: 1, ..Default::default() };
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.track_retirements();
        assert!(sched.submit(&engine, Request::new(0, vec![7; 16], 1),
                             EventSink::null()));
        // queue-full rejection is a terminal event too
        assert!(!sched.submit(&engine, Request::new(1, vec![7; 16], 1),
                              EventSink::null()));
        assert_eq!(sched.take_retired(), vec![1]);
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert_eq!(sched.take_retired(), vec![0]);
        assert!(sched.take_retired().is_empty());
        // off by default: nothing is logged
        let mut quiet: Scheduler<SimEngine> = Scheduler::new(&cfg);
        quiet.submit(&engine, Request::new(0, vec![7; 16], 1),
                     EventSink::null());
        while quiet.has_work() {
            quiet.run_round(&mut engine).unwrap();
        }
        assert!(quiet.take_retired().is_empty());
    }

    #[test]
    fn cancel_unknown_id_is_false() {
        let cfg = ServeConfig::default();
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        assert!(!sched.cancel(99));
    }

    #[test]
    fn engine_refusal_after_pop_terminates_and_frees_kv() {
        // regression for the admit() session leak: the session is out
        // of the queue and holding its KV reservation when the engine
        // refuses it — the refusal must be a terminal Rejected and the
        // blocks must come back, never a silent drop
        let cfg = ServeConfig::default();
        let mut engine = SimEngine::new(4).with_max_prompt(32);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        assert!(sched.submit(&engine, Request::new(0, vec![7; 64], 1),
                             sink.clone()));
        sched.run_round(&mut engine).unwrap();
        drop(sink);
        assert_eq!(sched.metrics.requests_rejected, 1);
        assert_eq!(sched.kv.used(), 0, "refused session must not hold kv");
        assert!(!sched.has_work());
        let events: Vec<Event> = rx.iter().collect();
        assert_eq!(events.len(), 1, "exactly one (terminal) event");
        match &events[0] {
            Event::Rejected { id: 0, reason } => {
                assert_eq!(reason.kind(), "engine-refused");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn failed_sessions_are_errored_and_reconcile() {
        // fail_all must land every live session on the terminal Errored
        // state, bump requests_errored, release KV, and keep the
        // accounting identity: done + rejected + cancelled + errored
        // == submitted
        // small budget: round 1 leaves two sessions mid-prefill and
        // one still queued, so the failure hits every live phase
        let cfg = ServeConfig {
            max_batch_tokens: 64,
            ..Default::default()
        };
        let mut engine = SimEngine::new(8);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        for i in 0..3 {
            assert!(sched.submit(&engine,
                                 Request::new(i, vec![7; 128], 4),
                                 sink.clone()));
        }
        sched.run_round(&mut engine).unwrap();
        assert!(sched.has_work(), "sessions must still be in flight");
        sched.fail_all("engine died");
        drop(sink);
        assert_eq!(sched.metrics.requests_errored, 3);
        assert_eq!(sched.kv.used(), 0, "failed sessions leaked kv");
        assert!(!sched.has_work());
        let m = &sched.metrics;
        assert_eq!(m.requests_completed + m.requests_rejected
                   + m.requests_cancelled + m.requests_errored, 3,
                   "terminal accounting must reconcile with submissions");
        let events: Vec<Event> = rx.iter().collect();
        for id in 0..3u64 {
            let terminals = events.iter()
                .filter(|e| e.id() == id && e.is_terminal())
                .count();
            assert_eq!(terminals, 1, "session {id}: exactly one terminal");
        }
        assert!(sched.metrics.report()
                    .contains("3 errored"),
                "errored count must surface in the report");
    }

    #[test]
    fn queue_depth_shed_spares_interactive_class() {
        let mut cfg = ServeConfig {
            max_batch_requests: 1,
            ..Default::default()
        };
        cfg.admission.enabled = true;
        cfg.admission.max_queue_depth = 2;
        cfg.admission.interactive_max_tokens = 16;
        let engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        // two batch-class requests fill the soft depth limit
        assert!(sched.submit(&engine, Request::new(0, vec![7; 64], 1),
                             sink.clone()));
        assert!(sched.submit(&engine, Request::new(1, vec![7; 64], 1),
                             sink.clone()));
        // third batch request is shed early with QueueDepth...
        assert!(!sched.submit(&engine, Request::new(2, vec![7; 64], 1),
                              sink.clone()));
        // ...but an interactive request may still use the full queue
        assert!(sched.submit(&engine, Request::new(3, vec![7; 8], 1),
                             sink.clone()));
        drop(sink);
        assert_eq!(sched.metrics.shed_queue_depth, 1);
        assert_eq!(sched.metrics.requests_rejected, 1);
        assert_eq!(sched.pending(), 3);
        let shed: Vec<Event> = rx.iter().collect();
        assert_eq!(shed.len(), 1);
        match &shed[0] {
            Event::Rejected { id: 2, reason } => {
                assert_eq!(reason.kind(), "queue-depth");
                assert!(reason.is_transient());
            }
            other => panic!("expected QueueDepth reject, got {other:?}"),
        }
    }

    #[test]
    fn kv_headroom_shed_counts_queued_demand() {
        let mut cfg = ServeConfig {
            kv_blocks: 32,
            decode_tokens: 0,
            ..Default::default()
        };
        cfg.admission.enabled = true;
        cfg.admission.kv_overcommit = 1.0;
        let engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        // each 256-token prompt wants 256/BLOCK_SIZE(=64) * 4 layers =
        // 16 of the 32 blocks — two fit exactly under overcommit 1.0
        assert!(sched.submit(&engine, Request::new(0, vec![7; 256], 0),
                             EventSink::null()));
        assert!(sched.submit(&engine, Request::new(1, vec![7; 256], 0),
                             EventSink::null()));
        // the third exceeds held(0) + queued(32) + need(16) > 32
        let (sink, rx) = EventSink::channel();
        assert!(!sched.submit(&engine, Request::new(2, vec![7; 256], 0),
                              sink.clone()));
        drop(sink);
        assert_eq!(sched.metrics.shed_kv_headroom, 1);
        let shed: Vec<Event> = rx.iter().collect();
        match &shed[0] {
            Event::Rejected { id: 2, reason } => {
                assert_eq!(reason.kind(), "kv-headroom");
            }
            other => panic!("expected KvHeadroom reject, got {other:?}"),
        }
    }

    #[test]
    fn deadline_shed_rejects_stale_queued_sessions() {
        // session 0 needs 4 rounds of prefill (budget 16, chunk cost
        // 16); session 1 is stuck behind max_batch_requests = 1 and
        // must be shed once it has waited past the 2-round deadline
        let mut cfg = ServeConfig {
            max_batch_tokens: 16,
            max_batch_requests: 1,
            chunk_layers: 1,
            ..Default::default()
        };
        cfg.admission.enabled = true;
        cfg.admission.max_queue_rounds = 2;
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        assert!(sched.submit(&engine, Request::new(0, vec![7; 64], 1),
                             sink.clone()));
        assert!(sched.submit(&engine, Request::new(1, vec![7; 64], 1),
                             sink.clone()));
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        drop(sink);
        assert_eq!(sched.metrics.requests_completed, 1);
        assert_eq!(sched.metrics.shed_deadline, 1);
        assert_eq!(sched.kv.used(), 0);
        let events: Vec<Event> = rx.iter().collect();
        let reason = events.iter().find_map(|e| match e {
            Event::Rejected { id: 1, reason } => Some(reason.clone()),
            _ => None,
        }).expect("session 1 must be shed");
        assert_eq!(reason.kind(), "deadline");
        assert!(format!("{reason}").contains("deadline"));
    }

    #[test]
    fn interactive_class_overtakes_queued_batch_work() {
        let mut cfg = ServeConfig {
            max_batch_requests: 1,
            max_concurrent_prefills: 1,
            ..Default::default()
        };
        cfg.admission.enabled = true;
        cfg.admission.interactive_max_tokens = 16;
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        // two batch prompts queue up, then an interactive one arrives
        assert!(sched.submit(&engine, Request::new(0, vec![7; 256], 1),
                             sink.clone()));
        assert!(sched.submit(&engine, Request::new(1, vec![7; 256], 1),
                             sink.clone()));
        assert!(sched.submit(&engine, Request::new(2, vec![7; 8], 1),
                             sink.clone()));
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        drop(sink);
        let done_order: Vec<u64> = rx.iter().filter_map(|e| match e {
            Event::Done { id, .. } => Some(id),
            _ => None,
        }).collect();
        assert_eq!(done_order, vec![0, 2, 1],
                   "interactive request must overtake queued batch work");
        // per-class TTFT histograms both populated
        assert_eq!(sched.metrics.interactive_ttft_us.count(), 1);
        assert_eq!(sched.metrics.batch_ttft_us.count(), 2);
    }

    #[test]
    fn degradation_ladder_engages_under_queue_pressure() {
        let mut cfg = ServeConfig {
            max_batch_tokens: 64,
            max_batch_requests: 2,
            ..Default::default()
        };
        cfg.admission.enabled = true;
        cfg.admission.degrade_queue_depth = 2;
        cfg.admission.degraded_budget_pct = 50;
        cfg.admission.degraded_max_prefills = 1;
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        for i in 0..6 {
            assert!(sched.submit(&engine,
                                 Request::new(i, vec![7; 64], 1),
                                 EventSink::null()));
        }
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert!(sched.metrics.degraded_rounds > 0,
                "queue of 6 over threshold 2 must trigger degradation");
        assert!(sched.metrics.degraded_rounds < sched.metrics.rounds,
                "pressure must lift once the queue drains");
        assert_eq!(sched.metrics.requests_completed, 6,
                   "degraded rounds still complete everything");
        assert_eq!(sched.kv.used(), 0);
    }

    #[test]
    fn round_occupancy_is_recorded() {
        let cfg = ServeConfig::default();
        let mut engine = SimEngine::new(4);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        sched.submit(&engine, Request::new(0, vec![7; 64], 2),
                     EventSink::null());
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        assert!(sched.metrics.rounds > 0);
        let spent = sched.metrics.decode_budget_tokens
            + sched.metrics.prefill_budget_tokens;
        assert!(spent > 0, "budget spend must be accounted");
        // idle rounds with no work at all are not recorded
        let rounds_before = sched.metrics.rounds;
        sched.run_round(&mut engine).unwrap();
        assert_eq!(sched.metrics.rounds, rounds_before);
    }
}
