//! Thread + channel server front-end: clients submit prompts through a
//! [`ServerHandle`] and read back a per-session [`Event`] stream; a
//! worker thread owns the engine (PJRT handles are not `Send`-safe
//! across this crate's wrappers, so the engine lives on its thread and
//! the handle talks over channels — the std-thread analog of the tokio
//! actor pattern this architecture would use with more cores).
//!
//! The worker runs [`Scheduler::run_round`] in a loop, ingesting
//! commands between rounds, so cancellation and new submissions take
//! effect at chunk granularity — a long prompt mid-prefill no longer
//! blocks the command stream.  `Shutdown` drains all in-flight work
//! before the metrics report is released.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;

use anyhow::Result;

use crate::config::{Config, MethodKind};

use super::engine::{EngineBuilder, EngineCore};
use super::request::{Request, RequestId, Response};
use super::scheduler::Scheduler;
use super::session::{EventSink, SessionHandle};

/// Commands accepted by the serving thread.
pub enum Command {
    Submit(Request, EventSink),
    Cancel(RequestId),
    /// Drain all in-flight work, then release the metrics report.
    Shutdown,
}

/// Client handle: submit/cancel sessions, shut the server down.
pub struct ServerHandle {
    pub tx: mpsc::Sender<Command>,
    report: mpsc::Receiver<String>,
    next_id: AtomicU64,
}

impl ServerHandle {
    /// Submit a prompt; returns the per-session event stream.
    pub fn submit(&self, tokens: Vec<i32>, max_new_tokens: usize)
                  -> SessionHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (sink, events) = EventSink::channel();
        let _ = self.tx.send(Command::Submit(
            Request::new(id, tokens, max_new_tokens), sink));
        SessionHandle { id, events }
    }

    /// One-call compatibility path: submit and block until the terminal
    /// event (evals and scripts that don't want to stream).
    pub fn submit_blocking(&self, tokens: Vec<i32>, max_new_tokens: usize)
                           -> Result<Response> {
        self.submit(tokens, max_new_tokens).wait()
    }

    /// Request cancellation of a session in any non-terminal phase; its
    /// stream receives a terminal `Cancelled` event when it lands.
    pub fn cancel(&self, id: RequestId) {
        let _ = self.tx.send(Command::Cancel(id));
    }

    /// Graceful shutdown: drain every in-flight session, then return the
    /// lifetime metrics report.
    pub fn shutdown(self) -> String {
        let _ = self.tx.send(Command::Shutdown);
        self.report.recv().unwrap_or_else(
            |_| "server worker exited without a report".to_string())
    }
}

/// Spawn the serving loop around an engine built by `factory` *on the
/// worker thread* (PJRT client construction included — its handles never
/// cross threads).  Generic over [`EngineCore`] so tests and benches can
/// serve the artifact-free `SimEngine`.
///
/// # Example (artifact-free: serve the simulated engine)
///
/// ```
/// use shareprefill::config::Config;
/// use shareprefill::serving::scheduler::Scheduler;
/// use shareprefill::serving::server::spawn;
/// use shareprefill::serving::sim::SimEngine;
///
/// let serve = Config::default().serve;
/// let handle = spawn(move || {
///     Ok((Scheduler::new(&serve), SimEngine::new(4)))
/// });
/// let response = handle.submit_blocking(vec![7; 64], 2).unwrap();
/// assert_eq!(response.generated.len(), 2);
/// assert!(handle.shutdown().contains("requests: 1 done"));
/// ```
pub fn spawn<E, F>(factory: F) -> ServerHandle
where
    E: EngineCore + 'static,
    F: FnOnce() -> Result<(Scheduler<E>, E)> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Command>();
    let (rep_tx, rep_rx) = mpsc::channel::<String>();
    // thread creation goes through exec (layering: `std::thread` is
    // exec's alone — pallas-lint enforces it)
    crate::exec::spawn_worker("serving-engine", move || {
        let (mut sched, mut engine) = match factory() {
            Ok(x) => x,
            Err(e) => {
                let _ = rep_tx.send(format!("engine init failed: {e:#}"));
                return;
            }
        };
        let mut shutting_down = false;
        loop {
            // ingest commands (blocking only when fully idle)
            loop {
                let cmd = if !sched.has_work() && !shutting_down {
                    match rx.recv() {
                        Ok(c) => c,
                        Err(_) => {
                            // all handles dropped: drain and exit
                            shutting_down = true;
                            break;
                        }
                    }
                } else {
                    match rx.try_recv() {
                        Ok(c) => c,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            shutting_down = true;
                            break;
                        }
                    }
                };
                match cmd {
                    Command::Submit(r, sink) => {
                        sched.submit(&engine, r, sink);
                    }
                    Command::Cancel(id) => {
                        sched.cancel(id);
                    }
                    Command::Shutdown => shutting_down = true,
                }
            }
            if let Err(e) = sched.run_round(&mut engine) {
                // terminal engine failure: every live session gets an
                // Error event so no client hangs
                sched.fail_all(&format!("{e:#}"));
                let _ = rep_tx.send(format!("engine error: {e:#}"));
                return;
            }
            if shutting_down && !sched.has_work() {
                // release the prefix index's retains so the report's
                // world ends with every KV block accounted for
                sched.flush_prefix_cache();
                let _ = rep_tx.send(sched.metrics.report());
                return;
            }
        }
    });
    ServerHandle { tx, report: rep_rx, next_id: AtomicU64::new(0) }
}

/// Builder-style server construction: one typed entry point from
/// [`Config`] to a running server, replacing the ad-hoc closure+tuple
/// wiring each caller used to repeat.
///
/// # Example (needs compiled model artifacts at runtime)
///
/// ```no_run
/// use shareprefill::config::MethodKind;
/// use shareprefill::serving::ServerBuilder;
///
/// let mut fleet = ServerBuilder::new()
///     .model("sim-llama")
///     .method(MethodKind::SharePrefill)
///     .workers(4)
///     .prefix_cache(true)
///     .spawn_fleet();
/// let session = fleet.submit(vec![1, 2, 3], 8);
/// let response = session.wait().unwrap();
/// println!("{} tokens, report:\n{}", response.generated.len(),
///          fleet.shutdown());
/// ```
pub struct ServerBuilder {
    config: Config,
    model: String,
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            config: Config::default(),
            model: "sim-llama".to_string(),
        }
    }

    /// Replace the whole config (method + serve + paths).
    pub fn config(mut self, cfg: Config) -> ServerBuilder {
        self.config = cfg;
        self
    }

    pub fn model(mut self, model: &str) -> ServerBuilder {
        self.model = model.to_string();
        self
    }

    /// Override just the method kind.
    pub fn method(mut self, kind: MethodKind) -> ServerBuilder {
        self.config.method.kind = kind;
        self
    }

    /// Layers advanced per prefill chunk (1 = finest interleaving).
    pub fn chunk_layers(mut self, n: usize) -> ServerBuilder {
        self.config.serve.chunk_layers = n.max(1);
        self
    }

    /// Prefills the scheduler interleaves concurrently (1 = the old
    /// strictly-serial prefill pipeline).
    pub fn max_concurrent_prefills(mut self, n: usize) -> ServerBuilder {
        self.config.serve.max_concurrent_prefills = n.max(1);
        self
    }

    /// Decode-step cap per request.
    pub fn decode_tokens(mut self, n: usize) -> ServerBuilder {
        self.config.serve.decode_tokens = n;
        self
    }

    /// Head-parallel prefill workers (`serve.workers`; 1 = serial,
    /// any `N` is bit-identical to it).
    pub fn workers(mut self, n: usize) -> ServerBuilder {
        self.config.serve.workers = n.max(1);
        self
    }

    /// Toggle the cross-request pattern cache (keeps the other
    /// `serve.pattern_cache` knobs).
    pub fn pattern_cache(mut self, enabled: bool) -> ServerBuilder {
        self.config.serve.pattern_cache.enabled = enabled;
        self
    }

    /// Toggle content-addressed prefix sharing — repeat or extended
    /// prompts adopt cached KV blocks and prefill only their divergent
    /// suffix (keeps the other `serve.prefix_cache` knobs).
    pub fn prefix_cache(mut self, enabled: bool) -> ServerBuilder {
        self.config.serve.prefix_cache.enabled = enabled;
        self
    }

    /// Engine shards behind the fleet front door (`serve.shards`;
    /// 1 = the plain single-engine server path).
    pub fn shards(mut self, n: usize) -> ServerBuilder {
        self.config.serve.shards = n.max(1);
        self
    }

    /// Spawn with the real artifact-backed engine (built on the worker
    /// thread via [`EngineBuilder`]).
    pub fn spawn(self) -> ServerHandle {
        let ServerBuilder { config, model } = self;
        let serve = config.serve.clone();
        spawn(move || {
            let registry = crate::runtime::open_registry(&config)?;
            let engine = EngineBuilder::new(registry, &model)
                .method_config(config.method.clone())
                .pattern_cache(config.serve.pattern_cache.clone())
                .workers(config.serve.workers)
                .build()?;
            Ok((Scheduler::new(&serve), engine))
        })
    }

    /// Spawn `serve.shards` artifact-backed engines behind the fleet
    /// front door.  Each shard builds its own engine *on its own
    /// thread* (PJRT handles never cross threads); `serve.shards = 1`
    /// returns the plain single-engine path unchanged.
    pub fn spawn_fleet(self) -> super::fleet::FleetHandle {
        let ServerBuilder { config, model } = self;
        let shards = config.serve.shards;
        let prefix_on = config.serve.prefix_cache.enabled;
        let serve = config.serve.clone();
        let mut handle = super::fleet::spawn_fleet(shards, move |_shard| {
            let registry = crate::runtime::open_registry(&config)?;
            let engine = EngineBuilder::new(registry, &model)
                .method_config(config.method.clone())
                .pattern_cache(config.serve.pattern_cache.clone())
                .workers(config.serve.workers)
                .build()?;
            Ok((Scheduler::new(&serve), engine))
        });
        if prefix_on {
            // co-locate same-prefix sessions with their cached blocks
            handle.enable_prefix_affinity();
        }
        handle
    }
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}
