//! Thread + channel server front-end: clients submit [`Request`]s through
//! an mpsc sender; a worker thread owns the engine (PJRT handles are not
//! `Send`-safe across this crate's wrappers, so the engine lives on its
//! thread and the handle talks over channels — the std-thread analog of
//! the tokio actor pattern this architecture would use with more cores).

use std::sync::mpsc;
use std::time::Duration;

use super::request::{Request, Response};

/// Commands accepted by the serving thread.
pub enum Command {
    Submit(Request),
    /// Drain the queue, then send a metrics report and stop.
    Shutdown,
}

/// Client handle.
pub struct ServerHandle {
    pub tx: mpsc::Sender<Command>,
    pub responses: mpsc::Receiver<Response>,
    pub report: mpsc::Receiver<String>,
}

impl ServerHandle {
    pub fn submit(&self, r: Request) {
        let _ = self.tx.send(Command::Submit(r));
    }

    pub fn shutdown_and_report(self) -> (Vec<Response>, String) {
        let _ = self.tx.send(Command::Shutdown);
        let mut out = Vec::new();
        // collect whatever is in flight until the report arrives
        loop {
            match self.responses.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => out.push(r),
                Err(_) => {
                    if let Ok(rep) = self.report.try_recv() {
                        // drain any stragglers
                        while let Ok(r) = self.responses.try_recv() {
                            out.push(r);
                        }
                        return (out, rep);
                    }
                }
            }
        }
    }
}

/// Spawn the serving loop. `make_engine` runs on the worker thread (PJRT
/// client construction included) — errors surface through the report
/// channel.
pub fn spawn<F>(make_engine: F) -> ServerHandle
where
    F: FnOnce() -> anyhow::Result<(super::scheduler::Scheduler,
                                   super::engine::Engine)>
        + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Command>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let (rep_tx, rep_rx) = mpsc::channel::<String>();
    std::thread::spawn(move || {
        let (mut sched, mut engine) = match make_engine() {
            Ok(x) => x,
            Err(e) => {
                let _ = rep_tx.send(format!("engine init failed: {e:#}"));
                return;
            }
        };
        let mut shutting_down = false;
        loop {
            // ingest commands (non-blocking when work is pending)
            loop {
                let cmd = if sched.pending() == 0 && !shutting_down {
                    match rx.recv() {
                        Ok(c) => c,
                        Err(_) => return,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(c) => c,
                        Err(_) => break,
                    }
                };
                match cmd {
                    Command::Submit(r) => {
                        sched.submit(r);
                    }
                    Command::Shutdown => shutting_down = true,
                }
            }
            match sched.run_round(&mut engine) {
                Ok(rs) => {
                    for r in rs {
                        let _ = resp_tx.send(r);
                    }
                }
                Err(e) => {
                    let _ = rep_tx.send(format!("engine error: {e:#}"));
                    return;
                }
            }
            if shutting_down && sched.pending() == 0 {
                let _ = rep_tx.send(sched.metrics.report());
                return;
            }
        }
    });
    ServerHandle { tx, responses: resp_rx, report: rep_rx }
}
