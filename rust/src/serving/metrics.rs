//! Serving metrics: request latency histograms (TTFT, prefill, decode,
//! queueing), throughput counters and pattern-distribution aggregation
//! across requests.

use crate::util::stats::{Histogram, Summary};

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub requests_cancelled: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub prefill_us: Histogram,
    pub decode_us: Histogram,
    pub queue_us: Histogram,
    /// Arrival → first token, per request (the continuous-batching
    /// headline: long prompts must not inflate everyone else's TTFT).
    pub ttft_us: Histogram,
    pub density: Summary,
    pub dense_heads: u64,
    pub shared_heads: u64,
    pub vslash_heads: u64,
    pub query_aware_heads: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_prefill(&mut self, stats: &super::engine::PrefillStats) {
        self.prefill_us.record_us(stats.latency_us);
        self.density.add(stats.density());
        self.dense_heads += stats.dense as u64;
        self.shared_heads += stats.shared as u64;
        self.vslash_heads += stats.vslash as u64;
        self.query_aware_heads += stats.query_aware as u64;
    }

    /// Tokens per second over the lifetime prompt tokens.
    pub fn prefill_throughput(&self) -> f64 {
        let total_us: f64 =
            self.prefill_us.mean_us() * self.prefill_us.count() as f64;
        if total_us == 0.0 {
            0.0
        } else {
            self.prompt_tokens as f64 / (total_us / 1e6)
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} done, {} rejected, {} cancelled\n\
             tokens: {} prompt, {} generated\n\
             ttft:    mean {:.1} ms, p99 ≤ {:.1} ms ({} samples)\n\
             prefill: mean {:.1} ms, p99 ≤ {:.1} ms ({} samples)\n\
             decode:  mean {:.1} ms\n\
             queue:   mean {:.2} ms\n\
             density: mean {:.3} (computed/causal blocks)\n\
             patterns: dense {}, shared {}, vslash {}, query-aware {}\n\
             prefill throughput: {:.0} tok/s",
            self.requests_completed, self.requests_rejected,
            self.requests_cancelled,
            self.prompt_tokens, self.generated_tokens,
            self.ttft_us.mean_us() / 1e3,
            self.ttft_us.quantile_us(0.99) as f64 / 1e3,
            self.ttft_us.count(),
            self.prefill_us.mean_us() / 1e3,
            self.prefill_us.quantile_us(0.99) as f64 / 1e3,
            self.prefill_us.count(),
            self.decode_us.mean_us() / 1e3,
            self.queue_us.mean_us() / 1e3,
            self.density.mean(),
            self.dense_heads, self.shared_heads, self.vslash_heads,
            self.query_aware_heads,
            self.prefill_throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::engine::PrefillStats;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        let mut s = PrefillStats::default();
        s.latency_us = 5_000;
        s.blocks_total = 10;
        s.blocks_computed = 5;
        s.shared = 3;
        m.record_prefill(&s);
        m.requests_completed = 1;
        m.prompt_tokens = 1024;
        m.ttft_us.record_us(6_000);
        let r = m.report();
        assert!(r.contains("shared 3"));
        assert!(r.contains("ttft"));
        assert!(m.prefill_throughput() > 0.0);
        assert!((m.density.mean() - 0.5).abs() < 1e-12);
    }
}
