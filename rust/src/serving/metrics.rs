//! Serving metrics: request latency histograms (TTFT, prefill, decode,
//! queueing), throughput counters, per-round budget occupancy (where do
//! the round's tokens actually go — decode, prefill, or idle?) and
//! pattern-distribution aggregation across requests.

use crate::util::stats::{Histogram, Summary};

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub requests_cancelled: u64,
    /// Sessions that got a terminal `Error` event (engine failure or a
    /// fleet-synthesized abort).  Without this the summary cannot
    /// reconcile: completed + rejected + cancelled + errored must equal
    /// submitted.
    pub requests_errored: u64,
    /// Admission-control sheds by cause (each also counts in
    /// `requests_rejected`); all zero with admission control disabled.
    pub shed_queue_depth: u64,
    pub shed_kv_headroom: u64,
    pub shed_deadline: u64,
    /// Rounds run with the degradation ladder engaged (shrunk budget /
    /// capped prefills under queue pressure).
    pub degraded_rounds: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub prefill_us: Histogram,
    pub decode_us: Histogram,
    pub queue_us: Histogram,
    /// Arrival → first token, per request (the continuous-batching
    /// headline: long prompts must not inflate everyone else's TTFT).
    pub ttft_us: Histogram,
    /// TTFT split by request class when admission control defines one
    /// (`serve.admission.interactive_max_tokens`): short interactive
    /// prompts vs everything else.  Both empty with classes disabled —
    /// `ttft_us` above always holds the combined picture.
    pub interactive_ttft_us: Histogram,
    pub batch_ttft_us: Histogram,
    pub density: Summary,
    pub dense_heads: u64,
    pub shared_heads: u64,
    pub vslash_heads: u64,
    pub query_aware_heads: u64,
    /// Cross-request pattern cache outcomes per head (all zero with the
    /// cache disabled): validated reuses, cold misses, and validation
    /// failures (invalidations) that fell back to exact computation.
    pub cache_hit_heads: u64,
    pub cache_miss_heads: u64,
    pub cache_rejected_heads: u64,
    /// Prefix-cache outcomes (all zero with `serve.prefix_cache` off):
    /// completed prefills that adopted at least one shared chunk, the
    /// KV blocks they adopted instead of recomputing, and the prompt
    /// tokens those chunks covered (the prefill started past them).
    pub prefix_hits: u64,
    pub prefix_blocks_reused: u64,
    pub prefix_tokens_skipped: u64,
    /// Scheduling rounds that had (or could have had) work.
    pub rounds: u64,
    /// Round-budget tokens spent on decode steps (1 per token).
    pub decode_budget_tokens: u64,
    /// Tokens spent on prefill chunks, budgeted + the round-end
    /// budget-exempt chunk (so this may exceed `rounds × budget`).
    pub prefill_budget_tokens: u64,
    /// Round-budget tokens left unspent by budgeted work (exempt-chunk
    /// overshoot never masks unused budget).
    pub idle_budget_tokens: u64,
    /// Head-parallel worker pool usage aggregated across prefills
    /// (all zero until a prefill with pool accounting completes):
    /// fan-out rounds, items sharded, and the summed busiest-shard
    /// item count per round (the critical path in items).
    pub pool_rounds: u64,
    pub pool_items: u64,
    pub pool_span_items: u64,
    /// Pool width the engine runs at (max observed; 0 = unknown/serial
    /// engines that report no pool usage).
    pub pool_workers: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_prefill(&mut self, stats: &super::engine::PrefillStats) {
        self.prefill_us.record_us(stats.latency_us);
        self.density.add(stats.density());
        self.dense_heads += stats.dense as u64;
        self.shared_heads += stats.shared as u64;
        self.vslash_heads += stats.vslash as u64;
        self.query_aware_heads += stats.query_aware as u64;
        self.cache_hit_heads += stats.cache_hits as u64;
        self.cache_miss_heads += stats.cache_misses as u64;
        self.cache_rejected_heads += stats.cache_rejected as u64;
        if stats.prefix_blocks_reused > 0 {
            self.prefix_hits += 1;
        }
        self.prefix_blocks_reused += stats.prefix_blocks_reused as u64;
        self.prefix_tokens_skipped += stats.prefix_tokens_skipped as u64;
        self.pool_rounds += stats.pool_rounds as u64;
        self.pool_items += stats.pool_items as u64;
        self.pool_span_items += stats.pool_span_items as u64;
        self.pool_workers = self.pool_workers.max(stats.pool_workers as u64);
    }

    /// Merge another engine's lifetime metrics into this one (the
    /// fleet's shard aggregation): counters add, histograms merge
    /// bucket-for-bucket, the density summary concatenates samples, and
    /// the pool width takes the max (shards share one configured
    /// width — a mixed fleet reports the widest).
    pub fn absorb(&mut self, other: &Metrics) {
        self.requests_completed += other.requests_completed;
        self.requests_rejected += other.requests_rejected;
        self.requests_cancelled += other.requests_cancelled;
        self.requests_errored += other.requests_errored;
        self.shed_queue_depth += other.shed_queue_depth;
        self.shed_kv_headroom += other.shed_kv_headroom;
        self.shed_deadline += other.shed_deadline;
        self.degraded_rounds += other.degraded_rounds;
        self.prompt_tokens += other.prompt_tokens;
        self.generated_tokens += other.generated_tokens;
        self.prefill_us.absorb(&other.prefill_us);
        self.decode_us.absorb(&other.decode_us);
        self.queue_us.absorb(&other.queue_us);
        self.ttft_us.absorb(&other.ttft_us);
        self.interactive_ttft_us.absorb(&other.interactive_ttft_us);
        self.batch_ttft_us.absorb(&other.batch_ttft_us);
        self.density.absorb(&other.density);
        self.dense_heads += other.dense_heads;
        self.shared_heads += other.shared_heads;
        self.vslash_heads += other.vslash_heads;
        self.query_aware_heads += other.query_aware_heads;
        self.cache_hit_heads += other.cache_hit_heads;
        self.cache_miss_heads += other.cache_miss_heads;
        self.cache_rejected_heads += other.cache_rejected_heads;
        self.prefix_hits += other.prefix_hits;
        self.prefix_blocks_reused += other.prefix_blocks_reused;
        self.prefix_tokens_skipped += other.prefix_tokens_skipped;
        self.rounds += other.rounds;
        self.decode_budget_tokens += other.decode_budget_tokens;
        self.prefill_budget_tokens += other.prefill_budget_tokens;
        self.idle_budget_tokens += other.idle_budget_tokens;
        self.pool_rounds += other.pool_rounds;
        self.pool_items += other.pool_items;
        self.pool_span_items += other.pool_span_items;
        self.pool_workers = self.pool_workers.max(other.pool_workers);
    }

    /// Count-based worker occupancy in `[0, 1]` across all recorded
    /// prefills: items sharded / (critical-path items × pool width).
    /// 1.0 with no recorded fan-outs (a serial engine is fully
    /// occupied by definition); the shortfall from 1.0 is the per-round
    /// shard imbalance — idle worker slots while the busiest shard
    /// finishes.
    pub fn worker_occupancy(&self) -> f64 {
        let denom = self.pool_span_items * self.pool_workers.max(1);
        if denom == 0 {
            return 1.0;
        }
        self.pool_items as f64 / denom as f64
    }

    /// Fraction of cache-consulting heads that reused a cached pattern;
    /// 0.0 before any cache-on prefill completed.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hit_heads + self.cache_miss_heads
            + self.cache_rejected_heads;
        if total == 0 {
            0.0
        } else {
            self.cache_hit_heads as f64 / total as f64
        }
    }

    /// Fraction of all lifetime prompt tokens the prefix cache let
    /// prefills start past (0.0 before any prompt completed, and with
    /// `serve.prefix_cache` off).
    pub fn prefix_skip_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            0.0
        } else {
            self.prefix_tokens_skipped as f64 / self.prompt_tokens as f64
        }
    }

    /// Account one scheduling round's budget spend: `decode` tokens on
    /// decode steps, `prefill` tokens on budgeted prefill chunks, and
    /// `exempt` tokens on the round-end budget-exempt chunk.  Idle is
    /// what the *budget* left unspent — the exempt chunk runs outside
    /// the budget, so it counts as prefill work but cannot mask budget
    /// tokens that genuinely went unused.
    pub fn record_round(&mut self, decode: usize, prefill: usize,
                        exempt: usize, budget: usize) {
        self.rounds += 1;
        self.decode_budget_tokens += decode as u64;
        self.prefill_budget_tokens += (prefill + exempt) as u64;
        self.idle_budget_tokens +=
            budget.saturating_sub(decode + prefill) as u64;
    }

    /// Budget occupancy fractions `(decode, prefill, idle)` across all
    /// recorded rounds; zeros before any round ran.
    pub fn occupancy(&self) -> (f64, f64, f64) {
        let total = (self.decode_budget_tokens + self.prefill_budget_tokens
                     + self.idle_budget_tokens) as f64;
        if total == 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (self.decode_budget_tokens as f64 / total,
         self.prefill_budget_tokens as f64 / total,
         self.idle_budget_tokens as f64 / total)
    }

    /// Tokens per second over the lifetime prompt tokens.
    pub fn prefill_throughput(&self) -> f64 {
        let total_us: f64 =
            self.prefill_us.mean_us() * self.prefill_us.count() as f64;
        if total_us == 0.0 {
            0.0
        } else {
            self.prompt_tokens as f64 / (total_us / 1e6)
        }
    }

    /// Total admission-control sheds (each also counted in
    /// `requests_rejected`).
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_depth + self.shed_kv_headroom + self.shed_deadline
    }

    pub fn report(&self) -> String {
        let (occ_d, occ_p, occ_i) = self.occupancy();
        format!(
            "requests: {} done, {} rejected, {} cancelled, {} errored\n\
             admission: {} shed (depth {}, headroom {}, deadline {}), \
             {} degraded rounds\n\
             classes: interactive ttft p99 ≤ {:.1} ms ({} samples), \
             batch ttft p99 ≤ {:.1} ms ({} samples)\n\
             tokens: {} prompt, {} generated\n\
             ttft:    mean {:.1} ms, p99 ≤ {:.1} ms ({} samples)\n\
             prefill: mean {:.1} ms, p99 ≤ {:.1} ms ({} samples)\n\
             decode:  mean {:.1} ms\n\
             queue:   mean {:.2} ms\n\
             density: mean {:.3} (computed/causal blocks)\n\
             patterns: dense {}, shared {}, vslash {}, query-aware {}\n\
             pattern cache: {} hits, {} misses, {} invalidated \
             ({:.0}% hit rate)\n\
             prefix cache: {} hits, {} blocks reused, {:.0}% prefill \
             skipped\n\
             workers: {} ({} fan-out rounds, {} items, occupancy \
             {:.0}%, imbalance {:.0}%)\n\
             rounds:  {} (budget occupancy: {:.0}% decode, {:.0}% \
             prefill, {:.0}% idle)\n\
             prefill throughput: {:.0} tok/s",
            self.requests_completed, self.requests_rejected,
            self.requests_cancelled, self.requests_errored,
            self.shed_total(), self.shed_queue_depth,
            self.shed_kv_headroom, self.shed_deadline,
            self.degraded_rounds,
            self.interactive_ttft_us.quantile_us(0.99) as f64 / 1e3,
            self.interactive_ttft_us.count(),
            self.batch_ttft_us.quantile_us(0.99) as f64 / 1e3,
            self.batch_ttft_us.count(),
            self.prompt_tokens, self.generated_tokens,
            self.ttft_us.mean_us() / 1e3,
            self.ttft_us.quantile_us(0.99) as f64 / 1e3,
            self.ttft_us.count(),
            self.prefill_us.mean_us() / 1e3,
            self.prefill_us.quantile_us(0.99) as f64 / 1e3,
            self.prefill_us.count(),
            self.decode_us.mean_us() / 1e3,
            self.queue_us.mean_us() / 1e3,
            self.density.mean(),
            self.dense_heads, self.shared_heads, self.vslash_heads,
            self.query_aware_heads,
            self.cache_hit_heads, self.cache_miss_heads,
            self.cache_rejected_heads, self.cache_hit_rate() * 100.0,
            self.prefix_hits, self.prefix_blocks_reused,
            self.prefix_skip_rate() * 100.0,
            self.pool_workers.max(1), self.pool_rounds, self.pool_items,
            self.worker_occupancy() * 100.0,
            (1.0 - self.worker_occupancy()) * 100.0,
            self.rounds, occ_d * 100.0, occ_p * 100.0, occ_i * 100.0,
            self.prefill_throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::engine::PrefillStats;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        let s = PrefillStats {
            latency_us: 5_000,
            blocks_total: 10,
            blocks_computed: 5,
            shared: 3,
            ..Default::default()
        };
        m.record_prefill(&s);
        m.requests_completed = 1;
        m.prompt_tokens = 1024;
        m.ttft_us.record_us(6_000);
        let r = m.report();
        assert!(r.contains("shared 3"));
        assert!(r.contains("ttft"));
        assert!(r.contains("budget occupancy"));
        assert!(m.prefill_throughput() > 0.0);
        assert!((m.density.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_rates_in_report() {
        let mut m = Metrics::new();
        assert_eq!(m.cache_hit_rate(), 0.0);
        let s = PrefillStats {
            cache_hits: 3,
            cache_misses: 1,
            ..Default::default()
        };
        m.record_prefill(&s);
        let s2 = PrefillStats {
            cache_rejected: 2,
            ..Default::default()
        };
        m.record_prefill(&s2);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("pattern cache: 3 hits, 1 misses, 2 \
                            invalidated (50% hit rate)"),
                "cache line missing from report: {r}");
    }

    #[test]
    fn prefix_counters_record_absorb_and_report() {
        let mut m = Metrics::new();
        assert_eq!(m.prefix_skip_rate(), 0.0);
        m.prompt_tokens = 256;
        m.record_prefill(&PrefillStats {
            prefix_blocks_reused: 8,
            prefix_tokens_skipped: 128,
            ..Default::default()
        });
        m.record_prefill(&PrefillStats::default()); // cold: no hit
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefix_blocks_reused, 8);
        assert!((m.prefix_skip_rate() - 0.5).abs() < 1e-12);
        let mut other = Metrics::new();
        other.prompt_tokens = 0;
        other.prefix_hits = 2;
        other.prefix_blocks_reused = 4;
        other.prefix_tokens_skipped = 64;
        m.absorb(&other);
        assert_eq!(m.prefix_hits, 3);
        assert_eq!(m.prefix_blocks_reused, 12);
        let r = m.report();
        assert!(r.contains("prefix cache: 3 hits, 12 blocks reused, \
                            75% prefill skipped"),
                "prefix line missing from report: {r}");
    }

    #[test]
    fn worker_occupancy_aggregates_pool_usage() {
        let mut m = Metrics::new();
        // no pool usage recorded: serial engines read as fully occupied
        assert_eq!(m.worker_occupancy(), 1.0);
        // 2 rounds of 6 items over 4 workers: span 2 per round
        let s = PrefillStats {
            pool_rounds: 2,
            pool_items: 12,
            pool_span_items: 4,
            pool_workers: 4,
            ..Default::default()
        };
        m.record_prefill(&s);
        assert!((m.worker_occupancy() - 12.0 / 16.0).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("workers: 4 (2 fan-out rounds, 12 items"),
                "worker line missing from report: {r}");
        assert!(r.contains("occupancy 75%"), "occupancy wrong: {r}");
    }

    #[test]
    fn absorb_merges_shard_metrics() {
        let mut a = Metrics::new();
        a.requests_completed = 2;
        a.prompt_tokens = 100;
        a.cache_hit_heads = 3;
        a.ttft_us.record_us(1_000);
        a.density.add(0.5);
        a.record_round(4, 2, 0, 8);
        a.pool_workers = 2;
        let mut b = Metrics::new();
        b.requests_completed = 1;
        b.requests_rejected = 1;
        b.prompt_tokens = 50;
        b.cache_miss_heads = 1;
        b.ttft_us.record_us(3_000);
        b.density.add(1.0);
        b.record_round(1, 1, 1, 8);
        b.pool_workers = 4;
        a.absorb(&b);
        assert_eq!(a.requests_completed, 3);
        assert_eq!(a.requests_rejected, 1);
        assert_eq!(a.prompt_tokens, 150);
        assert_eq!(a.ttft_us.count(), 2);
        assert!((a.ttft_us.mean_us() - 2_000.0).abs() < 1e-9);
        assert_eq!(a.density.count(), 2);
        assert_eq!(a.rounds, 2);
        assert_eq!(a.prefill_budget_tokens, 4);
        assert_eq!(a.pool_workers, 4, "widest shard wins");
        assert!((a.cache_hit_rate() - 0.75).abs() < 1e-12);
        let r = a.report();
        assert!(r.contains("requests: 3 done, 1 rejected, 0 cancelled"));
    }

    #[test]
    fn errored_and_shed_counters_merge_and_report() {
        let mut a = Metrics::new();
        a.requests_completed = 2;
        a.requests_errored = 1;
        a.shed_queue_depth = 2;
        a.interactive_ttft_us.record_us(1_000);
        let mut b = Metrics::new();
        b.requests_errored = 2;
        b.shed_kv_headroom = 1;
        b.shed_deadline = 3;
        b.degraded_rounds = 4;
        b.batch_ttft_us.record_us(9_000);
        a.absorb(&b);
        assert_eq!(a.requests_errored, 3);
        assert_eq!(a.shed_total(), 6);
        assert_eq!(a.degraded_rounds, 4);
        assert_eq!(a.interactive_ttft_us.count(), 1);
        assert_eq!(a.batch_ttft_us.count(), 1);
        let r = a.report();
        assert!(r.contains("requests: 2 done, 0 rejected, 0 cancelled, \
                            3 errored"),
                "errored missing from report: {r}");
        assert!(r.contains("admission: 6 shed (depth 2, headroom 1, \
                            deadline 3), 4 degraded rounds"),
                "admission line missing from report: {r}");
        assert!(r.contains("classes: interactive"),
                "class line missing from report: {r}");
    }

    #[test]
    fn round_occupancy_accounting() {
        let mut m = Metrics::new();
        assert_eq!(m.occupancy(), (0.0, 0.0, 0.0));
        m.record_round(4, 2, 0, 8); // 2 idle
        // exempt-only round: the 10-token chunk ran outside the budget,
        // so all 8 budget tokens were genuinely idle
        m.record_round(0, 0, 10, 8);
        assert_eq!(m.rounds, 2);
        assert_eq!(m.decode_budget_tokens, 4);
        assert_eq!(m.prefill_budget_tokens, 12);
        assert_eq!(m.idle_budget_tokens, 10);
        let (d, p, i) = m.occupancy();
        assert!((d + p + i - 1.0).abs() < 1e-12);
        assert!((d - 4.0 / 26.0).abs() < 1e-12);
    }
}
