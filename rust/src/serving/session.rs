//! Session lifecycle + streamed per-request events.
//!
//! A submitted request becomes a *session* that walks the lifecycle
//! `Queued → Prefilling → Decoding → Done | Cancelled | Rejected`,
//! emitting [`Event`]s on its own channel as it goes: `PrefillProgress`
//! per chunk, `PrefillDone` with the full [`PrefillStats`] (this is the
//! TTFT-relevant moment), one `Token` per decoded token, and exactly one
//! terminal event (`Done`, `Cancelled`, `Rejected`, or `Error`) — clients
//! never hang waiting on a dropped request.
//!
//! # Stream contract
//!
//! Every session's stream obeys three invariants the server tests (and
//! the serving fuzzer) hold it to: *exactly one* terminal event, always
//! last; `Token` events indexed contiguously from 0; `PrefillDone`
//! before the first `Token`.  Driving a stream by hand:
//!
//! ```
//! use shareprefill::serving::session::{Event, EventSink, SessionHandle};
//!
//! let (sink, rx) = EventSink::channel();
//! let handle = SessionHandle { id: 7, events: rx };
//! sink.send(Event::Token { id: 7, token: 42, index: 0 });
//! sink.send(Event::Cancelled { id: 7 });
//! let events = handle.collect(); // stops at the terminal event
//! assert_eq!(events.len(), 2);
//! assert!(events.last().is_some_and(|e| e.is_terminal()));
//! ```

use anyhow::{bail, Result};
use std::fmt;
use std::sync::mpsc;

use super::engine::PrefillStats;
use super::request::{RequestId, Response};

/// Lifecycle state of a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    Queued,
    Prefilling,
    Decoding,
    Done,
    Cancelled,
    Rejected,
    /// Terminal: the engine failed while serving this session (the
    /// stream got an `Error` event).
    Errored,
}

/// Why a session was refused admission (carried by the terminal
/// `Rejected` event).  Structured so clients can tell a transient
/// capacity condition (KV starvation under load — retry later) from a
/// request that can never succeed (empty/oversized prompt — fix it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// Admission queue at capacity.
    QueueFull,
    /// Zero-token prompt: nothing to prefill or condition on.
    EmptyPrompt,
    /// The KV allocator could not reserve the request's whole-lifetime
    /// block count within the bounded re-queue budget.
    KvExhausted { blocks_needed: usize, retries: usize },
    /// The engine refused the prompt at `begin_prefill` (e.g. it
    /// exceeds the largest compiled seq bucket).
    EngineRefused { message: String },
    /// Admission control shed the request at submit: the queue is
    /// deeper than `serve.admission.max_queue_depth` (early back-
    /// pressure well before the hard `QueueFull` wall).
    QueueDepth { depth: usize, limit: usize },
    /// Admission control shed the request at submit: its whole-lifetime
    /// KV reservation would push committed demand (held + queued) past
    /// the configured overcommit headroom.
    KvHeadroom { blocks_needed: usize, committed: usize,
                 capacity: usize },
    /// The request waited in the admission queue longer than its
    /// deadline (`serve.admission.max_queue_rounds` scheduler rounds)
    /// and was shed rather than served uselessly late.
    DeadlineExceeded { waited_rounds: u64, limit_rounds: u64 },
}

impl RejectReason {
    /// Stable machine-readable tag (log/metric friendly).
    pub fn kind(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue-full",
            RejectReason::EmptyPrompt => "empty-prompt",
            RejectReason::KvExhausted { .. } => "kv-exhausted",
            RejectReason::EngineRefused { .. } => "engine-refused",
            RejectReason::QueueDepth { .. } => "queue-depth",
            RejectReason::KvHeadroom { .. } => "kv-headroom",
            RejectReason::DeadlineExceeded { .. } => "deadline",
        }
    }

    /// Transient conditions clear on their own; resubmitting the same
    /// request later may succeed.
    pub fn is_transient(&self) -> bool {
        matches!(self,
                 RejectReason::QueueFull | RejectReason::KvExhausted { .. }
                 | RejectReason::QueueDepth { .. }
                 | RejectReason::KvHeadroom { .. }
                 | RejectReason::DeadlineExceeded { .. })
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::EmptyPrompt => write!(f, "empty prompt"),
            RejectReason::KvExhausted { blocks_needed, retries } => {
                write!(f, "kv cache exhausted: {blocks_needed} blocks \
                           unavailable after {retries} rounds")
            }
            RejectReason::EngineRefused { message } => {
                write!(f, "{message}")
            }
            RejectReason::QueueDepth { depth, limit } => {
                write!(f, "admission queue depth {depth} over the \
                           {limit}-deep admission limit")
            }
            RejectReason::KvHeadroom { blocks_needed, committed,
                                       capacity } => {
                write!(f, "kv headroom exhausted: {blocks_needed} blocks \
                           on top of {committed} committed exceeds the \
                           {capacity}-block overcommit ceiling")
            }
            RejectReason::DeadlineExceeded { waited_rounds,
                                             limit_rounds } => {
                write!(f, "queued {waited_rounds} rounds, past the \
                           {limit_rounds}-round deadline")
            }
        }
    }
}

/// Streamed per-request event.
#[derive(Debug, Clone)]
pub enum Event {
    /// A prefill chunk finished (`layers_done` of `layers_total`).
    PrefillProgress {
        id: RequestId,
        layers_done: usize,
        layers_total: usize,
    },
    /// Prefill completed; density/pattern accounting attached.
    PrefillDone { id: RequestId, stats: PrefillStats },
    /// One decoded token (`index` counts from 0 within the session).
    Token { id: RequestId, token: i32, index: usize },
    /// Terminal: the session completed normally.
    Done { id: RequestId, response: Response },
    /// Terminal: cancelled by the client.
    Cancelled { id: RequestId },
    /// Terminal: admission refused; `reason` says why (queue full, KV
    /// exhausted after bounded retries, empty/oversized prompt).
    Rejected { id: RequestId, reason: RejectReason },
    /// Terminal: the engine failed while serving this session.
    Error { id: RequestId, message: String },
}

impl Event {
    pub fn id(&self) -> RequestId {
        match self {
            Event::PrefillProgress { id, .. }
            | Event::PrefillDone { id, .. }
            | Event::Token { id, .. }
            | Event::Done { id, .. }
            | Event::Cancelled { id }
            | Event::Rejected { id, .. }
            | Event::Error { id, .. } => *id,
        }
    }

    /// True for the events that end a session's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self,
                 Event::Done { .. } | Event::Cancelled { .. }
                 | Event::Rejected { .. } | Event::Error { .. })
    }
}

/// Sending half of a session's event stream.  Cloneable so tests can
/// merge several sessions into one globally-ordered stream; sends to a
/// dropped receiver are silently discarded (a client that walked away
/// does not stall the server).
#[derive(Clone)]
pub struct EventSink {
    tx: mpsc::Sender<Event>,
}

impl EventSink {
    pub fn channel() -> (EventSink, mpsc::Receiver<Event>) {
        let (tx, rx) = mpsc::channel();
        (EventSink { tx }, rx)
    }

    /// A sink whose events go nowhere (receiver already dropped).
    pub fn null() -> EventSink {
        let (sink, rx) = EventSink::channel();
        drop(rx);
        sink
    }

    pub fn send(&self, ev: Event) {
        let _ = self.tx.send(ev);
    }
}

/// Client-side handle to one session's event stream.
pub struct SessionHandle {
    pub id: RequestId,
    pub events: mpsc::Receiver<Event>,
}

impl SessionHandle {
    /// Next event, blocking; `None` once the stream is closed.
    pub fn next_event(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Block until the terminal event; `Ok(Response)` on `Done`, an
    /// error describing the terminal state otherwise.  Intermediate
    /// events are discarded — the one-call path evals use.
    pub fn wait(self) -> Result<Response> {
        for ev in self.events.iter() {
            match ev {
                Event::Done { response, .. } => return Ok(response),
                Event::Rejected { reason, .. } => {
                    bail!("request {} rejected: {reason}", self.id)
                }
                Event::Cancelled { .. } => {
                    bail!("request {} cancelled", self.id)
                }
                Event::Error { message, .. } => {
                    bail!("request {} failed: {message}", self.id)
                }
                _ => {}
            }
        }
        bail!("server dropped session {} without a terminal event", self.id)
    }

    /// Drain the full stream (through the terminal event or disconnect).
    pub fn collect(self) -> Vec<Event> {
        let mut out = Vec::new();
        for ev in self.events.iter() {
            let terminal = ev.is_terminal();
            out.push(ev);
            if terminal {
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminal_classification() {
        let done = Event::Done {
            id: 1,
            response: Response {
                id: 1,
                generated: vec![],
                prefill_us: 0,
                decode_us: 0,
                queue_us: 0,
                ttft_us: 0,
                density: 1.0,
            },
        };
        assert!(done.is_terminal());
        assert_eq!(done.id(), 1);
        let prog = Event::PrefillProgress {
            id: 2, layers_done: 1, layers_total: 4,
        };
        assert!(!prog.is_terminal());
        assert_eq!(prog.id(), 2);
        assert!(Event::Cancelled { id: 3 }.is_terminal());
    }

    #[test]
    fn wait_returns_response() {
        let (sink, rx) = EventSink::channel();
        let h = SessionHandle { id: 9, events: rx };
        sink.send(Event::Token { id: 9, token: 5, index: 0 });
        sink.send(Event::Done {
            id: 9,
            response: Response {
                id: 9,
                generated: vec![5],
                prefill_us: 1,
                decode_us: 1,
                queue_us: 0,
                ttft_us: 1,
                density: 0.5,
            },
        });
        let r = h.wait().unwrap();
        assert_eq!(r.generated, vec![5]);
    }

    #[test]
    fn wait_surfaces_rejection() {
        let (sink, rx) = EventSink::channel();
        let h = SessionHandle { id: 4, events: rx };
        sink.send(Event::Rejected { id: 4, reason: RejectReason::QueueFull });
        let e = h.wait().unwrap_err();
        assert!(format!("{e}").contains("rejected"));
        assert!(format!("{e}").contains("queue full"));
    }

    #[test]
    fn reject_reason_kinds_are_distinct() {
        let kv = RejectReason::KvExhausted { blocks_needed: 4, retries: 3 };
        assert_eq!(kv.kind(), "kv-exhausted");
        assert!(kv.is_transient());
        assert_eq!(RejectReason::EmptyPrompt.kind(), "empty-prompt");
        assert!(!RejectReason::EmptyPrompt.is_transient());
        assert_ne!(kv.kind(), RejectReason::EmptyPrompt.kind());
        assert!(format!("{kv}").contains("4 blocks"));
    }

    #[test]
    fn admission_reject_reasons_are_transient_and_distinct() {
        let depth = RejectReason::QueueDepth { depth: 9, limit: 8 };
        let head = RejectReason::KvHeadroom {
            blocks_needed: 12, committed: 120, capacity: 128,
        };
        let late = RejectReason::DeadlineExceeded {
            waited_rounds: 33, limit_rounds: 32,
        };
        assert_eq!(depth.kind(), "queue-depth");
        assert_eq!(head.kind(), "kv-headroom");
        assert_eq!(late.kind(), "deadline");
        // admission sheds are back-pressure, not client errors: all
        // three clear on their own once load subsides
        assert!(depth.is_transient());
        assert!(head.is_transient());
        assert!(late.is_transient());
        let kinds = [depth.kind(), head.kind(), late.kind(),
                     RejectReason::QueueFull.kind()];
        let mut dedup = kinds.to_vec();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), kinds.len(), "kinds must be distinct");
        assert!(format!("{depth}").contains("depth 9"));
        assert!(format!("{head}").contains("12 blocks"));
        assert!(format!("{late}").contains("33 rounds"));
    }

    #[test]
    fn wait_detects_dropped_server() {
        let (sink, rx) = EventSink::channel();
        let h = SessionHandle { id: 8, events: rx };
        drop(sink); // server died without a terminal event
        assert!(h.wait().is_err());
    }

    #[test]
    fn null_sink_swallows() {
        EventSink::null().send(Event::Cancelled { id: 0 });
    }
}
