//! Load-aware placement and session affinity for the engine fleet.
//!
//! The router is pure bookkeeping — no channels, no threads — so the
//! placement policy is unit-testable in isolation and deterministic by
//! construction:
//!
//! * **Placement** scores every shard as `queue depth × estimated
//!   remaining prefill tokens` and picks the minimum, tie-broken by the
//!   lowest shard id.  New sessions therefore spread away from loaded
//!   shards while an idle fleet fills shard 0 first, reproducibly.
//! * **Affinity**: once placed, a session's id maps to its shard for
//!   the rest of the process lifetime (the mapping survives
//!   retirement), so follow-up commands — cancels racing a completion,
//!   late client actions — always reach the owning mailbox.
//! * **Retirement** refunds the load model when a session reaches its
//!   terminal event; **forgetting** a shard (it crashed) refunds all of
//!   its live sessions at once and reports them, sorted by id, so the
//!   supervisor can synthesize exactly one terminal event each.

use std::collections::HashMap;

use crate::serving::request::RequestId;

/// Per-session charge retained while the session is live: owning shard
/// and the prefill-token estimate to refund at retirement.
#[derive(Debug, Clone, Copy)]
struct Charge {
    shard: usize,
    est_tokens: u64,
}

/// Session-affine, load-aware request router for `serving::fleet`.
#[derive(Debug)]
pub struct FleetRouter {
    /// Live-session count per shard (the "queue depth" factor).
    depth: Vec<usize>,
    /// Estimated remaining prefill tokens per shard.
    est_tokens: Vec<u64>,
    /// Charges for sessions that have not yet reached a terminal event.
    live: HashMap<RequestId, Charge>,
    /// Full placement history: survives retirement for affinity.
    assigned: HashMap<RequestId, usize>,
    /// Prefix-affinity homes: first-chunk hash → the shard whose
    /// prefix cache holds (or will hold) that prompt family's blocks.
    prefix_home: HashMap<u64, usize>,
}

/// How many live sessions deeper than the shallowest shard a prefix
/// home may run before affinity yields to load-aware placement (the
/// home then moves with the spilled traffic).
pub const PREFIX_SPILL_DEPTH: usize = 4;

impl FleetRouter {
    pub fn new(shards: usize) -> FleetRouter {
        let shards = shards.max(1);
        FleetRouter {
            depth: vec![0; shards],
            est_tokens: vec![0; shards],
            live: HashMap::new(),
            assigned: HashMap::new(),
            prefix_home: HashMap::new(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.depth.len()
    }

    /// Load score = queue depth × estimated remaining prefill tokens.
    /// `u128` so a pathological backlog cannot overflow the product.
    fn score(&self, shard: usize) -> u128 {
        self.depth[shard] as u128 * self.est_tokens[shard] as u128
    }

    /// Place a new session on the least-loaded shard (deterministic
    /// tie-break: lowest shard id) and charge the load model.  Each
    /// session is charged at least one token so an all-empty-prompt
    /// backlog still registers as depth.
    pub fn place(&mut self, id: RequestId, prompt_tokens: usize) -> usize {
        let mut best = 0usize;
        for shard in 1..self.depth.len() {
            if self.score(shard) < self.score(best) {
                best = shard;
            }
        }
        let est_tokens = prompt_tokens.max(1) as u64;
        self.depth[best] += 1;
        self.est_tokens[best] += est_tokens;
        self.live.insert(id, Charge { shard: best, est_tokens });
        self.assigned.insert(id, best);
        best
    }

    /// Place a session whose prompt opens with the block chunk hashed
    /// as `prefix` (see `kvcache::chain_hashes`).  Sessions sharing a
    /// first chunk co-locate on that chunk's *home shard* — the one
    /// whose prefix cache holds (or is about to hold) their KV blocks —
    /// so warm hits happen instead of every shard re-prefilling the
    /// same prefix cold.  Load still wins two ways: a key with no home
    /// yet is placed load-aware (and that shard becomes the home), and
    /// a home running more than [`PREFIX_SPILL_DEPTH`] live sessions
    /// deeper than the shallowest shard spills — the load-aware pick
    /// takes the session *and* the home, so a hot prefix family
    /// migrates rather than melting one shard.  `prefix: None` is
    /// exactly [`FleetRouter::place`], so routing with the prefix cache
    /// disabled is bit-identical to the load-only policy.
    pub fn place_with_prefix(&mut self, id: RequestId,
                             prompt_tokens: usize,
                             prefix: Option<u64>) -> usize {
        let Some(key) = prefix else {
            return self.place(id, prompt_tokens);
        };
        if let Some(&home) = self.prefix_home.get(&key) {
            let shallowest =
                self.depth.iter().copied().min().unwrap_or(0);
            if self.depth[home] < shallowest + PREFIX_SPILL_DEPTH {
                let est_tokens = prompt_tokens.max(1) as u64;
                self.depth[home] += 1;
                self.est_tokens[home] += est_tokens;
                self.live.insert(id, Charge { shard: home, est_tokens });
                self.assigned.insert(id, home);
                return home;
            }
        }
        let shard = self.place(id, prompt_tokens);
        self.prefix_home.insert(key, shard);
        shard
    }

    /// The shard owning `id`, live or retired — affinity means a
    /// session's follow-up commands always reach the same mailbox.
    pub fn route(&self, id: RequestId) -> Option<usize> {
        self.assigned.get(&id).copied()
    }

    /// Refund a session's load charge after its terminal event.
    /// Idempotent; the affinity mapping is kept.
    pub fn retire(&mut self, id: RequestId) {
        if let Some(c) = self.live.remove(&id) {
            self.depth[c.shard] -= 1;
            self.est_tokens[c.shard] -= c.est_tokens;
        }
    }

    /// The shard died: refund and return all of its live sessions
    /// (ascending id, so the supervisor's synthesized terminal events
    /// are deterministic).  Its replacement starts with an empty load.
    pub fn forget_shard(&mut self, shard: usize) -> Vec<RequestId> {
        let mut ids: Vec<RequestId> = self
            .live
            .iter()
            .filter(|(_, c)| c.shard == shard)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        for &id in &ids {
            self.retire(id);
        }
        ids
    }

    /// Total sessions ever placed (the fleet summary's "routed" count).
    pub fn placed_total(&self) -> usize {
        self.assigned.len()
    }

    /// Live sessions currently charged to `shard`.
    pub fn live_on(&self, shard: usize) -> usize {
        self.depth[shard]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_load_ties_break_by_lowest_shard_id() {
        let mut r = FleetRouter::new(3);
        // Empty fleet: every score is 0, so shard 0 must win.
        assert_eq!(r.place(0, 128), 0);
        // Depth 1 × 128 on shard 0 vs 0 on shards 1 and 2 → shard 1.
        assert_eq!(r.place(1, 128), 1);
        assert_eq!(r.place(2, 128), 2);
        // All equal again (1 × 128 each): back to shard 0.
        assert_eq!(r.place(3, 128), 0);
    }

    #[test]
    fn placement_is_deterministic_under_replay() {
        let script: &[usize] = &[512, 16, 2048, 64, 64, 1024, 8, 256];
        let run = |shards: usize| -> Vec<usize> {
            let mut r = FleetRouter::new(shards);
            script
                .iter()
                .enumerate()
                .map(|(id, &len)| r.place(id as u64, len))
                .collect()
        };
        assert_eq!(run(4), run(4));
        assert_eq!(run(2), run(2));
    }

    #[test]
    fn affinity_sticks_across_the_session_lifetime() {
        let mut r = FleetRouter::new(2);
        let shard = r.place(7, 4096);
        assert_eq!(r.route(7), Some(shard));
        // Load the other shard; the existing session must not move.
        for id in 100..110 {
            r.place(id, 4096);
        }
        assert_eq!(r.route(7), Some(shard));
        // Even after retirement the mapping survives, so a late cancel
        // still reaches the shard that owned the session.
        r.retire(7);
        assert_eq!(r.route(7), Some(shard));
        assert_eq!(r.route(999), None);
    }

    #[test]
    fn new_sessions_rebalance_away_from_a_loaded_shard() {
        let mut r = FleetRouter::new(2);
        // One huge session lands on shard 0 …
        assert_eq!(r.place(0, 10_000), 0);
        // … so a burst of small sessions prefers shard 1 until its
        // depth × tokens product catches up with 1 × 10_000.
        let mut on_1 = 0;
        for id in 1..5 {
            if r.place(id, 32) == 1 {
                on_1 += 1;
            }
        }
        assert!(on_1 >= 3, "expected small sessions on shard 1, got {on_1}");
    }

    #[test]
    fn retire_refunds_the_load_model() {
        let mut r = FleetRouter::new(2);
        r.place(0, 10_000);
        r.retire(0);
        r.retire(0); // idempotent
        assert_eq!(r.live_on(0), 0);
        // Shard 0 is empty again, so the tie-break sends the next
        // session back to it.
        assert_eq!(r.place(1, 64), 0);
    }

    #[test]
    fn prefix_key_colocates_sessions_on_one_home_shard() {
        let mut r = FleetRouter::new(3);
        // First sighting of the key: load-aware (empty fleet → shard
        // 0), and shard 0 becomes the key's home.
        assert_eq!(r.place_with_prefix(0, 256, Some(0xfeed)), 0);
        // Plain load-aware placement would now pick shard 1; the
        // shared key pins the follow-ups to the warm home instead.
        assert_eq!(r.place_with_prefix(1, 256, Some(0xfeed)), 0);
        assert_eq!(r.place_with_prefix(2, 256, Some(0xfeed)), 0);
        // A different key is unaffected and spreads load-aware.
        assert_eq!(r.place_with_prefix(3, 256, Some(0xbeef)), 1);
        // No key at all behaves exactly like `place`.
        assert_eq!(r.place_with_prefix(4, 256, None), 2);
    }

    #[test]
    fn overloaded_home_spills_and_migrates_the_prefix_home() {
        let mut r = FleetRouter::new(2);
        // Pin the key's home to shard 0, then pile on until the home
        // runs PREFIX_SPILL_DEPTH deeper than the idle shard 1.
        for id in 0..PREFIX_SPILL_DEPTH as u64 {
            assert_eq!(r.place_with_prefix(id, 64, Some(1)), 0);
        }
        // Depth 4 vs 0: affinity yields, load-aware picks shard 1, and
        // the home migrates with the spill …
        assert_eq!(r.place_with_prefix(90, 64, Some(1)), 1);
        // … so the next same-key session follows it there.
        assert_eq!(r.place_with_prefix(91, 64, Some(1)), 1);
    }

    #[test]
    fn none_prefix_matches_plain_placement_exactly() {
        let script: &[usize] = &[512, 16, 2048, 64, 64, 1024, 8, 256];
        let mut plain = FleetRouter::new(3);
        let mut keyed = FleetRouter::new(3);
        for (id, &len) in script.iter().enumerate() {
            assert_eq!(plain.place(id as u64, len),
                       keyed.place_with_prefix(id as u64, len, None));
        }
    }

    #[test]
    fn forget_shard_reports_live_sessions_sorted_and_clears_load() {
        let mut r = FleetRouter::new(2);
        r.place(5, 100); // shard 0
        r.place(2, 100); // shard 1
        r.place(9, 100); // shard 0 (tie at 1×100 → lowest id)
        r.retire(5);
        assert_eq!(r.forget_shard(0), vec![9]);
        assert_eq!(r.live_on(0), 0);
        assert_eq!(r.forget_shard(0), Vec::<RequestId>::new());
        // Affinity survives even a forget: the dead shard's id is still
        // the routing answer (its replacement holds the mailbox).
        assert_eq!(r.route(9), Some(0));
    }
}
