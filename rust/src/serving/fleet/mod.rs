//! **Engine fleet**: N actor-style engine shards behind one front door.
//!
//! Each shard is a worker thread owning a full serving stack — its own
//! [`Scheduler`], KV allocator, pattern cache and worker pool — fed by
//! a private mailbox ([`ShardCmd`]) exactly like the single-engine
//! server loop in `serving/server.rs`.  The [`Fleet`] front door places
//! sessions with the [`FleetRouter`] (load-aware, session-affine,
//! deterministic tie-breaks) and forwards follow-up commands to the
//! owning shard's mailbox.
//!
//! **Mailbox protocol.**  Commands flow one way (front door → shard);
//! bookkeeping flows back on a per-shard note channel ([`ShardNote`]):
//! `Retired(id)` when a session received its terminal event (so the
//! front door's registry and the router's load model stay honest), and
//! `Export` when the shard's pattern cache published a new entry.  The
//! front door rebroadcasts each export to every *other* shard as
//! [`ShardCmd::Absorb`] — entries are tagged with their origin shard,
//! absorbed only as validation-gated warm candidates, never
//! re-broadcast (no gift loops), and the whole path is inert when the
//! pattern cache is off.
//!
//! **Supervision.**  Every shard thread carries a drop guard that
//! reports its exit on a third channel — including a panicking unwind.
//! The front door pumps its supervision loop on every public call: a
//! shard that died outside shutdown has its already-terminated sessions
//! retired (notes drained first, so nobody is double-terminated), every
//! session it still owned receives exactly one synthesized terminal
//! [`Event::Error`], and a fresh shard is spawned in its place.  KV
//! reclamation is by construction: the dead shard's allocator died with
//! its thread, and the replacement starts empty.  There is no
//! supervisor thread — supervision is lazy, which keeps the fleet
//! deterministic to drive from tests.
//!
//! `spawn_fleet(1, …)` does not build any of this: it returns the plain
//! single-engine [`server::spawn`] handle, so `serve.shards = 1` is
//! bit-identical to the pre-fleet path (asserted at the unit, fuzz and
//! bench levels).

pub mod router;

pub use router::FleetRouter;

use std::collections::HashMap;
use std::sync::mpsc;

use anyhow::Result;

use super::engine::{EngineCore, PatternExport};
use super::metrics::Metrics;
use super::request::{Request, RequestId};
use super::scheduler::Scheduler;
use super::server::{self, ServerHandle};
use super::session::{Event, EventSink, SessionHandle};

/// Commands accepted by a shard's mailbox.
pub enum ShardCmd {
    Submit(Request, EventSink),
    Cancel(RequestId),
    /// Absorb a peer shard's pattern-cache broadcast.
    Absorb(PatternExport),
    /// Fault injection (fuzz/tests): exit immediately *without* any
    /// cleanup, exactly like a panicking unwind — the exit guard
    /// reports an unclean death and the supervisor takes over.
    Kill,
    /// Drain all in-flight work, then exit cleanly.
    Shutdown,
}

/// Bookkeeping a shard streams back to the front door.
pub enum ShardNote {
    /// This session received its terminal event on the shard.
    Retired(RequestId),
    /// The shard's pattern cache published an entry (origin stamped).
    Export(PatternExport),
}

/// A shard's exit report, sent by its drop guard on *any* exit path —
/// clean shutdown, engine error, fault injection, or panic unwind.
pub struct ShardExit {
    /// True only for a drained shutdown with zero KV blocks in use.
    pub clean: bool,
    /// Lifetime metrics, harvested on orderly exits (`None` after a
    /// panic or kill — the scheduler died mid-flight).
    pub metrics: Option<Metrics>,
}

/// Drop guard ensuring the exit report is sent even through a panic.
struct ExitGuard {
    tx: mpsc::Sender<ShardExit>,
    clean: bool,
    metrics: Option<Metrics>,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(ShardExit {
            clean: self.clean,
            metrics: self.metrics.take(),
        });
    }
}

/// The shard actor body: the single-engine server loop plus the note
/// stream (retirements + pattern exports) and the exit guard.
fn run_shard<E: EngineCore>(
    shard: usize,
    mut sched: Scheduler<E>,
    mut engine: E,
    rx: mpsc::Receiver<ShardCmd>,
    notes: mpsc::Sender<ShardNote>,
    exit: mpsc::Sender<ShardExit>,
) {
    sched.track_retirements();
    let mut guard = ExitGuard { tx: exit, clean: false, metrics: None };
    let mut shutting_down = false;
    loop {
        // ingest commands (blocking only when fully idle)
        loop {
            let cmd = if !sched.has_work() && !shutting_down {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => {
                        // front door dropped: drain and exit
                        shutting_down = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            };
            match cmd {
                ShardCmd::Submit(r, sink) => {
                    sched.submit(&engine, r, sink);
                }
                ShardCmd::Cancel(id) => {
                    sched.cancel(id);
                }
                ShardCmd::Absorb(x) => engine.absorb_pattern_export(&x),
                ShardCmd::Kill => return,
                ShardCmd::Shutdown => shutting_down = true,
            }
        }
        let result = sched.run_round(&mut engine);
        // bookkeeping first: retirements before any exit report, so the
        // front door never synthesizes a second terminal event for a
        // session this shard already terminated
        for id in sched.take_retired() {
            let _ = notes.send(ShardNote::Retired(id));
        }
        for mut x in engine.take_pattern_exports() {
            x.origin = shard;
            let _ = notes.send(ShardNote::Export(x));
        }
        if let Err(e) = result {
            // terminal engine failure: every live session got an Error
            // from fail_all; report the (orderly) unclean exit
            sched.fail_all(&format!("{e:#}"));
            for id in sched.take_retired() {
                let _ = notes.send(ShardNote::Retired(id));
            }
            guard.metrics = Some(std::mem::take(&mut sched.metrics));
            return;
        }
        if shutting_down && !sched.has_work() {
            // release the prefix index's retains first: cached blocks
            // are deliberate state, not a leak, and must not fail the
            // clean-exit audit below
            sched.flush_prefix_cache();
            guard.clean = sched.kv.used() == 0;
            guard.metrics = Some(std::mem::take(&mut sched.metrics));
            return;
        }
    }
}

/// One shard's channel triple as held by the front door.
struct ShardSlot {
    tx: mpsc::Sender<ShardCmd>,
    notes: mpsc::Receiver<ShardNote>,
    exit: mpsc::Receiver<ShardExit>,
}

fn spawn_shard<E, F>(shard: usize, factory: F) -> ShardSlot
where
    E: EngineCore + 'static,
    F: Fn(usize) -> Result<(Scheduler<E>, E)> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<ShardCmd>();
    let (note_tx, note_rx) = mpsc::channel::<ShardNote>();
    let (exit_tx, exit_rx) = mpsc::channel::<ShardExit>();
    // thread creation goes through exec (layering: `std::thread` is
    // exec's alone; `serving/fleet` and `server.rs` are the only
    // modules allowed to name this entry point — pallas-lint enforces
    // both)
    crate::exec::spawn_worker(&format!("fleet-shard-{shard}"), move || {
        match factory(shard) {
            Ok((sched, engine)) => {
                run_shard(shard, sched, engine, rx, note_tx, exit_tx);
            }
            Err(_) => {
                // init failure: report straight away so the front door
                // can fail the shard's sessions and retry
                let _ = exit_tx.send(ShardExit {
                    clean: false,
                    metrics: None,
                });
            }
        }
    });
    ShardSlot { tx, notes: note_rx, exit: exit_rx }
}

/// The sharded front door: router + session registry + supervisor.
/// Lives on the caller's thread; all public methods pump the
/// supervision loop first, so crashes are observed (and repaired) at
/// the next interaction rather than by a background thread.
pub struct Fleet {
    shards: Vec<ShardSlot>,
    router: FleetRouter,
    /// Sessions not yet known to have reached a terminal event, with a
    /// clone of their sink so the supervisor can synthesize exactly one
    /// terminal `Error` if their shard dies.
    sessions: HashMap<RequestId, EventSink>,
    spawner: Box<dyn Fn(usize) -> ShardSlot + Send>,
    next_id: u64,
    restarts: u64,
    /// Metrics harvested from shards that exited before shutdown.
    harvested: Vec<Metrics>,
    /// Route sessions sharing a prompt's first block chunk to the same
    /// shard (whose prefix cache holds their KV blocks).  Set by the
    /// builder iff `serve.prefix_cache` is on; off, placement is
    /// bit-identical to the load-only policy.
    prefix_affinity: bool,
}

impl Fleet {
    fn submit(&mut self, tokens: Vec<i32>, max_new_tokens: usize)
              -> SessionHandle {
        self.pump();
        let id = self.next_id;
        self.next_id += 1;
        let (sink, events) = EventSink::channel();
        // prefix key = the prompt's first full-chunk chain hash, the
        // same content address the shard-local `PrefixIndex` uses, so
        // same-prefix sessions land where their cached blocks live
        // (sub-chunk prompts hash nothing and place load-aware)
        let prefix = if self.prefix_affinity {
            let head = tokens.len().min(crate::BLOCK_SIZE);
            super::kvcache::chain_hashes(&tokens[..head])
                .first()
                .copied()
        } else {
            None
        };
        let shard =
            self.router.place_with_prefix(id, tokens.len(), prefix);
        self.sessions.insert(id, sink.clone());
        // a send to a shard that died since the pump above is not lost:
        // the session is registered, so the supervisor synthesizes its
        // terminal Error when it observes the crash
        let _ = self.shards[shard].tx.send(ShardCmd::Submit(
            Request::new(id, tokens, max_new_tokens), sink));
        SessionHandle { id, events }
    }

    fn cancel(&mut self, id: RequestId) {
        self.pump();
        // affinity: late cancels still reach the owning shard's mailbox
        if let Some(shard) = self.router.route(id) {
            let _ = self.shards[shard].tx.send(ShardCmd::Cancel(id));
        }
    }

    /// Drain every shard's notes (retirements + export broadcast), then
    /// observe at most one exit per shard and repair it.  Returns the
    /// number of notes processed (a test-visible progress signal).
    fn pump(&mut self) -> usize {
        let mut drained = 0usize;
        for i in 0..self.shards.len() {
            loop {
                let note = match self.shards[i].notes.try_recv() {
                    Ok(n) => n,
                    Err(_) => break,
                };
                drained += 1;
                match note {
                    ShardNote::Retired(id) => {
                        self.router.retire(id);
                        self.sessions.remove(&id);
                    }
                    ShardNote::Export(x) => {
                        for (j, s) in self.shards.iter().enumerate() {
                            if j != i {
                                let _ = s.tx.send(
                                    ShardCmd::Absorb(x.clone()));
                            }
                        }
                    }
                }
            }
            let exit = match self.shards[i].exit.try_recv() {
                Ok(e) => Some(e),
                // disconnected without a report: the thread died before
                // its guard existed (factory panic) — treat as a crash
                Err(mpsc::TryRecvError::Disconnected) => {
                    Some(ShardExit { clean: false, metrics: None })
                }
                Err(mpsc::TryRecvError::Empty) => None,
            };
            if let Some(exit) = exit {
                self.on_exit(i, exit);
            }
        }
        drained
    }

    /// Supervision: shard `shard` exited outside shutdown.  Harvest its
    /// metrics, retire everything it already terminated (buffered notes
    /// count), give every session it still owned exactly one terminal
    /// `Error`, and restart it with a fresh scheduler/engine/KV.
    /// Reclamation is by construction: the dead allocator's blocks died
    /// with its thread.  A persistently failing factory shows up as a
    /// climbing `restarts` counter, one respawn per pump — the front
    /// door never spins on it.
    fn on_exit(&mut self, shard: usize, exit: ShardExit) {
        if let Some(m) = exit.metrics {
            self.harvested.push(m);
        }
        while let Ok(note) = self.shards[shard].notes.try_recv() {
            if let ShardNote::Retired(id) = note {
                self.router.retire(id);
                self.sessions.remove(&id);
            }
            // a dead shard's unflushed exports are dropped: gifts are
            // only candidates, and forwarding from a crashed publisher
            // buys nothing worth the extra state machine
        }
        for id in self.router.forget_shard(shard) {
            let Some(sink) = self.sessions.remove(&id) else { continue };
            sink.send(Event::Error {
                id,
                message: format!(
                    "engine shard {shard} crashed; session aborted (its \
                     KV and queue slots died with the shard)"),
            });
        }
        self.restarts += 1;
        self.shards[shard] = (self.spawner)(shard);
    }

    fn kill_shard(&mut self, shard: usize) {
        if shard < self.shards.len() {
            let _ = self.shards[shard].tx.send(ShardCmd::Kill);
        }
    }

    fn shutdown(mut self) -> String {
        self.pump();
        for s in &self.shards {
            let _ = s.tx.send(ShardCmd::Shutdown);
        }
        let mut agg = Metrics::new();
        for m in &self.harvested {
            agg.absorb(m);
        }
        let mut crashed = 0usize;
        let mut shard_lines = Vec::new();
        for i in 0..self.shards.len() {
            let exit = self.shards[i].exit.recv().ok();
            // final note drain either way: sessions the shard
            // terminated while we were waiting must not be
            // double-terminated below
            while let Ok(note) = self.shards[i].notes.try_recv() {
                if let ShardNote::Retired(id) = note {
                    self.router.retire(id);
                    self.sessions.remove(&id);
                }
            }
            match exit {
                Some(e) => {
                    if !e.clean {
                        crashed += 1;
                    }
                    if let Some(m) = e.metrics {
                        shard_lines.push(format!(
                            "  shard {i}: {} done, {} rejected, {} \
                             cancelled, {} errored",
                            m.requests_completed, m.requests_rejected,
                            m.requests_cancelled, m.requests_errored));
                        agg.absorb(&m);
                    }
                }
                None => crashed += 1,
            }
            // sessions a crashed shard still owned get their one
            // terminal Error here (a clean shard has none left)
            for id in self.router.forget_shard(i) {
                let Some(sink) = self.sessions.remove(&id) else {
                    continue;
                };
                sink.send(Event::Error {
                    id,
                    message: format!(
                        "engine shard {i} shut down with the session \
                         in flight"),
                });
            }
        }
        // safety net: a session whose shard assignment evaporated
        // entirely (should be unreachable — forget_shard covers every
        // placed session)
        for (id, sink) in self.sessions.drain() {
            sink.send(Event::Error {
                id,
                message: "fleet shut down before the session reached a \
                          shard".to_string(),
            });
        }
        let mut report = format!(
            "fleet: {} shards, {} restarts, {} unclean exits, {} \
             sessions routed",
            self.shards.len(), self.restarts, crashed,
            self.router.placed_total());
        for line in shard_lines {
            report.push('\n');
            report.push_str(&line);
        }
        report.push('\n');
        report.push_str(&agg.report());
        report
    }
}

/// One front door over 1..=N engines.  `Single` *is* the pre-fleet
/// [`ServerHandle`] — no router, no supervisor, no extra hop — so the
/// default `serve.shards = 1` deployment is bit-identical to a build
/// without this module.
pub enum FleetHandle {
    Single(ServerHandle),
    Sharded(Box<Fleet>),
}

impl FleetHandle {
    /// Submit a prompt; returns the per-session event stream.
    pub fn submit(&mut self, tokens: Vec<i32>, max_new_tokens: usize)
                  -> SessionHandle {
        match self {
            FleetHandle::Single(h) => h.submit(tokens, max_new_tokens),
            FleetHandle::Sharded(f) => f.submit(tokens, max_new_tokens),
        }
    }

    /// Request cancellation; routed to the session's own shard.
    pub fn cancel(&mut self, id: RequestId) {
        match self {
            FleetHandle::Single(h) => h.cancel(id),
            FleetHandle::Sharded(f) => f.cancel(id),
        }
    }

    /// Graceful shutdown: drain every shard, aggregate their metrics,
    /// and return the report (prefixed with a fleet summary line when
    /// sharded).
    pub fn shutdown(self) -> String {
        match self {
            FleetHandle::Single(h) => h.shutdown(),
            FleetHandle::Sharded(f) => f.shutdown(),
        }
    }

    /// Turn on prefix-affinity placement: sessions sharing a prompt's
    /// first block chunk co-locate on the shard whose prefix cache
    /// holds their blocks (with load-aware spill — see
    /// [`FleetRouter::place_with_prefix`]).  Intended to be flipped
    /// iff `serve.prefix_cache.enabled` is, so the knob-off fleet
    /// places bit-identically to the load-only policy.  No-op on a
    /// single-engine handle (one shard is its own home).
    pub fn enable_prefix_affinity(&mut self) {
        if let FleetHandle::Sharded(f) = self {
            f.prefix_affinity = true;
        }
    }

    /// Fault injection for tests/fuzzing: make a shard die as if its
    /// thread panicked.  No-op on a single-engine handle.
    pub fn kill_shard(&mut self, shard: usize) {
        if let FleetHandle::Sharded(f) = self {
            f.kill_shard(shard);
        }
    }

    /// Run one supervision pump now (notes + exits); returns the number
    /// of notes processed.  Tests use this to wait for broadcast
    /// propagation deterministically; production callers never need it
    /// (every public call pumps).
    pub fn pump_now(&mut self) -> usize {
        match self {
            FleetHandle::Single(_) => 0,
            FleetHandle::Sharded(f) => f.pump(),
        }
    }

    pub fn shard_count(&self) -> usize {
        match self {
            FleetHandle::Single(_) => 1,
            FleetHandle::Sharded(f) => f.shards.len(),
        }
    }

    /// True when this handle is the plain single-engine server path.
    pub fn is_single(&self) -> bool {
        matches!(self, FleetHandle::Single(_))
    }

    /// Shard restarts performed by the supervisor so far.
    pub fn restarts(&self) -> u64 {
        match self {
            FleetHandle::Single(_) => 0,
            FleetHandle::Sharded(f) => f.restarts,
        }
    }

    /// The shard a session was placed on (`Some(0)` always, when
    /// single).
    pub fn assignment_of(&self, id: RequestId) -> Option<usize> {
        match self {
            FleetHandle::Single(_) => Some(0),
            FleetHandle::Sharded(f) => f.router.route(id),
        }
    }
}

/// Spawn `shards` engine shards behind one front door, each built by
/// `factory(shard)` *on its own thread* (PJRT handles never cross
/// threads, exactly as in [`server::spawn`]).  `shards <= 1` returns
/// the plain single-engine server handle — the bit-identity guarantee
/// for the default config.
pub fn spawn_fleet<E, F>(shards: usize, factory: F) -> FleetHandle
where
    E: EngineCore + 'static,
    F: Fn(usize) -> Result<(Scheduler<E>, E)> + Clone + Send + 'static,
{
    let n = shards.max(1);
    if n == 1 {
        return FleetHandle::Single(server::spawn(move || factory(0)));
    }
    let spawner: Box<dyn Fn(usize) -> ShardSlot + Send> =
        Box::new(move |shard| spawn_shard(shard, factory.clone()));
    let slots = (0..n).map(|i| (spawner)(i)).collect();
    FleetHandle::Sharded(Box::new(Fleet {
        shards: slots,
        router: FleetRouter::new(n),
        sessions: HashMap::new(),
        spawner,
        next_id: 0,
        restarts: 0,
        harvested: Vec::new(),
        prefix_affinity: false,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::serving::sim::SimEngine;

    fn sim_fleet(shards: usize, cache: bool, work_ns: u64) -> FleetHandle {
        let cfg = ServeConfig::default();
        spawn_fleet(shards, move |_| {
            let mut e = SimEngine::new(4);
            if cache {
                e = e.with_pattern_cache();
            }
            if work_ns > 0 {
                e = e.with_work(work_ns);
            }
            Ok((Scheduler::new(&cfg), e))
        })
    }

    /// Timing-free event signature (mirrors the fuzz harness's `sig`).
    fn sig(ev: &Event) -> String {
        match ev {
            Event::PrefillProgress { id, layers_done, layers_total } => {
                format!("P{id}:{layers_done}/{layers_total}")
            }
            Event::PrefillDone { id, .. } => format!("F{id}"),
            Event::Token { id, token, index } => {
                format!("T{id}:{token}@{index}")
            }
            Event::Done { id, response } => {
                format!("D{id}:{:?}", response.generated)
            }
            Event::Cancelled { id } => format!("C{id}"),
            Event::Rejected { id, reason } => {
                format!("R{id}:{}", reason.kind())
            }
            Event::Error { id, .. } => format!("E{id}"),
        }
    }

    #[test]
    fn single_shard_is_the_server_path_bit_identical() {
        // the same fixed workload through the pre-fleet server and a
        // 1-shard fleet must yield identical per-session event streams
        let cfg = ServeConfig::default();
        let baseline = server::spawn(move || {
            Ok((Scheduler::new(&cfg), SimEngine::new(4)))
        });
        let mut fleet = sim_fleet(1, false, 0);
        assert!(fleet.is_single());
        assert_eq!(fleet.shard_count(), 1);
        assert_eq!(fleet.restarts(), 0);
        let lens = [64usize, 256, 16];
        let base_handles: Vec<SessionHandle> = lens
            .iter()
            .map(|&l| baseline.submit(vec![7; l], 2))
            .collect();
        let fleet_handles: Vec<SessionHandle> = lens
            .iter()
            .map(|&l| fleet.submit(vec![7; l], 2))
            .collect();
        for (b, f) in base_handles.into_iter().zip(fleet_handles) {
            assert_eq!(fleet.assignment_of(f.id), Some(0));
            let bs: Vec<String> = b.collect().iter().map(sig).collect();
            let fs: Vec<String> = f.collect().iter().map(sig).collect();
            assert_eq!(bs, fs, "shards=1 must match the server path");
        }
        let base_report = baseline.shutdown();
        let fleet_report = fleet.shutdown();
        assert!(!fleet_report.contains("fleet:"),
                "single path must not grow a fleet summary");
        assert_eq!(base_report.lines().next(), fleet_report.lines().next());
    }

    #[test]
    fn fleet_serves_across_shards() {
        let mut fleet = sim_fleet(2, false, 0);
        assert!(!fleet.is_single());
        assert_eq!(fleet.shard_count(), 2);
        let handles: Vec<SessionHandle> =
            (0..6).map(|_| fleet.submit(vec![7; 64], 2)).collect();
        let mut seen_shards = std::collections::HashSet::new();
        for h in handles {
            if let Some(s) = fleet.assignment_of(h.id) {
                seen_shards.insert(s);
            }
            let events = h.collect();
            let last = events.last().expect("stream must not be empty");
            assert!(matches!(last, Event::Done { .. }),
                    "expected Done, got {last:?}");
        }
        assert_eq!(seen_shards.len(), 2, "load must spread across shards");
        let report = fleet.shutdown();
        assert!(report.contains("fleet: 2 shards, 0 restarts"),
                "missing fleet summary: {report}");
        assert!(report.contains("requests: 6 done"),
                "aggregated metrics wrong: {report}");
    }

    #[test]
    fn killed_shard_terminates_sessions_once_and_restarts() {
        // enough simulated work that the kill lands mid-prefill
        let mut fleet = sim_fleet(2, false, 20_000);
        let victim = fleet.submit(vec![7; 512], 2);
        assert_eq!(fleet.assignment_of(victim.id), Some(0),
                   "first placement must be shard 0 (tie-break)");
        fleet.kill_shard(0);
        // the terminal Error for the aborted session is synthesized by
        // the supervision pump once the exit report lands — drive it
        for _ in 0..5_000 {
            fleet.pump_now();
            if fleet.restarts() >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(fleet.restarts() >= 1, "supervisor never saw the crash");
        let events = victim.collect();
        let terminals =
            events.iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1, "exactly one terminal event: {events:?}");
        assert!(events.last().is_some_and(Event::is_terminal),
                "stream must end on the terminal event");
        // the restarted shard serves new sessions normally
        let next = fleet.submit(vec![7; 16], 1);
        let last = next.collect().pop().expect("stream must not be empty");
        assert!(matches!(last, Event::Done { .. }),
                "restarted shard must serve: got {last:?}");
        assert!(fleet.restarts() >= 1);
        let report = fleet.shutdown();
        assert!(report.contains("restarts"), "summary missing: {report}");
    }

    #[test]
    fn broadcast_warms_peer_shards() {
        let mut fleet = sim_fleet(2, true, 0);
        // session 0 → shard 0 (tie-break); completing it publishes its
        // bucket, which the front door rebroadcasts to shard 1
        let first = fleet.submit(vec![7; 256], 1);
        let first_events = first.collect();
        assert!(matches!(first_events.last(),
                         Some(Event::Done { .. })));
        // wait for the Retired + Export notes to arrive, then pump so
        // the Absorb lands in shard 1's mailbox before the next Submit
        let mut drained = 0usize;
        for _ in 0..2_000 {
            drained += fleet.pump_now();
            if drained >= 2 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(drained >= 2, "expected Retired + Export notes");
        // session 1 → shard 1 (shard 0 already served one) runs warm
        // off the absorbed bucket, never having served 256 itself
        let second = fleet.submit(vec![7; 256], 1);
        assert_eq!(fleet.assignment_of(second.id), Some(1));
        let events = second.collect();
        let warm = events.iter().any(|e| matches!(
            e, Event::PrefillDone { stats, .. } if stats.cache_hits > 0));
        assert!(warm, "peer shard must run warm: {events:?}");
        fleet.shutdown();
    }

    #[test]
    fn prefix_affinity_colocates_and_reuses_cached_blocks() {
        let mut cfg = ServeConfig::default();
        cfg.prefix_cache.enabled = true;
        let mut fleet = spawn_fleet(2, move |_| {
            Ok((Scheduler::new(&cfg),
                SimEngine::new(4).with_work(20_000)))
        });
        fleet.enable_prefix_affinity();
        // a back-to-back same-prompt burst: the load-aware policy
        // would spread these across both shards; affinity pins them
        // all to the first session's home
        let burst: Vec<SessionHandle> =
            (0..3).map(|_| fleet.submit(vec![7; 256], 1)).collect();
        let homes: Vec<Option<usize>> = burst
            .iter()
            .map(|h| fleet.assignment_of(h.id))
            .collect();
        assert!(homes.iter().all(|s| *s == homes[0]),
                "same-prefix burst must co-locate: {homes:?}");
        for h in burst {
            let last =
                h.collect().pop().expect("stream must not be empty");
            assert!(matches!(last, Event::Done { .. }),
                    "expected Done, got {last:?}");
        }
        // a fresh same-prefix session lands on the warm home and
        // adopts the cached KV blocks instead of prefilling cold
        let warm = fleet.submit(vec![7; 256], 1);
        assert_eq!(fleet.assignment_of(warm.id), homes[0]);
        let events = warm.collect();
        let reused = events.iter().any(|e| matches!(
            e, Event::PrefillDone { stats, .. }
                if stats.prefix_blocks_reused > 0));
        assert!(reused, "home shard must reuse cached blocks: \
                         {events:?}");
        // flush-before-audit: prefix retains are not unclean exits
        let report = fleet.shutdown();
        assert!(report.contains("0 unclean exits"),
                "prefix retains flagged as a leak: {report}");
    }

    #[test]
    fn cancel_routes_to_owning_shard() {
        // heavy work so the session is still in flight when cancelled
        let mut fleet = sim_fleet(2, false, 50_000);
        let h = fleet.submit(vec![7; 512], 4);
        fleet.cancel(h.id);
        let events = h.collect();
        let terminals = events.iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1);
        assert!(matches!(events.last(),
                         Some(Event::Cancelled { .. })
                         | Some(Event::Done { .. })),
                "cancel must land or the session completes: {events:?}");
        fleet.shutdown();
    }
}
