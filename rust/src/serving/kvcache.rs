//! Paged KV-cache block allocator: refcounted logical blocks with a free
//! list, in the vLLM style.  The scheduler uses it for admission control
//! (a request needs `ceil(len / BLOCK_SIZE) * num_layers` blocks for its
//! whole lifetime); the engine owns the physical tensors.
//!
//! Refcounting exists so shared prefixes (same prompt served to multiple
//! requests) can share blocks — exercised by the property tests and the
//! scheduler's duplicate-prompt fast path.

use anyhow::{bail, Result};

/// Logical block handle.
pub type BlockId = u32;

#[derive(Debug)]
pub struct KvAllocator {
    capacity: usize,
    free: Vec<BlockId>,
    refcount: Vec<u16>,
}

impl KvAllocator {
    pub fn new(capacity: usize) -> KvAllocator {
        KvAllocator {
            capacity,
            free: (0..capacity as BlockId).rev().collect(),
            refcount: vec![0; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Would an `n`-block allocation succeed right now?  (Admission
    /// probe: the scheduler re-queues rather than rejects on false.)
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Allocate `n` blocks (all-or-nothing).
    pub fn alloc(&mut self, n: usize) -> Result<Vec<BlockId>> {
        if self.free.len() < n {
            bail!("kv cache exhausted: want {n}, have {}", self.free.len());
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free.pop()
                .expect("invariant: free list holds >= n blocks \
                         (length-checked above, &mut self held)");
            debug_assert_eq!(self.refcount[b as usize], 0);
            self.refcount[b as usize] = 1;
            out.push(b);
        }
        Ok(out)
    }

    /// Increase refcount (prefix sharing).
    pub fn retain(&mut self, blocks: &[BlockId]) -> Result<()> {
        for &b in blocks {
            if b as usize >= self.capacity {
                bail!("retain of out-of-range block {b} \
                       (capacity {})", self.capacity);
            }
            if self.refcount[b as usize] == 0 {
                bail!("retain of free block {b}");
            }
            self.refcount[b as usize] += 1;
        }
        Ok(())
    }

    /// Drop a reference; blocks return to the free list at refcount 0.
    pub fn release(&mut self, blocks: &[BlockId]) -> Result<()> {
        for &b in blocks {
            if b as usize >= self.capacity {
                bail!("release of out-of-range block {b} \
                       (capacity {})", self.capacity);
            }
            let rc = &mut self.refcount[b as usize];
            if *rc == 0 {
                bail!("double free of block {b}");
            }
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Blocks a request of `prompt_len` (+ decode headroom) needs across
    /// `num_layers` layers.
    pub fn blocks_needed(prompt_len: usize, decode: usize,
                         num_layers: usize) -> usize {
        let tokens = prompt_len + decode;
        tokens.div_ceil(crate::BLOCK_SIZE) * num_layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = KvAllocator::new(8);
        let b = a.alloc(5).unwrap();
        assert_eq!(a.available(), 3);
        a.release(&b).unwrap();
        assert_eq!(a.available(), 8);
    }

    #[test]
    fn all_or_nothing() {
        let mut a = KvAllocator::new(4);
        let _b = a.alloc(3).unwrap();
        assert!(a.alloc(2).is_err());
        assert_eq!(a.available(), 1);
    }

    #[test]
    fn refcount_sharing() {
        let mut a = KvAllocator::new(4);
        let b = a.alloc(2).unwrap();
        a.retain(&b).unwrap();
        a.release(&b).unwrap();
        assert_eq!(a.available(), 2); // still held by second ref
        a.release(&b).unwrap();
        assert_eq!(a.available(), 4);
    }

    #[test]
    fn double_free_detected() {
        let mut a = KvAllocator::new(2);
        let b = a.alloc(1).unwrap();
        a.release(&b).unwrap();
        assert!(a.release(&b).is_err());
    }

    #[test]
    fn failed_alloc_is_all_or_nothing() {
        // an over-ask must not partially drain the free list — the
        // scheduler's re-queue path relies on the allocator being
        // unchanged after a refused allocation
        let mut a = KvAllocator::new(4);
        let held = a.alloc(3).unwrap();
        assert!(a.alloc(2).is_err());
        assert_eq!(a.available(), 1, "failed alloc must not consume");
        assert_eq!(a.used(), 3);
        // the refused request succeeds verbatim once blocks free up —
        // exactly the admission re-queue contract
        a.release(&held).unwrap();
        assert!(a.can_alloc(2));
        let b = a.alloc(2).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(a.used(), 2);
    }

    #[test]
    fn exhaustion_probe_matches_alloc() {
        // can_alloc (the admission probe) must agree with alloc at the
        // boundary, including the empty allocation
        let mut a = KvAllocator::new(2);
        assert!(a.can_alloc(0) && a.can_alloc(2) && !a.can_alloc(3));
        let b = a.alloc(2).unwrap();
        assert!(a.can_alloc(0) && !a.can_alloc(1));
        assert!(a.alloc(1).is_err());
        let empty = a.alloc(0).unwrap();
        assert!(empty.is_empty());
        a.release(&b).unwrap();
        assert!(a.can_alloc(2));
    }

    #[test]
    fn retain_of_free_block_errors() {
        let mut a = KvAllocator::new(2);
        let b = a.alloc(1).unwrap();
        a.release(&b).unwrap();
        assert!(a.retain(&b).is_err(), "retain of a free block");
        // allocator must still be usable
        assert_eq!(a.available(), 2);
        assert!(a.alloc(2).is_ok());
    }

    #[test]
    fn refcounted_release_protects_against_double_free() {
        // one alloc + one retain = two owners; a third release is a
        // double free and must be detected, not corrupt the free list
        let mut a = KvAllocator::new(2);
        let b = a.alloc(2).unwrap();
        a.retain(&b).unwrap();
        a.release(&b).unwrap();
        assert_eq!(a.available(), 0, "still held by the second owner");
        a.release(&b).unwrap();
        assert_eq!(a.available(), 2);
        assert!(a.release(&b).is_err(), "third release is a double free");
        // conservation after the failed release: nothing double-freed
        assert_eq!(a.available(), 2);
        let c = a.alloc(2).unwrap();
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 2, "free list must hold unique blocks");
    }

    #[test]
    fn out_of_range_block_id_is_an_error_not_a_panic() {
        // a corrupt BlockId from a confused caller must come back as a
        // structured error like the double-free path does — not panic
        // the engine thread on an unchecked index (PR 6's documented
        // indexing-panic lint blind spot, closed here)
        let mut a = KvAllocator::new(4);
        let held = a.alloc(2).unwrap();
        assert!(a.retain(&[99]).is_err(), "retain past capacity");
        assert!(a.release(&[99]).is_err(), "release past capacity");
        assert!(a.release(&[4]).is_err(), "first id past capacity");
        // allocator must stay coherent and usable afterwards
        assert_eq!(a.used(), 2);
        a.release(&held).unwrap();
        assert_eq!(a.available(), 4);
        // zero-capacity allocator: every id is out of range
        let mut z = KvAllocator::new(0);
        assert!(z.retain(&[0]).is_err());
        assert!(z.release(&[0]).is_err());
    }

    #[test]
    fn blocks_needed_math() {
        assert_eq!(KvAllocator::blocks_needed(64, 0, 2), 2);
        assert_eq!(KvAllocator::blocks_needed(65, 0, 2), 4);
        assert_eq!(KvAllocator::blocks_needed(60, 8, 1), 2);
    }

    #[test]
    fn prop_no_double_allocation_and_conservation() {
        property("kv allocator conservation", 100, |g: &mut Gen| {
            let cap = g.usize_in(1..32);
            let mut a = KvAllocator::new(cap);
            let mut held: Vec<Vec<BlockId>> = Vec::new();
            for _ in 0..40 {
                if g.bool() {
                    let n = g.usize_in(0..cap + 2);
                    if let Ok(b) = a.alloc(n) {
                        // no block appears twice across live allocations
                        for &x in &b {
                            for h in &held {
                                assert!(!h.contains(&x),
                                        "block {x} double-allocated");
                            }
                        }
                        held.push(b);
                    }
                } else if !held.is_empty() {
                    let i = g.usize_in(0..held.len());
                    let b = held.swap_remove(i);
                    a.release(&b).unwrap();
                }
                let live: usize = held.iter().map(Vec::len).sum();
                assert_eq!(a.used(), live, "conservation violated");
            }
        });
    }
}
