//! Paged KV-cache block allocator: refcounted logical blocks with a free
//! list, in the vLLM style.  The scheduler uses it for admission control
//! (a request needs `ceil(len / BLOCK_SIZE) * num_layers` blocks for its
//! whole lifetime); the engine owns the physical tensors.
//!
//! Refcounting exists so shared prefixes (same prompt served to multiple
//! requests) can share blocks — exercised by the property tests and the
//! content-addressed [`PrefixIndex`]: completed prefills publish their
//! full prompt chunks under a chained hash, warm requests `retain` the
//! matched prefix and start prefill at the first divergent chunk, and
//! [`KvAllocator::make_exclusive`] is the copy-on-write primitive that
//! keeps shared blocks immutable (a block with refcount > 1 is cloned
//! before any writer may touch it).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Logical block handle.
pub type BlockId = u32;

/// Refcounted paged KV block allocator.
///
/// Hands out logical block ids from a bounded free list; the engine owns
/// the physical tensors behind them.  Every allocated block starts at
/// refcount 1; [`retain`](KvAllocator::retain) adds sharers (prefix
/// reuse) and [`release`](KvAllocator::release) drops them, returning
/// the block to the free list at zero.  Misuse (double free, retain of a
/// free block, out-of-range id) is a structured error, never a panic.
///
/// ```
/// use shareprefill::serving::kvcache::KvAllocator;
///
/// let mut kv = KvAllocator::new(8);
/// let blocks = kv.alloc(2).unwrap();
/// kv.retain(&blocks).unwrap();   // a second owner (prefix sharing)
/// kv.release(&blocks).unwrap();  // first owner gone ...
/// assert_eq!(kv.used(), 2);      // ... but the blocks stay live
/// kv.release(&blocks).unwrap();
/// assert_eq!(kv.used(), 0);
/// ```
#[derive(Debug)]
pub struct KvAllocator {
    capacity: usize,
    free: Vec<BlockId>,
    refcount: Vec<u16>,
}

impl KvAllocator {
    pub fn new(capacity: usize) -> KvAllocator {
        KvAllocator {
            capacity,
            free: (0..capacity as BlockId).rev().collect(),
            refcount: vec![0; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Would an `n`-block allocation succeed right now?  (Admission
    /// probe: the scheduler re-queues rather than rejects on false.)
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Allocate `n` blocks (all-or-nothing).
    pub fn alloc(&mut self, n: usize) -> Result<Vec<BlockId>> {
        if self.free.len() < n {
            bail!("kv cache exhausted: want {n}, have {}", self.free.len());
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.free.pop()
                .expect("invariant: free list holds >= n blocks \
                         (length-checked above, &mut self held)");
            debug_assert_eq!(self.refcount[b as usize], 0);
            self.refcount[b as usize] = 1;
            out.push(b);
        }
        Ok(out)
    }

    /// Increase refcount (prefix sharing).
    pub fn retain(&mut self, blocks: &[BlockId]) -> Result<()> {
        for &b in blocks {
            if b as usize >= self.capacity {
                bail!("retain of out-of-range block {b} \
                       (capacity {})", self.capacity);
            }
            if self.refcount[b as usize] == 0 {
                bail!("retain of free block {b}");
            }
            self.refcount[b as usize] += 1;
        }
        Ok(())
    }

    /// Drop a reference; blocks return to the free list at refcount 0.
    pub fn release(&mut self, blocks: &[BlockId]) -> Result<()> {
        for &b in blocks {
            if b as usize >= self.capacity {
                bail!("release of out-of-range block {b} \
                       (capacity {})", self.capacity);
            }
            let rc = &mut self.refcount[b as usize];
            if *rc == 0 {
                bail!("double free of block {b}");
            }
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Blocks a request of `prompt_len` (+ decode headroom) needs across
    /// `num_layers` layers.
    pub fn blocks_needed(prompt_len: usize, decode: usize,
                         num_layers: usize) -> usize {
        let tokens = prompt_len + decode;
        tokens.div_ceil(crate::BLOCK_SIZE) * num_layers
    }

    /// Current refcount of `b`, or `None` past capacity.  Diagnostic
    /// visibility for the copy-on-write and prefix-sharing invariants
    /// (the fuzz harness asserts no block is mutated at refcount > 1).
    pub fn refcount(&self, b: BlockId) -> Option<u16> {
        self.refcount.get(b as usize).copied()
    }

    /// Copy-on-write primitive: return a block the caller may mutate.
    ///
    /// A block held by exactly one owner is returned as-is; a shared
    /// block (refcount > 1) has the caller's reference moved onto a
    /// freshly allocated block — the other owners keep the original
    /// untouched.  Fails without side effects when the free list cannot
    /// supply the clone.
    ///
    /// ```
    /// use shareprefill::serving::kvcache::KvAllocator;
    ///
    /// let mut kv = KvAllocator::new(4);
    /// let b = kv.alloc(1).unwrap()[0];
    /// assert_eq!(kv.make_exclusive(b).unwrap(), b); // sole owner
    /// kv.retain(&[b]).unwrap();                     // now shared
    /// let mine = kv.make_exclusive(b).unwrap();
    /// assert_ne!(mine, b, "shared block is cloned before mutation");
    /// assert_eq!(kv.refcount(b), Some(1));
    /// ```
    pub fn make_exclusive(&mut self, b: BlockId) -> Result<BlockId> {
        if b as usize >= self.capacity {
            bail!("make_exclusive of out-of-range block {b} \
                   (capacity {})", self.capacity);
        }
        match self.refcount[b as usize] {
            0 => bail!("make_exclusive of free block {b}"),
            1 => Ok(b),
            _ => {
                let Some(fresh) = self.free.pop() else {
                    bail!("kv cache exhausted: copy-on-write of shared \
                           block {b} needs a free block");
                };
                self.refcount[b as usize] -= 1;
                debug_assert_eq!(self.refcount[fresh as usize], 0);
                self.refcount[fresh as usize] = 1;
                Ok(fresh)
            }
        }
    }
}

/// Chain-hash a prompt into one 64-bit commitment per full KV block's
/// worth of tokens (`BLOCK_SIZE`).  FNV-1a over the little-endian token
/// bytes, *chained*: chunk `k`'s hash folds in everything before it, so
/// equal hashes mean equal whole prefixes (up to 64-bit collisions) and
/// a [`PrefixIndex`] entry is reachable only through its full ancestry.
/// The trailing partial chunk is never hashed — only block-aligned
/// prefixes are shareable.
pub fn chain_hashes(tokens: &[i32]) -> Vec<u64> {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut out = Vec::with_capacity(tokens.len() / crate::BLOCK_SIZE);
    for chunk in tokens.chunks_exact(crate::BLOCK_SIZE) {
        for &t in chunk {
            for byte in t.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        }
        out.push(h);
    }
    out
}

#[derive(Debug)]
struct PrefixEntry {
    /// One block per layer for this chunk (layer-major within the chunk).
    blocks: Vec<BlockId>,
    /// LRU stamp — larger is more recently used.
    stamp: u64,
}

/// Content-addressed index from chained prompt-chunk hashes to
/// refcounted KV block lists — the prefix-sharing cache.
///
/// Completed prefills [`insert`](PrefixIndex::insert) their full prompt
/// chunks; admission [`probe`](PrefixIndex::probe)s for the longest
/// cached prefix and [`acquire`](PrefixIndex::acquire)s it, retaining
/// the matched blocks for the new session so prefill can start at the
/// first divergent chunk.  The index holds its own reference on every
/// cached block, so LRU eviction (bounded by `capacity` entries) only
/// releases the *index's* retain — live sessions sharing the block keep
/// theirs, and the allocator frees the block when the last one ends.
///
/// ```
/// use shareprefill::serving::kvcache::{KvAllocator, PrefixIndex};
///
/// let layers = 2;
/// let mut kv = KvAllocator::new(64);
/// let mut idx = PrefixIndex::new(16);
/// let prompt: Vec<i32> = (0..128).collect(); // two full chunks
///
/// // cold request: prefill computed everything, then published
/// let blocks = kv.alloc(2 * layers).unwrap();
/// idx.insert(&prompt, &blocks, layers, &mut kv).unwrap();
///
/// // warm request with the same prompt: both chunks hit
/// let (matched, shared) = idx.acquire(&prompt, &mut kv).unwrap();
/// assert_eq!((matched, shared.len()), (2, 2 * layers));
/// assert_eq!(shared, blocks, "same physical blocks, new retain");
/// # kv.release(&shared).unwrap();
/// # kv.release(&blocks).unwrap();
/// # idx.clear(&mut kv).unwrap();
/// # assert_eq!(kv.used(), 0);
/// ```
#[derive(Debug)]
pub struct PrefixIndex {
    entries: BTreeMap<u64, PrefixEntry>,
    capacity: usize,
    clock: u64,
}

impl PrefixIndex {
    /// An index bounded to `capacity` chunk entries (LRU beyond that).
    pub fn new(capacity: usize) -> PrefixIndex {
        PrefixIndex { entries: BTreeMap::new(), capacity, clock: 0 }
    }

    /// Number of cached chunk entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no chunks are cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total KV blocks the index itself holds a reference on.
    pub fn block_count(&self) -> usize {
        self.entries.values().map(|e| e.blocks.len()).sum()
    }

    /// How many leading full chunks of `tokens` are cached, without
    /// retaining anything — the admission probe (`can_alloc` is asked
    /// only for the suffix this many chunks exclude).
    pub fn probe(&self, tokens: &[i32]) -> usize {
        chain_hashes(tokens).iter()
            .take_while(|h| self.entries.contains_key(h))
            .count()
    }

    /// Claim the longest cached prefix of `tokens` for a new session:
    /// every matched chunk's blocks are `retain`ed on the session's
    /// behalf and LRU-touched.  Returns `(matched_chunks, blocks)` with
    /// the blocks chunk-major (chunk `k`'s layers at
    /// `[k*layers .. (k+1)*layers]`), matching the scheduler's session
    /// block layout.
    pub fn acquire(&mut self, tokens: &[i32], kv: &mut KvAllocator)
                   -> Result<(usize, Vec<BlockId>)> {
        self.clock += 1;
        let mut out = Vec::new();
        let mut matched = 0;
        for h in chain_hashes(tokens) {
            let Some(e) = self.entries.get_mut(&h) else { break };
            kv.retain(&e.blocks)?;
            e.stamp = self.clock;
            out.extend_from_slice(&e.blocks);
            matched += 1;
        }
        Ok((matched, out))
    }

    /// Publish a completed prefill: index every full chunk of `tokens`
    /// whose hash is not yet cached, retaining its `layers` blocks on
    /// the index's behalf (`blocks` chunk-major, as handed to the
    /// session).  Already-cached chunks are LRU-touched; at `capacity`
    /// the least-recently-used entry is evicted first, releasing only
    /// the index's own retain.
    pub fn insert(&mut self, tokens: &[i32], blocks: &[BlockId],
                  layers: usize, kv: &mut KvAllocator) -> Result<()> {
        if self.capacity == 0 || layers == 0 {
            return Ok(());
        }
        self.clock += 1;
        for (k, h) in chain_hashes(tokens).into_iter().enumerate() {
            let lo = k * layers;
            let hi = lo + layers;
            if hi > blocks.len() {
                break;
            }
            if let Some(e) = self.entries.get_mut(&h) {
                e.stamp = self.clock;
                continue;
            }
            while self.entries.len() >= self.capacity {
                self.evict_lru(kv)?;
            }
            kv.retain(&blocks[lo..hi])?;
            self.entries.insert(h, PrefixEntry {
                blocks: blocks[lo..hi].to_vec(),
                stamp: self.clock,
            });
        }
        Ok(())
    }

    /// Release every retain the index holds and forget all entries
    /// (shutdown / leak accounting; live sessions keep their own
    /// references).
    pub fn clear(&mut self, kv: &mut KvAllocator) -> Result<()> {
        for (_, e) in std::mem::take(&mut self.entries) {
            kv.release(&e.blocks)?;
        }
        Ok(())
    }

    /// Evict the single least-recently-used entry, releasing only the
    /// index's own retain; `false` when there was nothing to evict.
    /// The scheduler calls this under allocator pressure — cached
    /// prefixes are a luxury that must never starve a live admission.
    pub fn evict_one(&mut self, kv: &mut KvAllocator) -> Result<bool> {
        if self.entries.is_empty() {
            return Ok(false);
        }
        self.evict_lru(kv)?;
        Ok(true)
    }

    fn evict_lru(&mut self, kv: &mut KvAllocator) -> Result<()> {
        // oldest stamp wins; hash breaks ties deterministically
        let mut victim: Option<(u64, u64)> = None;
        for (&h, e) in &self.entries {
            match victim {
                Some((s, vh)) if (s, vh) <= (e.stamp, h) => {}
                _ => victim = Some((e.stamp, h)),
            }
        }
        let Some((_, h)) = victim else { return Ok(()) };
        let Some(e) = self.entries.remove(&h) else { return Ok(()) };
        kv.release(&e.blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = KvAllocator::new(8);
        let b = a.alloc(5).unwrap();
        assert_eq!(a.available(), 3);
        a.release(&b).unwrap();
        assert_eq!(a.available(), 8);
    }

    #[test]
    fn all_or_nothing() {
        let mut a = KvAllocator::new(4);
        let _b = a.alloc(3).unwrap();
        assert!(a.alloc(2).is_err());
        assert_eq!(a.available(), 1);
    }

    #[test]
    fn refcount_sharing() {
        let mut a = KvAllocator::new(4);
        let b = a.alloc(2).unwrap();
        a.retain(&b).unwrap();
        a.release(&b).unwrap();
        assert_eq!(a.available(), 2); // still held by second ref
        a.release(&b).unwrap();
        assert_eq!(a.available(), 4);
    }

    #[test]
    fn double_free_detected() {
        let mut a = KvAllocator::new(2);
        let b = a.alloc(1).unwrap();
        a.release(&b).unwrap();
        assert!(a.release(&b).is_err());
    }

    #[test]
    fn failed_alloc_is_all_or_nothing() {
        // an over-ask must not partially drain the free list — the
        // scheduler's re-queue path relies on the allocator being
        // unchanged after a refused allocation
        let mut a = KvAllocator::new(4);
        let held = a.alloc(3).unwrap();
        assert!(a.alloc(2).is_err());
        assert_eq!(a.available(), 1, "failed alloc must not consume");
        assert_eq!(a.used(), 3);
        // the refused request succeeds verbatim once blocks free up —
        // exactly the admission re-queue contract
        a.release(&held).unwrap();
        assert!(a.can_alloc(2));
        let b = a.alloc(2).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(a.used(), 2);
    }

    #[test]
    fn exhaustion_probe_matches_alloc() {
        // can_alloc (the admission probe) must agree with alloc at the
        // boundary, including the empty allocation
        let mut a = KvAllocator::new(2);
        assert!(a.can_alloc(0) && a.can_alloc(2) && !a.can_alloc(3));
        let b = a.alloc(2).unwrap();
        assert!(a.can_alloc(0) && !a.can_alloc(1));
        assert!(a.alloc(1).is_err());
        let empty = a.alloc(0).unwrap();
        assert!(empty.is_empty());
        a.release(&b).unwrap();
        assert!(a.can_alloc(2));
    }

    #[test]
    fn retain_of_free_block_errors() {
        let mut a = KvAllocator::new(2);
        let b = a.alloc(1).unwrap();
        a.release(&b).unwrap();
        assert!(a.retain(&b).is_err(), "retain of a free block");
        // allocator must still be usable
        assert_eq!(a.available(), 2);
        assert!(a.alloc(2).is_ok());
    }

    #[test]
    fn refcounted_release_protects_against_double_free() {
        // one alloc + one retain = two owners; a third release is a
        // double free and must be detected, not corrupt the free list
        let mut a = KvAllocator::new(2);
        let b = a.alloc(2).unwrap();
        a.retain(&b).unwrap();
        a.release(&b).unwrap();
        assert_eq!(a.available(), 0, "still held by the second owner");
        a.release(&b).unwrap();
        assert_eq!(a.available(), 2);
        assert!(a.release(&b).is_err(), "third release is a double free");
        // conservation after the failed release: nothing double-freed
        assert_eq!(a.available(), 2);
        let c = a.alloc(2).unwrap();
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 2, "free list must hold unique blocks");
    }

    #[test]
    fn out_of_range_block_id_is_an_error_not_a_panic() {
        // a corrupt BlockId from a confused caller must come back as a
        // structured error like the double-free path does — not panic
        // the engine thread on an unchecked index (PR 6's documented
        // indexing-panic lint blind spot, closed here)
        let mut a = KvAllocator::new(4);
        let held = a.alloc(2).unwrap();
        assert!(a.retain(&[99]).is_err(), "retain past capacity");
        assert!(a.release(&[99]).is_err(), "release past capacity");
        assert!(a.release(&[4]).is_err(), "first id past capacity");
        // allocator must stay coherent and usable afterwards
        assert_eq!(a.used(), 2);
        a.release(&held).unwrap();
        assert_eq!(a.available(), 4);
        // zero-capacity allocator: every id is out of range
        let mut z = KvAllocator::new(0);
        assert!(z.retain(&[0]).is_err());
        assert!(z.release(&[0]).is_err());
    }

    #[test]
    fn blocks_needed_math() {
        assert_eq!(KvAllocator::blocks_needed(64, 0, 2), 2);
        assert_eq!(KvAllocator::blocks_needed(65, 0, 2), 4);
        assert_eq!(KvAllocator::blocks_needed(60, 8, 1), 2);
    }

    #[test]
    fn prop_no_double_allocation_and_conservation() {
        property("kv allocator conservation", 100, |g: &mut Gen| {
            let cap = g.usize_in(1..32);
            let mut a = KvAllocator::new(cap);
            let mut held: Vec<Vec<BlockId>> = Vec::new();
            for _ in 0..40 {
                if g.bool() {
                    let n = g.usize_in(0..cap + 2);
                    if let Ok(b) = a.alloc(n) {
                        // no block appears twice across live allocations
                        for &x in &b {
                            for h in &held {
                                assert!(!h.contains(&x),
                                        "block {x} double-allocated");
                            }
                        }
                        held.push(b);
                    }
                } else if !held.is_empty() {
                    let i = g.usize_in(0..held.len());
                    let b = held.swap_remove(i);
                    a.release(&b).unwrap();
                }
                let live: usize = held.iter().map(Vec::len).sum();
                assert_eq!(a.used(), live, "conservation violated");
            }
        });
    }

    #[test]
    fn make_exclusive_cow_semantics() {
        let mut a = KvAllocator::new(4);
        let b = a.alloc(1).unwrap()[0];
        // sole owner: no clone
        assert_eq!(a.make_exclusive(b).unwrap(), b);
        assert_eq!(a.used(), 1);
        // shared: caller's ref moves to a fresh block, sharer keeps b
        a.retain(&[b]).unwrap();
        let mine = a.make_exclusive(b).unwrap();
        assert_ne!(mine, b);
        assert_eq!(a.refcount(b), Some(1));
        assert_eq!(a.refcount(mine), Some(1));
        assert_eq!(a.used(), 2, "clone is a real allocation");
        a.release(&[b]).unwrap();
        a.release(&[mine]).unwrap();
        assert_eq!(a.used(), 0, "no leak through the COW path");
    }

    #[test]
    fn make_exclusive_misuse_is_an_error_not_a_panic() {
        let mut a = KvAllocator::new(2);
        assert!(a.make_exclusive(9).is_err(), "out of range");
        let b = a.alloc(1).unwrap();
        a.release(&b).unwrap();
        assert!(a.make_exclusive(b[0]).is_err(), "free block");
        // exhausted free list: the shared block must stay shared (no
        // side effects on a refused clone)
        let held = a.alloc(2).unwrap();
        a.retain(&held[..1]).unwrap();
        assert!(a.make_exclusive(held[0]).is_err());
        assert_eq!(a.refcount(held[0]), Some(2), "refused COW is a no-op");
        a.release(&held[..1]).unwrap();
        a.release(&held).unwrap();
        assert_eq!(a.used(), 0);
    }

    /// `chunks` full chunks of the constant token `tag` — the shared
    /// prefixes the index tests key on.
    fn chunk_prompt(tag: i32, chunks: usize) -> Vec<i32> {
        vec![tag; crate::BLOCK_SIZE * chunks]
    }

    #[test]
    fn chain_hashes_commit_to_the_whole_prefix() {
        let bs = crate::BLOCK_SIZE;
        assert!(chain_hashes(&[]).is_empty());
        let mut partial = chunk_prompt(1, 1);
        partial.pop();
        assert!(chain_hashes(&partial).is_empty(),
                "partial chunks are never hashed");
        let a: Vec<i32> = (0..2 * bs as i32).collect();
        let ha = chain_hashes(&a);
        assert_eq!(ha.len(), 2);
        // same prefix ⇒ same hashes, regardless of what follows
        let mut b = a.clone();
        b.extend_from_slice(&[7; 10]);
        assert_eq!(chain_hashes(&b)[..2], ha[..]);
        // a different FIRST chunk changes the SECOND hash too (chained)
        let mut c = a.clone();
        c[0] += 1;
        let hc = chain_hashes(&c);
        assert_ne!(hc[0], ha[0]);
        assert_ne!(hc[1], ha[1], "chunk hash must commit to ancestry");
        // same second chunk after different firsts must not collide into
        // the same index slot
        assert_ne!(hc[1], hc[0]);
    }

    #[test]
    fn prefix_index_roundtrip_and_divergence() {
        let bs = crate::BLOCK_SIZE;
        let layers = 3;
        let mut kv = KvAllocator::new(64);
        let mut idx = PrefixIndex::new(8);
        let prompt: Vec<i32> = (0..2 * bs as i32).collect();
        let blocks = kv.alloc(2 * layers).unwrap();
        idx.insert(&prompt, &blocks, layers, &mut kv).unwrap();
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.block_count(), 2 * layers);
        for &b in &blocks {
            assert_eq!(kv.refcount(b), Some(2), "index holds its own ref");
        }

        // identical prompt: full hit, chunk-major block layout
        assert_eq!(idx.probe(&prompt), 2);
        let (m, shared) = idx.acquire(&prompt, &mut kv).unwrap();
        assert_eq!(m, 2);
        assert_eq!(shared, blocks);
        assert_eq!(kv.refcount(blocks[0]), Some(3));

        // divergence in the second chunk: only the first chunk matches
        let mut div = prompt.clone();
        div[bs] += 1;
        assert_eq!(idx.probe(&div), 1);
        let (m2, s2) = idx.acquire(&div, &mut kv).unwrap();
        assert_eq!(m2, 1);
        assert_eq!(&s2[..], &blocks[..layers]);

        // sessions done, index flushed: everything returns to the pool
        kv.release(&shared).unwrap();
        kv.release(&s2).unwrap();
        kv.release(&blocks).unwrap();
        idx.clear(&mut kv).unwrap();
        assert_eq!(kv.used(), 0, "zero KV leak through the index");
    }

    #[test]
    fn prefix_index_lru_eviction_respects_refcounts() {
        let layers = 1;
        let mut kv = KvAllocator::new(16);
        let mut idx = PrefixIndex::new(2); // two chunk entries max
        let p1 = chunk_prompt(1, 1);
        let p2 = chunk_prompt(2, 1);
        let p3 = chunk_prompt(3, 1);
        let p4 = chunk_prompt(4, 1);
        let b1 = kv.alloc(1).unwrap();
        let b2 = kv.alloc(1).unwrap();
        idx.insert(&p1, &b1, layers, &mut kv).unwrap();
        idx.insert(&p2, &b2, layers, &mut kv).unwrap();
        // a live session still shares p1's block (and touches its LRU)
        let (_, live) = idx.acquire(&p1, &mut kv).unwrap();
        // p1 was just touched, so inserting p3 evicts p2 (the LRU)
        let b3 = kv.alloc(1).unwrap();
        idx.insert(&p3, &b3, layers, &mut kv).unwrap();
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.probe(&p2), 0, "LRU entry evicted");
        assert_eq!(idx.probe(&p1), 1);
        assert_eq!(idx.probe(&p3), 1);
        // force p1's eviction too: the live session must keep its ref
        let b4 = kv.alloc(1).unwrap();
        idx.insert(&p4, &b4, layers, &mut kv).unwrap();
        assert_eq!(idx.probe(&p1), 0, "p1 was the LRU this time");
        assert_eq!(kv.refcount(live[0]), Some(2),
                   "session keeps its retain after index eviction; the \
                    original owner holds the other");
        kv.release(&live).unwrap();
        assert_eq!(kv.refcount(live[0]), Some(1));
        // drain everything: owners drop, index flushes, pool refills
        for b in [&b1, &b2, &b3, &b4] {
            kv.release(b).unwrap();
        }
        idx.clear(&mut kv).unwrap();
        assert_eq!(kv.used(), 0, "zero KV leak after eviction churn");
    }

    #[test]
    fn prop_prefix_index_conservation() {
        // randomized insert/acquire/release against the index: at every
        // step used() == blocks held by live sessions + index retains,
        // and a final clear() returns the allocator to empty
        property("prefix index conservation", 60, |g: &mut Gen| {
            let bs = crate::BLOCK_SIZE;
            let layers = 1 + g.usize_in(0..3);
            let mut kv = KvAllocator::new(128);
            let mut idx = PrefixIndex::new(1 + g.usize_in(0..6));
            let mut sessions: Vec<Vec<BlockId>> = Vec::new();
            for _ in 0..30 {
                match g.usize_in(0..3) {
                    0 => {
                        // cold-ish request: acquire prefix, alloc suffix,
                        // publish the full chunks
                        let chunks = 1 + g.usize_in(0..4);
                        let tag = g.usize_in(0..3) as i32;
                        let prompt: Vec<i32> = (0..chunks * bs)
                            .map(|i| tag + (i / bs) as i32).collect();
                        let (m, mut blocks) =
                            idx.acquire(&prompt, &mut kv).unwrap();
                        let need = (chunks - m) * layers;
                        if !kv.can_alloc(need) {
                            kv.release(&blocks).unwrap();
                            continue;
                        }
                        blocks.extend(kv.alloc(need).unwrap());
                        idx.insert(&prompt, &blocks, layers, &mut kv)
                            .unwrap();
                        sessions.push(blocks);
                    }
                    1 if !sessions.is_empty() => {
                        let i = g.usize_in(0..sessions.len());
                        let blocks = sessions.swap_remove(i);
                        kv.release(&blocks).unwrap();
                    }
                    _ => {
                        // COW poke: a shared session block must clone
                        if let Some(s) = sessions.first_mut() {
                            let b = s[0];
                            if kv.refcount(b).unwrap_or(0) > 1 {
                                if let Ok(nb) = kv.make_exclusive(b) {
                                    s[0] = nb;
                                }
                            }
                        }
                    }
                }
                // refcount-unit conservation: every session slot and
                // every index entry owns exactly one reference (used()
                // counts distinct blocks, which sharing makes smaller)
                let live: usize = sessions.iter().map(Vec::len).sum();
                let units: usize = (0..kv.capacity())
                    .map(|b| kv.refcount(b as BlockId).unwrap_or(0)
                             as usize)
                    .sum();
                assert_eq!(units, live + idx.block_count(),
                           "refcount conservation violated");
                assert!(kv.used() <= units, "used() over-counts");
            }
            for s in sessions {
                kv.release(&s).unwrap();
            }
            idx.clear(&mut kv).unwrap();
            assert_eq!(kv.used(), 0, "leak after drain + clear");
        });
    }
}
