//! Offline head clustering (paper Section 5.2 "Offline Clustering of
//! Similar Heads") and the Figure 2 similarity analysis.
//!
//! Pipeline (`shareprefill cluster`): run a dense prefill on a calibration
//! sample (the paper uses one Retr.KV sample), collect each head's
//! block-averaged attention map, compress (block-pooled features + PCA —
//! the linear stand-in for the paper's conv autoencoder, DESIGN.md
//! "Substitutions"), L2-normalize, agglomerative-cluster with a distance
//! threshold, and dissolve clusters smaller than 5 into noise.  Only the
//! (layer, head) → cluster table is persisted; actual patterns are always
//! constructed online from live inputs.

pub mod features;
pub mod offline;
pub mod similarity;

pub use features::head_features;
pub use offline::{cluster_heads, load_clusters, save_clusters, HeadClusters};
pub use similarity::{jaccard_matrix, pattern_of_map};
