//! Figure 2 machinery: binarized patterns from attention maps and the
//! head × head Jaccard similarity matrix.

use crate::attention::BlockMask;
use crate::util::math::{cumulative_select, softmax_inplace};

/// Binarize an `[nb, nb]` raw block-averaged QK map into a pattern via the
/// same row-softmax + flatten + cumulative-γ selection Alg. 2 uses.
pub fn pattern_of_map(abar: &[f32], nb: usize, gamma: f32) -> BlockMask {
    let mut scores = abar.to_vec();
    for i in 0..nb {
        softmax_inplace(&mut scores[i * nb..(i + 1) * nb]);
    }
    let total: f32 = scores.iter().sum();
    if total > 0.0 {
        scores.iter_mut().for_each(|x| *x /= total);
    }
    let mut mask = BlockMask::empty(nb);
    for flat in cumulative_select(&scores, gamma) {
        mask.insert(flat / nb, flat % nb);
    }
    mask
}

/// Pairwise Jaccard similarity of patterns: `[n, n]` row-major.
pub fn jaccard_matrix(patterns: &[BlockMask]) -> Vec<f64> {
    let n = patterns.len();
    let mut m = vec![0f64; n * n];
    for i in 0..n {
        m[i * n + i] = 1.0;
        for j in (i + 1)..n {
            let s = patterns[i].jaccard(&patterns[j]);
            m[i * n + j] = s;
            m[j * n + i] = s;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::NEG_INF;

    fn map_with(nb: usize, hot: &[(usize, usize)]) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = 0.0;
            }
        }
        for &(i, j) in hot {
            m[i * nb + j] = 8.0;
        }
        m
    }

    #[test]
    fn pattern_selects_hot_blocks() {
        let nb = 4;
        let m = map_with(nb, &[(2, 0), (3, 0)]);
        let p = pattern_of_map(&m, nb, 0.5);
        assert!(p.contains(2, 0));
        assert!(p.contains(3, 0));
        assert!(p.density() < 1.0);
    }

    #[test]
    fn matrix_symmetric_unit_diagonal() {
        let nb = 4;
        let a = pattern_of_map(&map_with(nb, &[(2, 0)]), nb, 0.6);
        let b = pattern_of_map(&map_with(nb, &[(3, 3)]), nb, 0.6);
        let c = pattern_of_map(&map_with(nb, &[(2, 0)]), nb, 0.6);
        let m = jaccard_matrix(&[a, b, c]);
        for i in 0..3 {
            assert!((m[i * 3 + i] - 1.0).abs() < 1e-12);
            for j in 0..3 {
                assert!((m[i * 3 + j] - m[j * 3 + i]).abs() < 1e-12);
            }
        }
        // identical patterns 0 and 2 more similar than 0 and 1
        assert!(m[2] > m[1]);
        assert!((m[2] - 1.0).abs() < 1e-12);
    }
}
