//! The offline clustering pipeline + the persisted cluster table.

use anyhow::{bail, Result};
use std::path::Path;

use crate::linalg::cluster::{agglomerative, NOISE};
use crate::linalg::pca::pca;
use crate::linalg::{euclidean, Mat};
use crate::substrate::json::{self, Json};

use super::features::head_features;

/// Persisted result: (layer * num_heads + head) → cluster (None = noise).
#[derive(Debug, Clone)]
pub struct HeadClusters {
    pub model: String,
    pub num_layers: usize,
    pub num_heads: usize,
    pub num_clusters: usize,
    pub assignment: Vec<Option<usize>>,
}

impl HeadClusters {
    pub fn cluster_of(&self, layer: usize, head: usize) -> Option<usize> {
        self.assignment[layer * self.num_heads + head]
    }

    /// Heads per cluster (observability).
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0; self.num_clusters];
        for a in self.assignment.iter().flatten() {
            s[*a] += 1;
        }
        s
    }
}

/// Cluster heads from their block-averaged attention maps.
///
/// * `maps[i]` — head i's `[nb, nb]` raw block-averaged QK map (dense run
///   on the calibration sample), i = layer * num_heads + head.
/// * `grid` — pooled feature grid (paper's AE latent ≈ 64 → 16×16 grid
///   reduced to `pca_dims`).
/// * `threshold` — agglomerative distance threshold.
/// * `min_size` — clusters smaller than this become noise (paper: 5).
pub fn cluster_heads(model: &str, num_layers: usize, num_heads: usize,
                     maps: &[Vec<f32>], nb: usize, grid: usize,
                     pca_dims: usize, threshold: f64, min_size: usize)
                     -> HeadClusters {
    assert_eq!(maps.len(), num_layers * num_heads);
    let feats: Vec<Vec<f64>> =
        maps.iter().map(|m| head_features(m, nb, grid)).collect();
    let x = Mat::from_rows(feats);
    let (scores, _) = pca(&x, pca_dims);
    // L2-normalize the compressed representations (as the paper does)
    let mut rows: Vec<Vec<f64>> = (0..scores.rows)
        .map(|i| scores.row(i).to_vec())
        .collect();
    for r in rows.iter_mut() {
        let n: f64 = r.iter().map(|x| x * x).sum::<f64>().sqrt();
        if n > 0.0 {
            r.iter_mut().for_each(|x| *x /= n);
        }
    }
    let c = agglomerative(rows.len(), threshold, min_size,
                          |i, j| euclidean(&rows[i], &rows[j]));
    HeadClusters {
        model: model.to_string(),
        num_layers,
        num_heads,
        num_clusters: c.num_clusters,
        assignment: c.assignment.iter()
            .map(|&a| if a == NOISE { None } else { Some(a) })
            .collect(),
    }
}

pub fn save_clusters(hc: &HeadClusters, path: &Path) -> Result<()> {
    let assignment: Vec<Json> = hc.assignment.iter()
        .map(|a| match a {
            Some(c) => Json::num(*c as f64),
            None => Json::num(-1.0),
        })
        .collect();
    let j = Json::obj(vec![
        ("model", Json::str(hc.model.clone())),
        ("num_layers", Json::num(hc.num_layers as f64)),
        ("num_heads", Json::num(hc.num_heads as f64)),
        ("num_clusters", Json::num(hc.num_clusters as f64)),
        ("assignment", Json::Arr(assignment)),
    ]);
    std::fs::write(path, j.to_string())?;
    Ok(())
}

pub fn load_clusters(path: &Path) -> Result<HeadClusters> {
    let j = json::parse(&std::fs::read_to_string(path)?)?;
    let num_layers = j.req("num_layers")?.as_usize()?;
    let num_heads = j.req("num_heads")?.as_usize()?;
    let assignment: Vec<Option<usize>> = j.req("assignment")?
        .as_arr()?
        .iter()
        .map(|v| {
            let n = v.as_f64()?;
            Ok(if n < 0.0 { None } else { Some(n as usize) })
        })
        .collect::<Result<_>>()?;
    if assignment.len() != num_layers * num_heads {
        bail!("cluster table length mismatch");
    }
    Ok(HeadClusters {
        model: j.req("model")?.as_str()?.to_string(),
        num_layers,
        num_heads,
        num_clusters: j.req("num_clusters")?.as_usize()?,
        assignment,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::NEG_INF;

    fn sink_map(nb: usize, strength: f32) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = if j == 0 { strength } else { 0.0 };
            }
        }
        m
    }

    fn diag_map(nb: usize) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = if j == i { 5.0 } else { 0.0 };
            }
        }
        m
    }

    #[test]
    fn groups_sink_and_diag_heads() {
        let nb = 8;
        // 2 layers × 4 heads: heads 0,1 sink-like, heads 2,3 diagonal-like
        let mut maps = Vec::new();
        for _layer in 0..2 {
            maps.push(sink_map(nb, 5.0));
            maps.push(sink_map(nb, 4.5));
            maps.push(diag_map(nb));
            maps.push(diag_map(nb));
        }
        let hc = cluster_heads("m", 2, 4, &maps, nb, 4, 8, 0.5, 2);
        assert!(hc.num_clusters >= 2, "found {}", hc.num_clusters);
        // sink heads in both layers share a cluster
        assert_eq!(hc.cluster_of(0, 0), hc.cluster_of(1, 1));
        assert_eq!(hc.cluster_of(0, 2), hc.cluster_of(1, 3));
        assert_ne!(hc.cluster_of(0, 0), hc.cluster_of(0, 2));
    }

    #[test]
    fn roundtrip_persistence() {
        let hc = HeadClusters {
            model: "m".into(),
            num_layers: 1,
            num_heads: 3,
            num_clusters: 1,
            assignment: vec![Some(0), None, Some(0)],
        };
        let path = std::env::temp_dir().join("hc_test.json");
        save_clusters(&hc, &path).unwrap();
        let back = load_clusters(&path).unwrap();
        assert_eq!(back.assignment, hc.assignment);
        assert_eq!(back.num_clusters, 1);
        assert_eq!(back.sizes(), vec![2]);
        std::fs::remove_file(path).ok();
    }
}
