//! Feature extraction for head clustering: block-averaged attention map →
//! fixed-size pooled grid (dimension-independent across seq buckets) →
//! flattened feature vector.

use crate::util::math::softmax_inplace;

/// Pool an `[nb, nb]` row-softmaxed attention map onto a fixed `g × g`
/// grid by averaging cells (g defaults to 16 in the pipeline).  The map is
/// first row-softmaxed from raw block-averaged QK values so features are
/// scale-free.
pub fn head_features(abar: &[f32], nb: usize, g: usize) -> Vec<f64> {
    debug_assert_eq!(abar.len(), nb * nb);
    let mut scores = abar.to_vec();
    for i in 0..nb {
        softmax_inplace(&mut scores[i * nb..(i + 1) * nb]);
    }
    let g = g.min(nb);
    let mut out = vec![0f64; g * g];
    let mut counts = vec![0usize; g * g];
    for i in 0..nb {
        for j in 0..nb {
            let gi = i * g / nb;
            let gj = j * g / nb;
            out[gi * g + gj] += scores[i * nb + j] as f64;
            counts[gi * g + gj] += 1;
        }
    }
    for (o, c) in out.iter_mut().zip(&counts) {
        if *c > 0 {
            *o /= *c as f64;
        }
    }
    // L2 normalize (the paper normalizes compressed representations)
    let norm: f64 = out.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        out.iter_mut().for_each(|x| *x /= norm);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::NEG_INF;

    fn causal_map(nb: usize, f: impl Fn(usize, usize) -> f32) -> Vec<f32> {
        let mut m = vec![NEG_INF; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                m[i * nb + j] = f(i, j);
            }
        }
        m
    }

    #[test]
    fn features_unit_norm() {
        let m = causal_map(8, |_, _| 1.0);
        let f = head_features(&m, 8, 4);
        let n: f64 = f.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((n - 1.0).abs() < 1e-9);
        assert_eq!(f.len(), 16);
    }

    #[test]
    fn similar_maps_have_close_features() {
        let a = causal_map(8, |i, j| if j == 0 { 5.0 } else { 0.0 });
        let b = causal_map(8, |i, j| if j == 0 { 4.8 } else { 0.05 });
        let c = causal_map(8, |i, j| if i == j { 5.0 } else { 0.0 });
        let fa = head_features(&a, 8, 4);
        let fb = head_features(&b, 8, 4);
        let fc = head_features(&c, 8, 4);
        let dab = crate::linalg::euclidean(&fa, &fb);
        let dac = crate::linalg::euclidean(&fa, &fc);
        assert!(dab < dac, "sink≈sink ({dab}) should beat sink vs diag ({dac})");
    }

    #[test]
    fn g_clamped_to_nb() {
        let m = causal_map(2, |_, _| 1.0);
        let f = head_features(&m, 2, 16);
        assert_eq!(f.len(), 4);
    }
}
