//! Figure 5 bench: prefill latency vs. context length per method.
//! `cargo bench --bench fig5_latency` (BENCH_FAST=1 for a quick pass).

use shareprefill::bench::Bench;
use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::workloads::tasks::latency_prompt;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let fast = std::env::var("BENCH_FAST").is_ok();
    let ctxs: &[usize] = if fast { &[512, 1024] } else { &[512, 1024, 2048] };
    let mut b = Bench::new("fig5: prefill latency (sim-llama)")
        .with_iters(1, if fast { 1 } else { 3 });
    for kind in MethodKind::all() {
        let mut engine = build_engine(&registry, &cfg, "sim-llama", kind)?;
        for &ctx in ctxs {
            let prompt = latency_prompt(ctx);
            b.case(&format!("{}/{}", kind.name(), ctx), || {
                let pre = engine.prefill(&prompt).unwrap();
                pre.real_len
            });
        }
    }
    println!("\n{}", b.report());
    Ok(())
}
