//! Figure 1 bench: latency side of the accuracy/latency tradeoff (the
//! accuracy side comes from `--example tradeoff`).

use shareprefill::bench::Bench;
use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::workloads::tasks::latency_prompt;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let registry = open_registry(&cfg)?;
    let ctx = if std::env::var("BENCH_FAST").is_ok() { 512 } else { 1024 };
    let mut b = Bench::new(&format!("fig1: per-method latency @ {ctx}"))
        .with_iters(1, 2);
    for model in ["sim-llama", "sim-qwen"] {
        for kind in MethodKind::all() {
            let mut engine = build_engine(&registry, &cfg, model, kind)?;
            let prompt = latency_prompt(ctx);
            b.case(&format!("{model}/{}", kind.name()), || {
                engine.prefill(&prompt).unwrap().real_len
            });
        }
    }
    println!("\n{}", b.report());
    Ok(())
}
