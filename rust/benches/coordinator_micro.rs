//! L3 coordinator micro-bench: pattern-engine costs that must never rival
//! the attention compute — vslash search, pivotal construction, packing,
//! JS decisions, KV allocator churn, clustering, and the session
//! scheduler's continuous-batching round overhead (measured against the
//! artifact-free SimEngine so only coordinator bookkeeping is on the
//! clock).

use shareprefill::attention::{construct_pivotal, decide_pattern,
                              search_vslash, PivotalDict};
use shareprefill::bench::Bench;
use shareprefill::clustering::cluster_heads;
use shareprefill::config::ServeConfig;
use shareprefill::serving::kvcache::KvAllocator;
use shareprefill::serving::sim::SimEngine;
use shareprefill::serving::{EventSink, Request, Scheduler};
use shareprefill::util::rng::Rng;
use shareprefill::BLOCK_SIZE;

fn main() {
    let mut b = Bench::new("coordinator micro").with_iters(2, 5);
    let mut rng = Rng::new(1);
    let seq = 4096;
    let nb = seq / BLOCK_SIZE;
    let bs = BLOCK_SIZE;

    let amap: Vec<f32> = (0..bs * seq).map(|_| rng.f32()).collect();
    b.case("vslash_search @4096", || {
        std::hint::black_box(search_vslash(&amap, bs, seq, 0.65));
        1
    });

    let abar: Vec<f32> = (0..nb * nb).map(|_| rng.normal() as f32).collect();
    b.case("pivotal_construct @64x64", || {
        std::hint::black_box(construct_pivotal(&abar, nb, 0.65, (0, 0)));
        1
    });

    let mask = construct_pivotal(&abar, nb, 0.65, (0, 0)).mask;
    b.case("pack @64x64", || {
        std::hint::black_box(mask.pack(nb / 2));
        nb
    });

    let ahat: Vec<f32> = {
        let mut v: Vec<f32> = (0..nb).map(|_| rng.f32() + 0.01).collect();
        let s: f32 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    };
    let dict = PivotalDict::new();
    b.case("decide_pattern x48", || {
        for _ in 0..48 {
            std::hint::black_box(decide_pattern(&ahat, Some(0), &dict,
                                                0.3, 0.2));
        }
        48
    });

    b.case("kv alloc/release x1000", || {
        let mut a = KvAllocator::new(4096);
        for _ in 0..1000 {
            let blk = a.alloc(16).unwrap();
            a.release(&blk).unwrap();
        }
        1000
    });

    b.case("session rounds: 8 reqs, chunked+interleaved", || {
        let cfg = ServeConfig {
            max_batch_tokens: 512,
            chunk_layers: 1,
            decode_tokens: 8,
            ..Default::default()
        };
        let mut engine = SimEngine::new(6);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        for i in 0..8 {
            let (sink, _rx) = EventSink::channel();
            sched.submit(&engine, Request::new(i, vec![7; 256], 8), sink);
        }
        while sched.has_work() {
            sched.run_round(&mut engine).unwrap();
        }
        8
    });

    let maps: Vec<Vec<f32>> = (0..48)
        .map(|_| (0..nb * nb).map(|_| rng.normal() as f32).collect())
        .collect();
    b.case("offline clustering 48 heads", || {
        std::hint::black_box(cluster_heads("m", 6, 8, &maps, nb, 16, 64,
                                           0.6, 5));
        48
    });

    println!("\n{}", b.report());
}
