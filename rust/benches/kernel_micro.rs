//! L1 kernel micro-bench: budgeted attention artifact cost vs. budget —
//! verifies executed cost tracks the block budget (the §6.1 speedup
//! mechanism) and measures probe overhead.
//!
//! Two modes:
//!   * default — registry-backed artifacts (needs `make artifacts` and
//!     a PJRT runtime, so it cannot run in plain CI)
//!   * `--host-only [--json PATH]` — the host-side kernels the
//!     coordinator runs on every prefill (vslash search, thresholded
//!     FlashPrefill-style discovery, pivotal construction, mask
//!     packing, abar scatter), artifact-free.  The JSON (per-kernel
//!     mean_ms + ns_per_token) is merged into the bench-smoke
//!     trajectory artifact (`BENCH_9.json`) by CI, which schema-checks
//!     it and fails any kernel more than 15% over its committed
//!     ns/token.

use shareprefill::attention::{construct_pivotal, scatter_abar,
                              search_vslash, search_vslash_threshold,
                              BlockMask};
use shareprefill::bench::Bench;
use shareprefill::config::Config;
use shareprefill::eval::open_registry;
use shareprefill::runtime::Tensor;
use shareprefill::util::math::NEG_INF;
use shareprefill::util::rng::Rng;
use shareprefill::BLOCK_SIZE;

fn rand(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Bench the pure host kernels and (optionally) dump per-kernel JSON.
fn host_only(json_path: Option<&str>) -> anyhow::Result<()> {
    let seq = if std::env::var("BENCH_FAST").is_ok() { 1024 } else { 2048 };
    let nb = seq / BLOCK_SIZE;
    let bs = BLOCK_SIZE;
    let gamma = 0.9f32;
    let budget = nb / 4;
    let mut rng = Rng::new(7);

    // row-normalized probe map [bs, seq] (what the probe artifact
    // hands the coordinator)
    let mut amap = rand(&mut rng, bs * seq);
    for r in 0..bs {
        let row = &mut amap[r * seq..(r + 1) * seq];
        row.iter_mut().for_each(|x| *x = x.abs() + 1e-3);
        let sum: f32 = row.iter().sum();
        row.iter_mut().for_each(|x| *x /= sum);
    }
    // full block-averaged QK map, -inf above the diagonal
    let mut abar = vec![NEG_INF; nb * nb];
    for i in 0..nb {
        for j in 0..=i {
            abar[i * nb + j] = rng.normal() as f32;
        }
    }
    // a budgeted kernel output: slot values + causal band idx/valid
    let mut slots = vec![0f32; nb * budget];
    let mut idx = vec![0i32; nb * budget];
    let mut valid = vec![0f32; nb * budget];
    for i in 0..nb {
        let lo = i.saturating_sub(budget - 1);
        for s in 0..budget {
            let off = i * budget + s;
            let j = lo + s;
            if j <= i {
                idx[off] = j as i32;
                valid[off] = 1.0;
                slots[off] = rng.normal() as f32;
            }
        }
    }
    // diagonal-band mask filling the budget (pack input)
    let mut mask = BlockMask::empty(nb);
    for i in 0..nb {
        for j in i.saturating_sub(budget - 1)..=i {
            mask.insert(i, j);
        }
    }

    let mut b = Bench::new(&format!("kernel micro (host) @ seq {seq}"));
    b.case("search_vslash", || {
        std::hint::black_box(search_vslash(&amap, bs, seq, gamma));
        seq
    });
    b.case("search_flash_threshold", || {
        std::hint::black_box(search_vslash_threshold(&amap, bs, seq,
                                                     gamma));
        seq
    });
    b.case("construct_pivotal", || {
        std::hint::black_box(construct_pivotal(&abar, nb, gamma, (0, 0)));
        seq
    });
    b.case("blockmask_pack", || {
        std::hint::black_box(mask.pack(budget));
        seq
    });
    b.case("scatter_abar", || {
        std::hint::black_box(scatter_abar(&slots, &idx, &valid, nb,
                                          budget));
        seq
    });
    println!("\n{}", b.report());

    if let Some(path) = json_path {
        // no JSON serializer in the offline vendor set; the schema is
        // flat enough to emit by hand (mirrors serve_bench)
        let mut s = format!(
            "{{\n  \"group\": \"kernel_micro_host\",\n  \
             \"seq\": {seq},\n  \"kernels\": [\n");
        for (i, r) in b.results.iter().enumerate() {
            let ns_per_token = r.mean_ms * 1e6 / seq as f64;
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_ms\": {:.4}, \
                 \"ns_per_token\": {:.4}}}{}\n",
                r.name, r.mean_ms, ns_per_token,
                if i + 1 < b.results.len() { "," } else { "" }));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, s)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn artifact_bench() -> anyhow::Result<()> {
    let registry = open_registry(&Config::default())?;
    let spec = registry.model("sim-llama")?.clone();
    let seq = if std::env::var("BENCH_FAST").is_ok() { 1024 } else { 2048 };
    let nb = seq / shareprefill::BLOCK_SIZE;
    let d = spec.head_dim;
    let mut rng = Rng::new(5);
    let q = Tensor::f32(vec![seq, d], rand(&mut rng, seq * d));
    let k = Tensor::f32(vec![seq, d], rand(&mut rng, seq * d));
    let v = Tensor::f32(vec![seq, d], rand(&mut rng, seq * d));

    let mut b = Bench::new(&format!("kernel: attn artifact @ seq {seq}"))
        .with_iters(1, 3);
    for frac in [8usize, 4, 2, 1] {
        let budget = spec.budget_bucket_for(seq, nb / frac);
        // diagonal-band mask filling the budget
        let mut mask = BlockMask::empty(nb);
        for i in 0..nb {
            for j in i.saturating_sub(budget - 1)..=i {
                mask.insert(i, j);
            }
        }
        let (idx, valid) = mask.pack(budget);
        let name = format!("{}_attn_s{}_b{}", spec.prefix, seq, budget);
        let (q2, k2, v2) = (q.clone(), k.clone(), v.clone());
        b.case(&format!("budget {budget}/{nb}"), || {
            registry.execute(&name, &[q2.clone(), k2.clone(), v2.clone(),
                                      idx.clone(), valid.clone()])
                .unwrap();
            mask.count()
        });
    }
    // probe artifacts
    let h = spec.num_heads;
    let qh = Tensor::f32(vec![h, 64, d], rand(&mut rng, h * 64 * d));
    let kh = Tensor::f32(vec![h, seq, d], rand(&mut rng, h * seq * d));
    let name = format!("{}_patternprobe_s{}", spec.prefix, seq);
    b.case("pattern_probe", || {
        registry.execute(&name, &[qh.clone(), kh.clone()]).unwrap();
        1
    });
    let name = format!("{}_vslashprobe_s{}", spec.prefix, seq);
    b.case("vslash_probe", || {
        registry.execute(&name, &[qh.clone(), kh.clone()]).unwrap();
        1
    });
    println!("\n{}", b.report());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut host = false;
    let mut json_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--host-only" => host = true,
            "--json" => {
                json_path = Some(args.next().ok_or_else(
                    || anyhow::anyhow!("--json expects a path"))?);
            }
            _ => {} // `cargo bench` may pass harness flags; ignore
        }
    }
    if host {
        host_only(json_path.as_deref())
    } else {
        artifact_bench()
    }
}
