//! L1 kernel micro-bench: budgeted attention artifact cost vs. budget —
//! verifies executed cost tracks the block budget (the §6.1 speedup
//! mechanism) and measures probe overhead.

use shareprefill::attention::BlockMask;
use shareprefill::bench::Bench;
use shareprefill::config::Config;
use shareprefill::eval::open_registry;
use shareprefill::runtime::Tensor;
use shareprefill::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let registry = open_registry(&Config::default())?;
    let spec = registry.model("sim-llama")?.clone();
    let seq = if std::env::var("BENCH_FAST").is_ok() { 1024 } else { 2048 };
    let nb = seq / shareprefill::BLOCK_SIZE;
    let d = spec.head_dim;
    let mut rng = Rng::new(5);
    let rand = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    };
    let q = Tensor::f32(vec![seq, d], rand(&mut rng, seq * d));
    let k = Tensor::f32(vec![seq, d], rand(&mut rng, seq * d));
    let v = Tensor::f32(vec![seq, d], rand(&mut rng, seq * d));

    let mut b = Bench::new(&format!("kernel: attn artifact @ seq {seq}"))
        .with_iters(1, 3);
    for frac in [8usize, 4, 2, 1] {
        let budget = spec.budget_bucket_for(seq, nb / frac);
        // diagonal-band mask filling the budget
        let mut mask = BlockMask::empty(nb);
        for i in 0..nb {
            for j in i.saturating_sub(budget - 1)..=i {
                mask.insert(i, j);
            }
        }
        let (idx, valid) = mask.pack(budget);
        let name = format!("{}_attn_s{}_b{}", spec.prefix, seq, budget);
        let (q2, k2, v2) = (q.clone(), k.clone(), v.clone());
        b.case(&format!("budget {budget}/{nb}"), || {
            registry.execute(&name, &[q2.clone(), k2.clone(), v2.clone(),
                                      idx.clone(), valid.clone()])
                .unwrap();
            mask.count()
        });
    }
    // probe artifacts
    let h = spec.num_heads;
    let qh = Tensor::f32(vec![h, 64, d], rand(&mut rng, h * 64 * d));
    let kh = Tensor::f32(vec![h, seq, d], rand(&mut rng, h * seq * d));
    let name = format!("{}_patternprobe_s{}", spec.prefix, seq);
    b.case("pattern_probe", || {
        registry.execute(&name, &[qh.clone(), kh.clone()]).unwrap();
        1
    });
    let name = format!("{}_vslashprobe_s{}", spec.prefix, seq);
    b.case("vslash_probe", || {
        registry.execute(&name, &[qh.clone(), kh.clone()]).unwrap();
        1
    });
    println!("\n{}", b.report());
    Ok(())
}
