//! Table 2 latency column: prefill latency of the ablation variants.

use shareprefill::bench::Bench;
use shareprefill::config::{Config, MethodKind};
use shareprefill::eval::{build_engine, open_registry};
use shareprefill::workloads::tasks::latency_prompt;

fn main() -> anyhow::Result<()> {
    let registry = open_registry(&Config::default())?;
    let ctx = if std::env::var("BENCH_FAST").is_ok() { 1024 } else { 2048 };
    let prompt = latency_prompt(ctx);
    let mut b = Bench::new(&format!("table2: ablation latency @ {ctx}"))
        .with_iters(1, 2);
    let variants = [("ours", 0.2, 0.3), ("wo_sharing(tau=0)", 0.0, 0.3),
                    ("wo_exclusion(delta=1.01)", 0.2, 1.01)];
    for (name, tau, delta) in variants {
        let mut cfg = Config::default();
        cfg.method.tau = tau;
        cfg.method.delta = delta;
        let mut engine = build_engine(&registry, &cfg, "sim-llama",
                                      MethodKind::SharePrefill)?;
        b.case(name, || engine.prefill(&prompt).unwrap().real_len);
    }
    println!("\n{}", b.report());
    Ok(())
}
