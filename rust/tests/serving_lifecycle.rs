//! Server/scheduler lifecycle tests over the artifact-free `SimEngine`:
//! these run in CI with no compiled artifacts and pin down the session
//! API's contracts — chunked prefill interleaves decode, concurrent
//! prefills interleave chunks (and stay strictly serial at
//! `max_concurrent_prefills = 1`), short prompts overtake long
//! prefills, KV-starved requests re-queue then reject with a typed
//! `RejectReason`, cancellation works mid-prefill (including with other
//! prefills in flight), and shutdown drains every in-flight session —
//! always exactly one terminal event per session.

use shareprefill::config::ServeConfig;
use shareprefill::exec::env_workers;
use shareprefill::serving::scheduler::Scheduler;
use shareprefill::serving::server;
use shareprefill::serving::sim::SimEngine;
use shareprefill::serving::{Event, EventSink, RejectReason, Request};

fn drain<E: shareprefill::serving::EngineCore>(
    sched: &mut Scheduler<E>, engine: &mut E) {
    let mut rounds = 0;
    while sched.has_work() {
        sched.run_round(engine).unwrap();
        rounds += 1;
        assert!(rounds < 100_000, "scheduler failed to drain");
    }
}

/// The continuous-batching acceptance property: with a short prompt
/// decoding and a long prompt prefilling, decode tokens land *between*
/// consecutive prefill chunks of the long prompt.
#[test]
fn decode_interleaves_between_prefill_chunks() {
    let cfg = ServeConfig {
        max_batch_tokens: 8, // small round budget: fine-grained rounds
        chunk_layers: 1,
        decode_tokens: 16,
        ..Default::default()
    };
    let mut engine = SimEngine::new(6);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    // one shared sink so cross-session event order is observable
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 64], 16), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 640], 4), sink.clone()));
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();

    // request 1 ran its prefill in 6 single-layer chunks
    let progress_1 = events.iter()
        .filter(|e| matches!(e, Event::PrefillProgress { id: 1, .. }))
        .count();
    assert_eq!(progress_1, 6, "expected one PrefillProgress per layer");

    // a decode Token of request 0 appears strictly between two prefill
    // chunks of request 1 — the head-of-line blocking fix in one assert
    let mut seen_progress_1 = false;
    let mut token_between = false;
    for e in &events {
        match e {
            Event::PrefillProgress { id: 1, .. } => {
                seen_progress_1 = true;
            }
            Event::Token { id: 0, .. } if seen_progress_1 => {
                // is there another chunk of 1 after this token?
                token_between = true;
                break;
            }
            _ => {}
        }
    }
    assert!(token_between,
            "no decode token interleaved into the long prefill");
    // ... and request 1 still had prefill chunks pending at that point
    let last_token_0 = events.iter()
        .position(|e| matches!(e, Event::Token { id: 0, .. }))
        .unwrap();
    let chunks_after = events[last_token_0..].iter()
        .filter(|e| matches!(e, Event::PrefillProgress { id: 1, .. }))
        .count();
    assert!(chunks_after >= 1,
            "first decode token should precede later prefill chunks");

    // both sessions reach Done with the right token counts
    for (id, want) in [(0u64, 16usize), (1, 4)] {
        let done = events.iter().find_map(|e| match e {
            Event::Done { id: i, response } if *i == id => Some(response),
            _ => None,
        }).expect("missing Done");
        assert_eq!(done.generated.len(), want);
    }
    assert_eq!(sched.kv.used(), 0);
}

/// With `max_concurrent_prefills > 1`, chunks of two prompts interleave
/// within one engine — the multi-prefill tentpole property at the
/// scheduler level.
#[test]
fn concurrent_prefills_interleave_chunks() {
    let cfg = ServeConfig {
        max_batch_tokens: 8192,
        chunk_layers: 1,
        decode_tokens: 2,
        max_concurrent_prefills: 2,
        ..Default::default()
    };
    let mut engine = SimEngine::new(6);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 640], 2), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 640], 2), sink.clone()));
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();

    // chunk progress of request 1 lands before request 0 finishes its
    // prefill (and vice versa): the prefills genuinely interleave
    let done_0 = events.iter()
        .position(|e| matches!(e, Event::PrefillDone { id: 0, .. }))
        .expect("request 0 never finished prefill");
    let progress_1_before = events[..done_0].iter()
        .filter(|e| matches!(e, Event::PrefillProgress { id: 1, .. }))
        .count();
    assert!(progress_1_before >= 1,
            "no chunk of request 1 ran during request 0's prefill");
    for id in [0u64, 1] {
        let terminals = events.iter()
            .filter(|e| e.id() == id && e.is_terminal())
            .count();
        assert_eq!(terminals, 1, "request {id}: exactly one terminal");
        assert!(events.iter().any(|e| matches!(
            e, Event::Done { id: i, .. } if *i == id)));
    }
    assert_eq!(sched.kv.used(), 0);
}

/// Regression for the PR-2 contract: with `max_concurrent_prefills = 1`
/// prefills stay strictly serial — no chunk of a later prompt runs
/// before the earlier prompt's `PrefillDone`.
#[test]
fn single_prefill_mode_stays_serial() {
    let cfg = ServeConfig {
        max_batch_tokens: 8192,
        chunk_layers: 1,
        decode_tokens: 2,
        max_concurrent_prefills: 1,
        ..Default::default()
    };
    let mut engine = SimEngine::new(6);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 640], 2), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 640], 2), sink.clone()));
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();
    let done_0 = events.iter()
        .position(|e| matches!(e, Event::PrefillDone { id: 0, .. }))
        .expect("request 0 never finished prefill");
    let progress_1_before = events[..done_0].iter()
        .filter(|e| matches!(e, Event::PrefillProgress { id: 1, .. }))
        .count();
    assert_eq!(progress_1_before, 0,
               "serial mode must not interleave prefills");
    assert_eq!(sched.metrics.requests_completed, 2);
    assert_eq!(sched.kv.used(), 0);
}

/// Shortest-remaining-work-first: a short prompt submitted *after* a
/// long one finishes its prefill first when concurrency allows.
#[test]
fn short_prompt_overtakes_long_prefill() {
    // budget fits the long prompt's exempt chunk (4096/8 = 512) plus all
    // 8 of the short prompt's chunks (64/8 = 8 each) per round, so the
    // short prompt finishes its whole prefill while the long one is on
    // chunk 1 — the TTFT fairness win in one assert
    let cfg = ServeConfig {
        max_batch_tokens: 600,
        chunk_layers: 1,
        decode_tokens: 2,
        max_concurrent_prefills: 2,
        ..Default::default()
    };
    let mut engine = SimEngine::new(8);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 4096], 2), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 64], 2), sink.clone()));
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();
    let done_long = events.iter()
        .position(|e| matches!(e, Event::PrefillDone { id: 0, .. }))
        .unwrap();
    let done_short = events.iter()
        .position(|e| matches!(e, Event::PrefillDone { id: 1, .. }))
        .unwrap();
    assert!(done_short < done_long,
            "short prompt must not wait out the long prefill");
    assert_eq!(sched.metrics.requests_completed, 2);
    assert_eq!(sched.kv.used(), 0);
}

/// The over-budget regime the fairness redesign targets: when the long
/// prompt's chunk alone outweighs the whole round budget, short prompts
/// still prefill at full speed inside the budget (the mega-chunk is
/// deferred to the round-end exempt grant, not allowed to eat the
/// round), and the long prompt still advances exactly one chunk per
/// round — no starvation either way.
#[test]
fn short_prompts_progress_when_long_chunk_exceeds_budget() {
    let cfg = ServeConfig {
        max_batch_tokens: 400, // long chunk cost: 4096/8 = 512 > 400
        chunk_layers: 1,
        decode_tokens: 2,
        max_concurrent_prefills: 2,
        ..Default::default()
    };
    let mut engine = SimEngine::new(8);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 4096], 2), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 64], 2), sink.clone()));
    sched.run_round(&mut engine).unwrap();
    let round1: Vec<Event> = rx.try_iter().collect();
    assert!(round1.iter().any(|e| matches!(e, Event::Done { id: 1, .. })),
            "short prompt must complete within the first round; the \
             long prompt's over-budget chunk must not eat the round");
    let long_chunks = round1.iter()
        .filter(|e| matches!(e, Event::PrefillProgress { id: 0, .. }))
        .count();
    assert_eq!(long_chunks, 1,
               "long prompt advances exactly its one exempt chunk");
    drain(&mut sched, &mut engine);
    drop(sink);
    let rest: Vec<Event> = rx.iter().collect();
    assert!(rest.iter().any(|e| matches!(e, Event::Done { id: 0, .. })),
            "long prompt must not starve");
    assert_eq!(sched.metrics.requests_completed, 2);
    assert_eq!(sched.kv.used(), 0);
}

/// The engine-level determinism contract of the head-parallel worker
/// pool: the same mixed-length request stream scheduled at pool width
/// 1 and at `SHAREPREFILL_WORKERS` (default 4) produces the same
/// events in the same order — tokens, progress, terminals — and
/// bit-identical per-request block accounting and decode output.
#[test]
fn worker_pool_widths_produce_identical_event_streams() {
    let run = |workers: usize| {
        let cfg = ServeConfig {
            max_batch_tokens: 96,
            chunk_layers: 1,
            decode_tokens: 3,
            max_concurrent_prefills: 2,
            ..Default::default()
        };
        let mut engine = SimEngine::new(6).with_workers(workers);
        let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
        let (sink, rx) = EventSink::channel();
        for (id, len) in [(0u64, 640usize), (1, 64), (2, 320)] {
            assert!(sched.submit(Request::new(id, vec![1; len], 3),
                                 sink.clone()));
        }
        drain(&mut sched, &mut engine);
        drop(sink);
        rx.iter().collect::<Vec<Event>>()
    };
    let serial = run(1);
    // .max(2): the parallel arm stays distinct even when the CI matrix
    // pins SHAREPREFILL_WORKERS=1
    let wide = run(env_workers().unwrap_or(4).max(2));
    assert_eq!(serial.len(), wide.len(),
               "worker width changed the number of events");
    for (a, b) in serial.iter().zip(&wide) {
        match (a, b) {
            (Event::PrefillDone { id: ia, stats: sa },
             Event::PrefillDone { id: ib, stats: sb }) => {
                assert_eq!(ia, ib);
                assert_eq!(
                    (sa.blocks_computed, sa.blocks_total, sa.dense,
                     sa.shared, sa.vslash),
                    (sb.blocks_computed, sb.blocks_total, sb.dense,
                     sb.shared, sb.vslash),
                    "request {ia}: block accounting diverged");
            }
            (Event::Token { id: ia, token: ta, index: xa },
             Event::Token { id: ib, token: tb, index: xb }) => {
                assert_eq!((ia, ta, xa), (ib, tb, xb),
                           "decode token diverged");
            }
            (Event::Done { id: ia, response: ra },
             Event::Done { id: ib, response: rb }) => {
                assert_eq!(ia, ib);
                assert_eq!(ra.generated, rb.generated,
                           "request {ia}: generated tokens diverged");
            }
            _ => assert_eq!(
                std::mem::discriminant(a), std::mem::discriminant(b),
                "event kind diverged: {a:?} vs {b:?}"),
        }
    }
}

/// Cancel one of two concurrent prefills mid-flight: its KV frees, the
/// survivor completes, and every session ends in exactly one terminal
/// event.
#[test]
fn cancel_one_concurrent_prefill_mid_flight() {
    let cfg = ServeConfig {
        max_batch_tokens: 1, // at most the exempt chunk per round
        chunk_layers: 1,
        decode_tokens: 2,
        max_concurrent_prefills: 2,
        ..Default::default()
    };
    let mut engine = SimEngine::new(8);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 640], 2), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 320], 2), sink.clone()));
    // a few partial rounds: both prefills live, neither done
    for _ in 0..4 {
        sched.run_round(&mut engine).unwrap();
    }
    assert_eq!(sched.prefills_in_flight(), 2);
    let kv_both = sched.kv.used();
    assert!(kv_both > 0);
    assert!(sched.cancel(0));
    assert!(sched.kv.used() < kv_both,
            "cancelling must free the cancelled prefill's KV");
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();
    for id in [0u64, 1] {
        let terminals = events.iter()
            .filter(|e| e.id() == id && e.is_terminal())
            .count();
        assert_eq!(terminals, 1, "request {id}: exactly one terminal");
    }
    assert!(events.iter().any(|e| matches!(e, Event::Cancelled { id: 0 })));
    assert!(events.iter().any(|e| matches!(e, Event::Done { id: 1, .. })),
            "survivor must still complete");
    assert_eq!(sched.kv.used(), 0);
    assert_eq!(sched.metrics.requests_cancelled, 1);
    assert_eq!(sched.metrics.requests_completed, 1);
}

/// `Rejected` now says why: KV starvation after bounded retries and an
/// empty prompt produce distinguishable `RejectReason`s.
#[test]
fn reject_reasons_distinguish_kv_from_empty() {
    let cfg = ServeConfig {
        kv_blocks: 2, // a 64-token, 4-layer request needs 4
        decode_tokens: 0,
        admit_retries: 3,
        ..Default::default()
    };
    let mut engine = SimEngine::new(4);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 64], 0), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![], 0), sink.clone()));
    drain(&mut sched, &mut engine);
    drop(sink);
    let mut kinds = std::collections::HashMap::new();
    for e in rx.iter() {
        if let Event::Rejected { id, reason } = e {
            kinds.insert(id, reason);
        }
    }
    let kv = kinds.get(&0).expect("kv-starved request must be rejected");
    assert_eq!(kv.kind(), "kv-exhausted");
    assert!(kv.is_transient());
    assert!(matches!(kv, RejectReason::KvExhausted {
        blocks_needed: 4, retries: 3 }));
    let empty = kinds.get(&1).expect("empty prompt must be rejected");
    assert_eq!(empty.kind(), "empty-prompt");
    assert!(!empty.is_transient());
}

/// KV-starved head of queue waits (bounded) and is admitted once blocks
/// free up — no silent drop, no spurious rejection.
#[test]
fn kv_exhausted_request_requeues_until_blocks_free() {
    // blocks_needed(64, 0, 4) = ceil(64/64)*4 = 4: capacity for exactly
    // one request at a time
    let cfg = ServeConfig {
        kv_blocks: 4,
        decode_tokens: 0,
        admit_retries: 64,
        ..Default::default()
    };
    let mut engine = SimEngine::new(4);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(0, vec![1; 64], 0), sink.clone()));
    assert!(sched.submit(Request::new(1, vec![1; 64], 0), sink.clone()));
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();
    let dones = events.iter()
        .filter(|e| matches!(e, Event::Done { .. }))
        .count();
    assert_eq!(dones, 2, "second request must be re-queued, not dropped");
    assert_eq!(sched.metrics.requests_rejected, 0);
    assert_eq!(sched.kv.used(), 0);
}

/// A request that can never fit gets a terminal Rejected event after the
/// bounded retries — clients never hang.
#[test]
fn kv_impossible_request_rejects_with_terminal_event() {
    let cfg = ServeConfig {
        kv_blocks: 2, // needs 4
        decode_tokens: 0,
        admit_retries: 3,
        ..Default::default()
    };
    let mut engine = SimEngine::new(4);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    assert!(sched.submit(Request::new(7, vec![1; 64], 0), sink));
    drain(&mut sched, &mut engine);
    let events: Vec<Event> = rx.iter().collect();
    assert!(events.iter().any(|e| matches!(
        e, Event::Rejected { id: 7, .. })),
            "KV-starved request must end with a terminal Rejected event");
    assert_eq!(sched.metrics.requests_rejected, 1);
}

#[test]
fn empty_prompt_rejected_not_panicking() {
    let cfg = ServeConfig::default();
    let mut engine = SimEngine::new(2);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    sched.submit(Request::new(3, vec![], 4), sink);
    drain(&mut sched, &mut engine);
    let events: Vec<Event> = rx.iter().collect();
    assert!(matches!(events.as_slice(),
                     [Event::Rejected { id: 3, .. }]));
}

/// Oversized prompts fail per-request (engine's bucket error), not by
/// killing the server loop.
#[test]
fn oversized_prompt_rejects_per_request() {
    let cfg = ServeConfig::default();
    let mut engine = SimEngine::new(2).with_max_prompt(128);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    sched.submit(Request::new(0, vec![1; 4096], 2), sink.clone());
    sched.submit(Request::new(1, vec![1; 64], 2), sink.clone());
    drain(&mut sched, &mut engine);
    drop(sink);
    let events: Vec<Event> = rx.iter().collect();
    assert!(events.iter().any(|e| matches!(
        e, Event::Rejected { id: 0, .. })));
    assert!(events.iter().any(|e| matches!(e, Event::Done { id: 1, .. })),
            "later requests keep serving after a per-request failure");
}

/// Cancel a session mid-prefill: terminal Cancelled event, KV released,
/// scheduler drains clean.
#[test]
fn cancel_mid_prefill_releases_kv() {
    let cfg = ServeConfig {
        max_batch_tokens: 1, // one chunk per round
        chunk_layers: 1,
        ..Default::default()
    };
    let mut engine = SimEngine::new(8);
    let mut sched: Scheduler<SimEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    sched.submit(Request::new(0, vec![1; 640], 4), sink);
    sched.run_round(&mut engine).unwrap(); // prefill started, not done
    assert!(sched.kv.used() > 0);
    assert!(sched.cancel(0));
    assert_eq!(sched.kv.used(), 0, "cancel must free the KV reservation");
    assert!(!sched.has_work());
    let events: Vec<Event> = rx.iter().collect();
    assert!(matches!(events.last(), Some(Event::Cancelled { id: 0 })));
    let progressed = events.iter()
        .filter(|e| matches!(e, Event::PrefillProgress { .. }))
        .count();
    assert!(progressed >= 1 && progressed < 8,
            "cancellation should land mid-prefill (got {progressed})");
    assert_eq!(sched.metrics.requests_cancelled, 1);
}

/// Full server lifecycle over threads: spawn → submit mixed lengths →
/// cancel one → shutdown drains; every session gets exactly one terminal
/// event and the report reflects the traffic.
#[test]
fn server_lifecycle_submit_cancel_shutdown_drains() {
    let cfg = ServeConfig {
        max_batch_tokens: 64,
        chunk_layers: 1,
        decode_tokens: 4,
        ..Default::default()
    };
    let handle = server::spawn(move || {
        // big layer count: prefills span many rounds, so the Cancel
        // command lands while its target is still queued or prefilling
        Ok((Scheduler::new(&cfg), SimEngine::new(64)))
    });
    let sessions: Vec<_> = [64usize, 256, 512, 128, 320]
        .iter()
        .map(|&len| handle.submit(vec![1; len], 4))
        .collect();
    let cancel_id = sessions[4].id;
    handle.cancel(cancel_id);
    let report = handle.shutdown();

    let mut terminal = 0;
    let mut cancelled_seen = false;
    for s in sessions {
        let id = s.id;
        let events = s.collect();
        let last = events.last().expect("no events for session");
        assert!(last.is_terminal(),
                "session {id} stream ended without a terminal event");
        // exactly one terminal event, and it is the last one
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
        terminal += 1;
        match last {
            Event::Cancelled { id } => {
                assert_eq!(*id, cancel_id);
                cancelled_seen = true;
            }
            Event::Done { response, .. } => {
                assert_eq!(response.generated.len(), 4);
                // SimEngine stamps prefill latency deterministically
                assert_eq!(response.prefill_us, 1);
            }
            other => panic!("unexpected terminal event {other:?}"),
        }
    }
    assert_eq!(terminal, 5);
    // the cancel raced the worker: it either landed (Cancelled) or the
    // session had already finished (Done) — both are terminal; the
    // deterministic mid-prefill case is covered above
    let _ = cancelled_seen;
    assert!(report.contains("requests:"), "report missing: {report}");
}

/// An engine that dies mid-prefill: the scheduler can't finish this
/// session, but must not leak its KV reservation or strand its client.
struct FailEngine;

impl shareprefill::serving::EngineCore for FailEngine {
    type Prefill = ();
    type Decode = ();

    fn layers_total(&self) -> usize {
        4
    }

    fn begin_prefill(&mut self, _tokens: &[i32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn prefill_chunk(&mut self, _t: &mut (), _layers: usize)
                     -> anyhow::Result<bool> {
        anyhow::bail!("kernel exploded")
    }

    fn prefill_progress(&self, _t: &()) -> (usize, usize) {
        (0, 4)
    }

    fn start_decode(&mut self, _t: (), _max_new: usize)
                    -> anyhow::Result<((),
                                       shareprefill::serving::PrefillStats)> {
        anyhow::bail!("unreachable")
    }

    fn decode_step(&mut self, _d: &mut ()) -> anyhow::Result<Option<i32>> {
        Ok(None)
    }

    fn generated<'a>(&self, _d: &'a ()) -> &'a [i32] {
        &[]
    }

    fn decode_elapsed_us(&self, _d: &()) -> u64 {
        0
    }
}

#[test]
fn engine_error_mid_prefill_frees_kv_and_emits_terminal_error() {
    let cfg = ServeConfig::default();
    let mut engine = FailEngine;
    let mut sched: Scheduler<FailEngine> = Scheduler::new(&cfg);
    let (sink, rx) = EventSink::channel();
    sched.submit(Request::new(5, vec![1; 64], 2), sink);
    assert!(sched.run_round(&mut engine).is_err());
    assert_eq!(sched.kv.used(), 0,
               "failed session must not leak its KV reservation");
    let events: Vec<Event> = rx.iter().collect();
    assert!(matches!(events.last(), Some(Event::Error { id: 5, .. })),
            "client must receive a terminal Error event, got {events:?}");
}

/// submit_blocking stays a one-call path for evals.
#[test]
fn submit_blocking_roundtrip() {
    let cfg = ServeConfig::default();
    let handle = server::spawn(move || {
        Ok((Scheduler::new(&cfg), SimEngine::new(4)))
    });
    let r = handle.submit_blocking(vec![1; 64], 3).unwrap();
    assert_eq!(r.generated, vec![64, 65, 66]);
    let report = handle.shutdown();
    assert!(report.contains("1 done"));
}

/// Engine init failure surfaces through the report channel and pending
/// sessions unblock with an error instead of hanging.
#[test]
fn engine_init_failure_does_not_hang_clients() {
    let handle = server::spawn(
        || -> anyhow::Result<(Scheduler<SimEngine>, SimEngine)> {
            anyhow::bail!("no artifacts here")
        });
    let s = handle.submit(vec![1; 16], 1);
    assert!(s.wait().is_err(), "client must not hang on dead server");
    let report = handle.shutdown();
    assert!(report.contains("engine init failed"));
}
